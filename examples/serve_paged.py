"""Serve a small model with batched requests through the paged engine:
continuous batching, memos HBM<->host KV-page tiering, preemption under
HBM pressure, and exact greedy decoding.

Run:  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np

from repro.configs import registry, smoke
from repro.models import transformer as T
from repro.serving import PagedServingEngine, ServeConfig

cfg = smoke(registry()["qwen3_4b"])
params = T.init_params(cfg, jax.random.PRNGKey(0))

engine = PagedServingEngine(cfg, params, ServeConfig(
    page_size=8, max_batch=3, fast_slots=16, slow_slots=256,
    memos_interval=6))

rng = np.random.RandomState(0)
reqs = [engine.submit(rng.randint(0, cfg.vocab, size=n).tolist(), max_new=8)
        for n in (5, 9, 3, 12, 7, 4)]

hist = engine.run(max_steps=400)

print(f"served {len(reqs)} requests in {engine.step_count} steps "
      f"({engine.tokens_out} new tokens)")
for r in reqs:
    lat = (r.finish_step or 0) - r.arrival
    print(f"  req {r.rid}: prompt={len(r.prompt):>2} -> {r.generated} "
          f"(latency {lat} steps)")

st = engine.kv.store
print(f"\nKV traffic: HBM->host {st.traffic[(0, 1)]}B, "
      f"host->HBM {st.traffic[(1, 0)]}B")
print(f"memos passes: {len(engine.memos.reports)}, "
      f"migrations: {sum(r.migrations.migrated for r in engine.memos.reports)}")
occ = engine.kv.occupancy()
print(f"final pool occupancy: {occ}")
