"""End-to-end driver: train a ~100M-param OLMoE-family MoE for a few
hundred steps on CPU with checkpoint/restart, expert-hotness tracking
(the MoE half of memos), and a simulated mid-run crash + recovery.

Run:  PYTHONPATH=src python examples/train_moe_tiered.py [--steps 200]
"""
import argparse
import tempfile
from dataclasses import replace

import numpy as np

from repro.configs import get_arch, smoke
from repro.launch.train import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--big", action="store_true",
                help="~100M params (slower); default is the smoke config")
args = ap.parse_args()

cfg = smoke(get_arch("olmoe_1b_7b"))
if args.big:  # ~100M params: d_model 512, 8 layers, 16 experts
    cfg = replace(cfg, d_model=512, n_layers=8, n_experts=16, top_k=4,
                  expert_d_ff=512, d_ff=512, vocab=8192, d_head=64,
                  n_heads=8, n_kv_heads=8)

with tempfile.TemporaryDirectory() as ckpt_dir:
    crash_at = args.steps // 2
    print(f"=== training with a simulated crash at step {crash_at} ===")
    try:
        train_loop(cfg, steps=args.steps, global_batch=8, seq_len=64,
                   ckpt_dir=ckpt_dir, ckpt_every=25, crash_at=crash_at)
    except RuntimeError as e:
        print(f"!! {e} — restarting from the latest checkpoint")

    losses, params, _ = train_loop(cfg, steps=args.steps, global_batch=8,
                                   seq_len=64, ckpt_dir=ckpt_dir,
                                   ckpt_every=25)
    print(f"\nrecovered + finished: loss {losses[0 if losses else 0]:.4f} "
          f"... {losses[-1]:.4f}")
    assert losses[-1] < 5.0, "training failed to learn the synthetic task"
    print("loss decreased on the synthetic Markov task ✓")
