"""Long-context decode with sub-quadratic archs: a Mamba-2 smoke model
decodes far past any attention window with O(1) state, and a
sliding-window (mixtral-family) model decodes with a ring-buffer KV cache
that never grows — the mechanisms behind the long_500k dry-run cells.

Run:  PYTHONPATH=src python examples/longctx_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry, smoke
from repro.models import transformer as T

for arch in ("mamba2_1_3b", "mixtral_8x7b"):
    cfg = smoke(registry()[arch])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab, size=24)

    # teacher-forced reference over the whole long sequence
    horizon = 40
    toks = jnp.asarray([prompt.tolist() + [0] * horizon], jnp.int32)

    lg, state = T.prefill(params, cfg,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=32)  # cache far smaller than the context!
    kv_bytes = sum(int(np.prod(c["k"].shape)) * 2 * 4
                   for c in state["attn"])
    ssm_bytes = sum(int(np.prod(c["h"].shape)) * 4 for c in state["mamba"])
    gen = []
    for t in range(horizon):
        g = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
        gen.append(g)
        lg, state = T.decode_step(params, cfg, state,
                                  {"tokens": jnp.asarray([[g]], jnp.int32)})
    print(f"{arch:16s} decoded {horizon} tokens past a {len(prompt)}-token "
          f"prompt; state: kv={kv_bytes}B ssm={ssm_bytes}B (context-length-"
          f"independent)")
    print(f"  first 10: {gen[:10]}")
print("ring-buffer / O(1)-state long-context decode ✓")
