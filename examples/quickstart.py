"""Quickstart: the memos core on a synthetic page workload.

Builds a hybrid fast/slow TierStore, drives a phased access pattern
through SysMon, and shows the memos loop (predict -> plan -> migrate)
moving hot/WD pages to the fast tier and draining cold pages to the slow
tier — the paper's Fig. 10 pipeline end to end, in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.hierarchy import FAST, SLOW
from repro.core.tiers import TierConfig, TierStore

N_PAGES, FAST_SLOTS = 64, 16

store = TierStore(TierConfig(n_pages=N_PAGES, fast_slots=FAST_SLOTS,
                             slow_slots=N_PAGES, page_shape=(8,)))
for p in range(N_PAGES):
    store.allocate(p, SLOW)                       # everything starts "on NVM"
    store.write_page(p, np.full(8, p, np.float32))

mgr = MemosManager(store, MemosConfig(interval=4, adaptive_interval=False))
sm = sysmon.init(N_PAGES, n_banks=8, n_slabs=4)

print(f"{'step':>4} {'fast':>5} {'slow':>5} {'migrated':>9} {'imbalance':>9}")
for step in range(48):
    phase = step // 16                            # working set shifts twice
    hot = jnp.arange(phase * 8, phase * 8 + 8)
    warm = jnp.arange(40, 48)                     # read-mostly pages
    sm = sysmon.record(sm, hot, is_write=True)
    sm = sysmon.record(sm, warm, is_write=False)
    sm, report = mgr.maybe_step(sm)
    if report:
        print(f"{step:>4} {report.fast_pages:>5} {report.slow_pages:>5} "
              f"{report.migrations.migrated:>9} {report.bank_imbalance:>9.2f}")

tiers = np.asarray(store.tier)
print("\nfinal placement (phase-2 hot pages 16..23 should be FAST):")
print("  pages 16..23 tier:", tiers[16:24].tolist(), "(0=FAST)")
print("  pages  0..7  tier:", tiers[0:8].tolist(), "(1=SLOW, decayed)")
for p in range(N_PAGES):                          # contents always intact
    np.testing.assert_array_equal(store.read_page(p), np.full(8, p))
print("all page contents bit-exact after migrations ✓")
