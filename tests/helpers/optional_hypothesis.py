"""Optional-hypothesis shim: property tests skip cleanly when hypothesis
is not installed (it lives in requirements-dev.txt, not the runtime deps).

    from helpers.optional_hypothesis import given, settings, st

When hypothesis is present these are the real objects.  Otherwise ``given``
returns a decorator that marks the test skipped, ``settings`` is a no-op,
and ``st`` yields inert strategy stubs, so modules still collect.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(pip install -r requirements-dev.txt)")

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        def __getattr__(self, _name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
