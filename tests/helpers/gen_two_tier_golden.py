"""Golden-fixture generator for the two-tier parity pin.

Runs a deterministic memos scenario — phased hot sets, migrations in both
directions, wear tracking + Start-Gap leveling active — and dumps the
complete observable hierarchy state to ``tests/data/two_tier_golden.npz``.

The committed fixture was produced by the **pre-redesign** ``TierStore``
(the hardcoded FAST/SLOW implementation, commit 0434817); the regression
test ``tests/test_hierarchy.py::test_two_tier_parity_vs_golden`` replays
the same scenario through ``MemoryHierarchy.two_tier`` and asserts every
array matches bit for bit.  Regenerate only if the scenario itself
changes (which invalidates the pin):

    PYTHONPATH=src:tests python tests/helpers/gen_two_tier_golden.py
"""
from __future__ import annotations

from pathlib import Path

import jax.numpy as jnp
import numpy as np

OUT = Path(__file__).resolve().parents[1] / "data" / "two_tier_golden.npz"

SYSMON_FIELDS = ("reads", "writes", "access_count", "hist", "last_access",
                 "intv_cnt", "intv_sum", "intv_sqsum", "bank_freq",
                 "slab_freq", "sample_idx")


def run_scenario():
    """The pinned scenario: 32 pages, 8 fast slots, leveling every 5 writes,
    three phases of shifting hot sets driving promotions and demotions."""
    from repro.core import sysmon
    from repro.core.memos import MemosConfig, MemosManager
    from repro.core.tiers import TierConfig, TierStore

    store = TierStore(TierConfig(
        n_pages=32, fast_slots=8, slow_slots=32, page_shape=(4,),
        dtype=jnp.float32, n_banks=2, n_slabs=4, gap_write_interval=5))
    slow_tier = int(store.tier[0])          # pages start on the slow tier
    for p in range(32):
        assert store.allocate(p, slow_tier)
        store.write_page(p, np.full(4, float(p), np.float32))

    mgr = MemosManager(store, MemosConfig(interval=4, adaptive_interval=False,
                                          engine="batched"))
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    rng = np.random.RandomState(7)
    for step in range(24):
        phase = step // 8                   # hot set shifts twice
        hot = np.arange(phase * 6, phase * 6 + 6)
        warm = rng.randint(20, 32, size=3)
        sm = sysmon.record(sm, jnp.asarray(hot, jnp.int32), is_write=True)
        sm = sysmon.record(sm, jnp.asarray(warm, jnp.int32), is_write=False)
        if step % 5 == 0:                   # host-side demand writes -> wear
            store.write_page(int(hot[0]), np.full(4, 100.0 + step, np.float32)) \
                if int(store.tier[hot[0]]) == slow_tier else None
        sm, _ = mgr.maybe_step(sm)
    return store, mgr, sm


def collect(store, mgr, sm) -> dict:
    state = {
        "tier": np.asarray(store.tier),
        "slot": np.asarray(store.slot),
        "version": np.asarray(store.version),
        "fast_pool": np.asarray(store.fast_pool, np.float32),
        "pages": np.stack([store.read_page(p)
                           for p in range(store.cfg.n_pages)]),
        "wear_counts": store.wear.wear_counts(),
        "wear_remap": np.asarray(store.wear._remap),
        "wear_writes_total": np.int64(store.wear.writes_total),
        "leveling_writes": np.int64(store.wear.leveling_writes),
        "traffic_fast_to_slow": np.int64(store.traffic[(0, 1)]),
        "traffic_slow_to_fast": np.int64(store.traffic[(1, 0)]),
        "writes_to_fast": np.int64(store.writes_to[0]),
        "writes_to_slow": np.int64(store.writes_to[1]),
        "reads_from_fast": np.int64(store.reads_from[0]),
        "reads_from_slow": np.int64(store.reads_from[1]),
        "n_reports": np.int64(len(mgr.reports)),
        "migrated_per_pass": np.asarray(
            [r.migrations.migrated for r in mgr.reports], np.int64),
        "to_fast_per_pass": np.asarray(
            [r.migrations.to_fast for r in mgr.reports], np.int64),
        "to_slow_per_pass": np.asarray(
            [r.migrations.to_slow for r in mgr.reports], np.int64),
    }
    for f in SYSMON_FIELDS:
        state[f"sysmon_{f}"] = np.asarray(getattr(sm, f))
    return state


def main():
    store, mgr, sm = run_scenario()
    state = collect(store, mgr, sm)
    assert state["traffic_fast_to_slow"] > 0, "scenario must demote pages"
    assert state["traffic_slow_to_fast"] > 0, "scenario must promote pages"
    OUT.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(OUT, **state)
    print(f"wrote {OUT} ({OUT.stat().st_size} bytes), "
          f"{int(state['n_reports'])} memos passes, "
          f"{int(state['migrated_per_pass'].sum())} migrations")


if __name__ == "__main__":
    main()
