"""Small-mesh sharding gate (run as a subprocess: needs 8 fake devices).

For each arch family, runs the *sharded* train step / prefill / decode on
a (2,4) data x model mesh and checks numerical parity against the
unsharded single-logical-device path — catching sharding-rule regressions
long before the 512-device dry-run.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()

import sys  # noqa: E402
from dataclasses import replace  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))


def _mesh_context(mesh):
    """jax.set_mesh where available; on older jax the Mesh object itself is
    the context manager that installs the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh

from repro.configs import get_arch, smoke  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_mesh_info  # noqa: E402
from repro.launch.train import make_train_step  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel import sharding as sh  # noqa: E402


def check(arch_id: str, tweak=None, tol=5e-3):
    cfg = smoke(get_arch(arch_id))
    if tweak:
        cfg = replace(cfg, **tweak)
    mesh = make_debug_mesh(2, 4)
    mi = make_mesh_info(mesh)
    mode = sh.attn_mode(cfg, mi)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    B, S, nm = 4, 16, 2
    rng = np.random.RandomState(0)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jnp.asarray(rng.standard_normal(
                     (nm, B // nm, S, cfg.d_model)), jnp.float32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab,
                                                   (nm, B // nm, S)))}
    else:
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab,
                                                   (nm, B // nm, S))),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab,
                                                   (nm, B // nm, S)))}

    # unsharded reference
    ref_step = jax.jit(make_train_step(cfg, None))
    p_ref, o_ref, m_ref = ref_step(params, opt, batch)

    # sharded
    with _mesh_context(mesh):
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           sh.param_specs(cfg, mi),
                           is_leaf=lambda x: isinstance(x, P))
        params_s = jax.device_put(params, psh)
        opt_s = adamw.init(params_s)
        step = jax.jit(make_train_step(cfg, mi))
        p_s, o_s, m_s = step(params_s, opt_s, batch)

    dl = abs(float(m_ref["loss"]) - float(m_s["loss"]))
    # parameter drift after one update
    dmax = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)))
    ok = dl < tol and dmax < tol
    print(f"{arch_id:16s} mode={mode:9s} dloss={dl:.2e} dparam={dmax:.2e} "
          f"{'OK' if ok else 'FAIL'}", flush=True)
    return ok


def main():
    results = [
        check("qwen3_4b"),                                  # megatron GQA
        check("qwen3_4b", {"n_heads": 6, "n_kv_heads": 3,
                           "d_model": 192}),                # context mode
        # EP with capacity high enough that nothing drops: isolates the
        # sharding math from (intended) GShard capacity-dropping effects
        check("olmoe_1b_7b", {"moe_capacity_factor": 8.0}),  # MoE EP
        check("mixtral_8x7b", {"n_experts": 2}),            # MoE TP branch
        check("mamba2_1_3b"),                               # SSM
        check("zamba2_7b"),                                 # hybrid + shared
        check("gemma3_4b"),                                 # local/global+tied
    ]
    if not all(results):
        sys.exit(1)
    print("ALL SHARDED PARITY CHECKS PASSED")


if __name__ == "__main__":
    main()
