"""End-to-end behaviour test for the paper's system: the full memos loop
(SysMon -> predictor -> placement -> migration) on a hybrid store, driving
a phase-shifting workload — the Fig. 10 pipeline as one assertion-laden
scenario (the per-component tests live in test_core_memos.py etc.)."""
import jax.numpy as jnp
import numpy as np

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.hierarchy import FAST, SLOW
from repro.core.tiers import TierConfig, TierStore


def test_memos_end_to_end_phase_shift():
    n_pages, fast = 64, 16
    store = TierStore(TierConfig(n_pages=n_pages, fast_slots=fast,
                                 slow_slots=n_pages, page_shape=(8,)))
    for p in range(n_pages):
        assert store.allocate(p, SLOW)          # everything starts "on NVM"
        store.write_page(p, np.full(8, p, np.float32))

    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False))
    sm = sysmon.init(n_pages, n_banks=8, n_slabs=4)

    for step in range(48):
        phase = step // 16                      # working set shifts twice
        hot = jnp.arange(phase * 8, phase * 8 + 8)      # WD-hot pages
        warm = jnp.arange(40, 48)                        # RD pages
        sm = sysmon.record(sm, hot, is_write=True)
        sm = sysmon.record(sm, warm, is_write=False)
        sm, _ = mgr.maybe_step(sm)

    tiers = np.asarray(store.tier)
    # final phase's WD-hot pages live in the fast tier
    assert (tiers[16:24] == FAST).all(), tiers[16:24]
    # first phase's long-cold pages drained back to the slow tier
    assert (tiers[0:8] == SLOW).all(), tiers[0:8]
    # capacity never violated and every page still allocated exactly once
    assert (tiers == FAST).sum() <= fast
    # page contents bit-exact after all migrations
    for p in range(n_pages):
        np.testing.assert_array_equal(store.read_page(p),
                                      np.full(8, p, np.float32))
    # migrations actually happened in both directions
    st = mgr.engine.stats
    assert st.to_fast > 0 and st.to_slow > 0
