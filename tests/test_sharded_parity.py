"""Sharding gate: sharded-vs-unsharded numerical parity on a small mesh.

Runs in a subprocess because it needs 8 placeholder XLA devices (the rest
of the suite must see 1 device).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

HELPER = Path(__file__).parent / "helpers" / "sharded_gate.py"


@pytest.mark.slow
def test_sharded_parity_small_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(HELPER)], env=env,
                          capture_output=True, text=True, timeout=1800)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, "sharded parity subprocess failed"
    assert "ALL SHARDED PARITY CHECKS PASSED" in proc.stdout
