"""Parity + invariant suite for the NVM wear & energy telemetry subsystem.

Pins down:
  * bit-exact parity between the Pallas ``wear_update`` kernel (interpret
    mode) and its numpy oracle — the acceptance criterion for the kernel;
  * Start-Gap leveling invariants: the remap stays a permutation, logical
    page contents survive arbitrary rotation, wear spreads across slots;
  * TierStore integration: every slow-tier write (single-page, batched,
    migration demotion) charges exactly one wear count;
  * the energy/lifetime accounting math against hand-computed values;
  * the placement feedback: wear pressure pins WD pages to the fast tier.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import BatchedMigrationEngine, MigrationEngine
from repro.core.hierarchy import FAST, SLOW
from repro.core.placement import BandwidthBalancer, plan, target_tier
from repro.core.tiers import TierConfig, TierStore
from repro.kernels.wear_update import wear_update, wear_update_ref
from repro.nvm import EnergyMeter, NvmWear, StartGapLeveler, init_wear


def make_store(n=24, fast=8, slow=24, quantize=False, shape=(4,), seed=0,
               leveling=True, gap_interval=None):
    s = TierStore(TierConfig(n_pages=n, fast_slots=fast, slow_slots=slow,
                             page_shape=shape, quantize_slow=quantize,
                             wear_leveling=leveling,
                             gap_write_interval=gap_interval))
    rng = np.random.RandomState(seed)
    for p in range(n):
        assert s.allocate(p, SLOW)
        s.write_page(p, rng.standard_normal(shape).astype(np.float32))
    return s


# =============================================================================
# kernel parity: Pallas interpret mode vs numpy oracle, bit-exact
# =============================================================================

@pytest.mark.parametrize("n,k,block", [(64, 7, 128), (512, 300, 128),
                                       (1000, 1, 256), (256, 1024, 512),
                                       (200, 33, 512)])  # clamp stays lane-aligned
def test_wear_update_kernel_parity(n, k, block):
    rng = np.random.RandomState(n + k)
    wear = rng.randint(0, 1000, n).astype(np.int32)
    ids = rng.randint(0, n, k).astype(np.int32)       # duplicates accumulate
    amt = rng.randint(0, 5, k).astype(np.int32)
    ref = wear_update_ref(wear, ids, amt)
    got_interp = np.asarray(wear_update(
        jnp.asarray(wear), jnp.asarray(ids), jnp.asarray(amt),
        block=block, interpret=True))
    got_auto = np.asarray(wear_update(
        jnp.asarray(wear), jnp.asarray(ids), jnp.asarray(amt)))
    np.testing.assert_array_equal(ref, got_interp)    # bit-exact, pinned
    np.testing.assert_array_equal(ref, got_auto)


def test_wear_update_valid_mask_and_default_amount():
    rng = np.random.RandomState(3)
    wear = np.zeros(32, np.int32)
    ids = rng.randint(0, 32, 20).astype(np.int32)
    valid = rng.rand(20) < 0.5
    ref = wear_update_ref(wear, ids[valid])           # amount defaults to 1
    got = np.asarray(wear_update(jnp.asarray(wear), jnp.asarray(ids),
                                 valid=jnp.asarray(valid), interpret=True,
                                 block=128))
    np.testing.assert_array_equal(ref, got)
    # empty event list is a no-op
    np.testing.assert_array_equal(
        np.asarray(wear_update(jnp.asarray(wear), jnp.zeros(0, jnp.int32))),
        wear)


# =============================================================================
# wear state + leveling invariants
# =============================================================================

def test_remap_permutation_and_content_preserved_under_rotation():
    s = make_store(n=16, fast=4, slow=16, gap_interval=3, seed=1)
    rng = np.random.RandomState(1)
    data = {p: s.read_page(p).copy() for p in range(16)}
    for _ in range(300):
        p = int(rng.randint(16))
        data[p] = rng.standard_normal(4).astype(np.float32)
        s.write_page(p, data[p])
    assert s.leveler.stats.rotations >= 1            # pool fully rotated
    s.wear.check()                                   # remap is a permutation
    for p in range(16):
        np.testing.assert_allclose(s.read_page(p), data[p], rtol=1e-6)


def test_quantized_pool_survives_rotation():
    s = make_store(n=12, fast=4, slow=12, quantize=True, gap_interval=2)
    vals = {p: np.full((4,), float(p + 1), np.float32) for p in range(12)}
    for p, v in vals.items():
        s.write_page(p, v)
    for _ in range(60):                              # drive many advances
        s.write_page(3, vals[3])
    s.wear.check()
    for p, v in vals.items():
        np.testing.assert_allclose(s.read_page(p), v, rtol=0.05)


def test_gap_sweep_is_a_rotation():
    """N-1 advances shift every physical row by one (Start-Gap semantics)."""
    wear = NvmWear(6)

    class PoolOnly:
        slow_pool = np.arange(6, dtype=np.float32).reshape(6, 1)
        slow_scale = None

    store = PoolOnly()
    lv = StartGapLeveler(wear, gap_write_interval=1)
    before = store.slow_pool.copy()
    for _ in range(5):                               # one full sweep
        lv.advance(store)
    assert lv.stats.rotations == 1 and lv.stats.gap == 0
    np.testing.assert_array_equal(store.slow_pool, np.roll(before, -1, axis=0))
    # logical view is unchanged: remap follows the data
    np.testing.assert_array_equal(
        store.slow_pool[wear.phys(np.arange(6))], before)
    # each advance physically writes two rows
    assert wear.leveling_writes == 10
    assert wear.wear_counts().sum() == 10


def test_leveling_spreads_wear():
    """A single write-hot logical slot must not pin a single physical slot."""
    hot = make_store(n=8, fast=4, slow=8, gap_interval=4, seed=2)
    cold = make_store(n=8, fast=4, slow=8, leveling=False, seed=2)
    v = np.ones(4, np.float32)
    for _ in range(200):
        hot.write_page(0, v)
        cold.write_page(0, v)
    assert cold.wear.max_wear() >= 200               # all on one slot
    assert hot.wear.max_wear() < cold.wear.max_wear() / 2
    assert (hot.wear.wear_counts() > 0).sum() == 8   # every slot took a share


def test_every_slow_write_path_charges_wear():
    s = make_store(n=16, fast=8, slow=16, leveling=False)
    base = s.wear.writes_total                       # 16 setup writes
    assert base == 16
    s.write_page(2, np.zeros(4, np.float32))         # single-page path
    assert s.wear.writes_total == base + 1
    s.slow_write_batch(np.arange(4), np.zeros((4, 4), np.float32))
    assert s.wear.writes_total == base + 5
    # fast-tier writes must NOT consume NVM endurance
    eng = BatchedMigrationEngine(s)
    eng.migrate_locked([0, 1], FAST)
    s.write_page(0, np.ones(4, np.float32))
    assert s.wear.writes_total == base + 5
    # demotion commits are slow writes -> charged
    eng.migrate_optimistic([0, 1], SLOW)
    assert s.wear.writes_total == base + 7
    # device counters (flushed through the wear_update kernel) agree with
    # the host totals
    assert s.wear.wear_counts().sum() == \
        s.wear.writes_total + s.wear.leveling_writes


def test_wear_tracking_disabled():
    s = TierStore(TierConfig(n_pages=4, fast_slots=2, slow_slots=4,
                             page_shape=(2,), track_wear=False))
    assert s.wear is None and s.leveler is None
    assert s.allocate(0, SLOW)
    s.write_page(0, np.zeros(2, np.float32))         # no tracker, no crash
    np.testing.assert_array_equal(s.read_page(0), np.zeros(2))


# =============================================================================
# engine parity with wear tracking + leveling enabled
# =============================================================================

@pytest.mark.parametrize("quantize", [False, True])
def test_migration_parity_with_leveling_active(quantize):
    """Both engines see identical logical state even while Start-Gap
    rotation reshuffles the physical pool underneath them."""
    ref_s = make_store(quantize=quantize, gap_interval=2, seed=4)
    bat_s = make_store(quantize=quantize, gap_interval=2, seed=4)
    ref, bat = MigrationEngine(ref_s), BatchedMigrationEngine(bat_s)
    rng = np.random.RandomState(5)
    for _ in range(8):
        pages = rng.choice(24, size=rng.randint(1, 10), replace=False)
        dst = FAST if rng.rand() < 0.5 else SLOW
        st_r = ref.migrate_locked(pages, dst)
        st_b = bat.migrate_locked(pages, dst)
        assert st_r.migrated == st_b.migrated
        np.testing.assert_array_equal(ref_s.tier, bat_s.tier)
        np.testing.assert_array_equal(ref_s.slot, bat_s.slot)
        for p in range(24):
            np.testing.assert_array_equal(ref_s.read_page(p),
                                          bat_s.read_page(p))
    ref_s.wear.check()
    bat_s.wear.check()
    # both engines consumed identical endurance (same page-write totals)
    assert ref_s.wear.writes_total == bat_s.wear.writes_total


# =============================================================================
# energy / lifetime accounting
# =============================================================================

def test_energy_report_math():
    s = make_store(n=8, fast=4, slow=8, leveling=False)
    meter = EnergyMeter(s, window_s=2.0)
    s.write_page(0, np.zeros(4, np.float32))
    s.write_page(0, np.zeros(4, np.float32))
    s.read_page(1)
    r = meter.end_pass()
    assert (r.slow_writes, r.slow_reads, r.leveling_writes) == (2, 1, 0)
    page_b = s.page_nbytes
    exp_w = 2 * cm.page_access_energy_nj(cm.NVM, page_b, True) * 1e-6
    exp_r = 1 * cm.page_access_energy_nj(cm.NVM, page_b, False) * 1e-6
    assert r.write_energy_mj == pytest.approx(exp_w)
    assert r.read_energy_mj == pytest.approx(exp_r)
    assert r.dynamic_power_mw == pytest.approx((exp_w + exp_r) / 2.0)
    assert r.standby_w == pytest.approx(
        cm.standby_power_w(r.capacity_gb, cm.NVM))
    # lifetime: max wear = 3 writes on slot of page 0 (setup + 2) over 2 s
    assert r.wear_max == 3
    assert r.lifetime_years_actual == pytest.approx(
        cm.lifetime_years_from_wear(3, 2.0))
    # second pass sees only the delta
    s.write_page(2, np.zeros(4, np.float32))
    r2 = meter.end_pass()
    assert (r2.slow_writes, r2.slow_reads) == (1, 0)
    assert r2.passes == 2
    d = r2.to_dict()
    assert d["slow_writes"] == 1 and isinstance(d["wear_imbalance"], float)


def test_lifetime_helpers():
    assert cm.lifetime_years_from_wear(0, 10.0) == float("inf")
    assert cm.lifetime_years_from_wear(100, 0.0) == float("inf")
    y = cm.lifetime_years_from_wear(cm.NVM.endurance, cm.SECONDS_PER_YEAR)
    assert y == pytest.approx(1.0)
    assert cm.startgap_interval() == 19              # 95% -> 19 writes/move
    assert cm.startgap_interval(0.5) == 1


# =============================================================================
# placement feedback: wear pressure pins WD pages to the fast tier
# =============================================================================

def test_target_tier_wear_penalty():
    wd = np.array([2, 1, 0, 2], np.int8)     # WD, RD, COLD, WD
    hot = np.zeros(4, bool)
    future = np.zeros(4, np.int8)            # UN_WD everywhere
    reuse = np.zeros(4, np.int8)
    base = target_tier(wd, hot, future, reuse)
    np.testing.assert_array_equal(base, [SLOW] * 4)
    under = target_tier(wd, hot, future, reuse, wear_penalty=1.0)
    np.testing.assert_array_equal(under, [FAST, SLOW, SLOW, FAST])


def test_plan_wear_penalty_ranks_wd_first():
    class Summary:
        wd_code = np.array([1, 2, 1, 2], np.int8)    # RD, WD, RD, WD
        hot = np.ones(4, bool)
        hotness = np.array([5.0, 1.0, 4.0, 1.5], np.float32)
        future = np.zeros(4, np.int8)
        reuse_class = np.zeros(4, np.int8)

    current = np.full(4, SLOW, np.int8)
    d0 = plan(Summary, current)
    assert list(d0.hotness_list) == [0, 2, 3, 1]     # plain hotness order
    d1 = plan(Summary, current, wear_penalty=10.0)
    assert list(d1.hotness_list)[:2] == [3, 1]       # WD pages boosted first


def test_spill_excludes_wd_under_pressure():
    b = BandwidthBalancer(0.9)
    wd_code = np.array([2, 1, 2, 1], np.int8)
    hotness = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    tier = np.full(4, FAST, np.int8)
    normal = b.spill_candidates(wd_code, hotness, tier, n=4)
    assert set(normal.tolist()) == {0, 1, 2, 3}
    pressured = b.spill_candidates(wd_code, hotness, tier, n=4,
                                   exclude_wd=True)
    assert set(pressured.tolist()) == {1, 3}         # RD only


def test_memos_wear_pressure_promotes_first_time_wd_pages():
    """End to end: a first-time WD page (no history, not hot) stays on NVM
    without feedback and is pinned to the fast tier under wear pressure."""

    def run(horizon):
        s = make_store(n=32, fast=16, slow=32, leveling=False, seed=7)
        mgr = MemosManager(s, MemosConfig(
            interval=4, adaptive_interval=False,
            lifetime_horizon_years=horizon))
        sm = sysmon.init(32, s.cfg.n_banks, s.cfg.n_slabs)
        for step in range(8):
            sm = sysmon.record(sm, jnp.asarray([20]), is_write=False)
            if step == 0:        # pass 1: background write so wear rate > 0
                sm = sysmon.record(sm, jnp.asarray([10]), is_write=True)
                s.write_page(10, np.ones(4, np.float32))
            if step == 4:        # pass 2: fresh WD pages, single touch each
                sm = sysmon.record(sm, jnp.asarray([0, 1, 2, 3]),
                                   is_write=True)
            sm, rep = mgr.maybe_step(sm)
        return s, mgr

    s_off, m_off = run(None)
    assert (s_off.tier[:4] == SLOW).all()
    assert not any(r.wear_pressure for r in m_off.reports)
    s_on, m_on = run(1e12)
    assert (s_on.tier[:4] == FAST).all()
    assert m_on.reports[-1].wear_pressure
    # telemetry rides along on every report when wear is tracked
    assert all(r.nvm is not None for r in m_on.reports)
    assert m_on.reports[-1].nvm.passes == len(m_on.reports)


def test_adaptive_interval_scales_telemetry_window():
    """With adaptive interval growth, the per-pass accounting window must
    stretch with the pass's actual step span so a constant write rate does
    not read as inflated wear pressure."""
    s = make_store(n=16, fast=8, slow=16, leveling=False, seed=9)
    mgr = MemosManager(s, MemosConfig(interval=2, adaptive_interval=True,
                                      interval_growth=2.0, interval_max=16))
    sm = sysmon.init(16, s.cfg.n_banks, s.cfg.n_slabs)
    for _ in range(64):
        sm = sysmon.record(sm, jnp.asarray([0]), is_write=True)
        sm, _ = mgr.maybe_step(sm)
    windows = [r.nvm.window_s for r in mgr.reports]
    # windows track the growing interval (2 steps = 1.0 notional second)
    assert windows[0] == pytest.approx(1.0)
    assert windows[-1] > windows[0]
    steps = [r.step for r in mgr.reports]
    spans = np.diff([0] + steps)
    np.testing.assert_allclose(windows, spans / 2.0)
    assert mgr.meter.elapsed == pytest.approx(sum(windows))
