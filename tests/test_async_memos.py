"""Asynchronous memos pipeline: snapshot -> plan (worker) -> commit.

The overlapped pipeline must be *bit-identical* to the synchronous pass:
a clean commit replays the exact Algorithm-2 reservations the plan
simulated on its cloned allocators, and a conflicted commit (pages
dirtied mid-plan, detected through the optimistic-migration version
counters) degrades to the synchronous path.  Driven directly against a
TierStore so nothing else mutates state between boundaries — every
observable array (page table, pool contents, wear counters, traffic,
per-pass stats) is compared bit for bit.  Also pins the exact
token-granular interval accounting of ``maybe_step``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import StoreView, plan_locked, replay_reservations
from repro.core.tiers import TierConfig, TierStore


def make_store(seed=0):
    store = TierStore(TierConfig(
        n_pages=32, fast_slots=8, slow_slots=32, page_shape=(4,),
        dtype=jnp.float32, n_banks=2, n_slabs=4, gap_write_interval=5))
    rng = np.random.RandomState(seed)
    for p in range(32):
        assert store.allocate(p, int(store.tier[p]))
        store.write_page(p, rng.standard_normal(4).astype(np.float32))
    return store


def drive(mgr, n_steps=24, mid_plan_hook=None, bump_after_pass=None):
    """Golden-style scenario: phased hot sets forcing promotions and
    demotions, no data writes between boundaries (so every byte of state
    is comparable).  ``mid_plan_hook`` installs the async conflict
    injector; ``bump_after_pass`` replays the injector's version bumps
    into the synchronous oracle at the equivalent point."""
    if mid_plan_hook is not None:
        mgr._mid_plan_hook = mid_plan_hook
    sm = sysmon.init(32, mgr.store.cfg.n_banks, mgr.store.cfg.n_slabs)
    rng = np.random.RandomState(7)
    for step in range(n_steps):
        phase = step // 8
        hot = np.arange(phase * 6, phase * 6 + 6)
        warm = rng.randint(20, 32, size=3)
        sm = sysmon.record(sm, jnp.asarray(hot, jnp.int32), is_write=True)
        sm = sysmon.record(sm, jnp.asarray(warm, jnp.int32), is_write=False)
        n_before = len(mgr.reports)
        sm, rep = mgr.maybe_step(sm)
        if rep is not None and bump_after_pass is not None:
            bump_after_pass(mgr, n_before)
    mgr.flush()
    return sm


def collect(store, mgr):
    return {
        "tier": store.tier.copy(),
        "slot": store.slot.copy(),
        "version": store.version.copy(),
        "fast_pool": np.asarray(store.fast_pool, np.float32),
        "slow_pool": store.slow_pool.copy(),
        "pages": np.stack([store.read_page(p) for p in range(32)]),
        "wear": store.wear.wear_counts(),
        "remap": store.wear._remap.copy(),
        "writes_total": np.int64(store.wear.writes_total),
        "leveling": np.int64(store.wear.leveling_writes),
        "migrated": np.asarray([r.migrations.migrated for r in mgr.reports]),
        "to_fast": np.asarray([r.migrations.to_fast for r in mgr.reports]),
        "to_slow": np.asarray([r.migrations.to_slow for r in mgr.reports]),
        "n_marked": np.asarray([r.n_marked for r in mgr.reports]),
    }


def assert_identical(sync_state, async_state):
    for key in sync_state:
        np.testing.assert_array_equal(
            sync_state[key], async_state[key],
            err_msg=f"async pipeline diverged from the synchronous "
                    f"path at {key!r}")


def cfg(async_plan):
    return MemosConfig(interval=4, adaptive_interval=False,
                       async_plan=async_plan)


def test_async_clean_commit_bit_identical_to_sync():
    """No mid-plan interference: every pass commits through the
    overlapped path and the final state matches the synchronous run bit
    for bit (replayed reservations land every page in the same slot)."""
    s_store, a_store = make_store(), make_store()
    s_mgr = MemosManager(s_store, cfg(False))
    a_mgr = MemosManager(a_store, cfg(True))
    drive(s_mgr)
    drive(a_mgr)
    assert a_mgr.plan_commits > 0 and a_mgr.plan_conflicts == 0
    assert len(s_mgr.reports) == len(a_mgr.reports) > 0
    assert any(r.migrations.migrated for r in a_mgr.reports)
    assert all(r.committed_async for r in a_mgr.reports)
    assert_identical(collect(s_store, s_mgr), collect(a_store, a_mgr))
    for t in range(a_store.n_tiers):
        a_store.alloc[t].check_consistency()


def test_async_forced_mid_plan_dirtying_degrades_bit_identical():
    """Every pass gets a page dirtied mid-plan (version bump through the
    optimistic-migration counters): the commit must detect the conflict,
    degrade to the synchronous path, and still end bit-identical to a
    synchronous run with the same bumps applied after each pass."""
    a_store = make_store()
    a_mgr = MemosManager(a_store, cfg(True))
    bumped = {}                       # pass ordinal -> dirtied page

    def dirty_first_planned(mgr, decision, plans):
        for pl in plans:
            if len(pl):
                p = int(pl.pages[0])
                bumped[len(mgr.reports)] = p
                mgr.store.version[p] += 1   # a write landing mid-plan
                return

    drive(a_mgr, mid_plan_hook=dirty_first_planned)
    assert a_mgr.plan_conflicts > 0, "scenario never exercised a conflict"
    assert a_mgr.plan_conflicts == len(bumped)
    assert any(r.plan_conflict for r in a_mgr.reports)

    s_store = make_store()
    s_mgr = MemosManager(s_store, cfg(False))

    def replay_bump(mgr, pass_ordinal):
        p = bumped.get(pass_ordinal)
        if p is not None:
            mgr.store.version[p] += 1

    drive(s_mgr, bump_after_pass=replay_bump)
    assert len(s_mgr.reports) == len(a_mgr.reports)
    assert_identical(collect(s_store, s_mgr), collect(a_store, a_mgr))


def test_replay_divergence_rolls_back_and_degrades():
    """An interleaved allocation that steals a planned block makes the
    reservation replay diverge: the commit rolls every replayed slot
    back (allocator invariants intact) and degrades to the synchronous
    path — migrations still happen, nothing leaks."""
    store = make_store()
    mgr = MemosManager(store, cfg(True))
    stolen = []

    def steal_a_slot(m, decision, plans):
        # emulate a new_page allocation landing in the plan's destination
        # tier mid-dispatch: the replay can no longer land the same slots
        for pl in plans:
            if len(pl):
                s = m.store.alloc[pl.dst_tier].alloc(0, None)
                if s is not None:
                    stolen.append((pl.dst_tier, s))
                return

    drive(mgr, mid_plan_hook=steal_a_slot)
    assert stolen, "hook never fired"
    assert mgr.plan_conflicts > 0
    for t in range(store.n_tiers):
        store.alloc[t].check_consistency()
    # the degraded passes still migrated pages around the stolen slots
    assert any(r.migrations.migrated for r in mgr.reports)
    live = store.slot != -1
    tiers, slots = store.tier[live], store.slot[live]
    for t in np.unique(tiers):
        ss = slots[tiers == t]
        assert len(set(ss.tolist())) == ss.size, "slot double-booked"


def test_replay_reservations_exactness():
    """Unit: a plan simulated on a StoreView replays onto the live store
    landing identical slots; replay after an interfering allocation
    reports divergence and restores the free count."""
    store = make_store()
    view = StoreView(store)
    plan = plan_locked(view, range(6), 0,
                       bank_freq=np.ones(2), slab_freq=np.ones(4))
    assert len(plan) == 6
    n_free = store.alloc[0].n_free
    assert replay_reservations(store, [plan])
    assert store.alloc[0].n_free == n_free - 6
    # a second replay of the same plan must diverge (slots now taken)
    assert not replay_reservations(store, [plan])
    assert store.alloc[0].n_free == n_free - 6     # rollback exact
    store.alloc[0].check_consistency()


# =============================================================================
# maybe_step interval accounting (the double-count bugfix)
# =============================================================================

def passes_after(steps_seq, interval=4):
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=interval,
                                          adaptive_interval=False))
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    counts = []
    for k in steps_seq:
        sm = sysmon.record(sm, jnp.asarray([0, 1], jnp.int32), is_write=True)
        sm, _ = mgr.maybe_step(sm, steps=k)
        counts.append(len(mgr.reports))
    return counts


def test_interval_accounting_exact_over_shrunken_dispatches():
    """A dispatch spanning more than one interval banks its overshoot:
    the skipped pass fires at the next boundary (even a 1-token one, the
    min-remaining-steps shrinkage near sequence ends) instead of pushing
    a full interval out — pass count tracks floor(tokens / interval)."""
    # 8 tokens at once (K = 2 x interval), then 1-token tail dispatches
    assert passes_after([8, 1, 1, 2]) == [1, 2, 2, 3]
    # the old remainder-modulo accounting lost the banked interval:
    # 8 % 4 = 0 -> the second pass needed 4 *more* tokens (fired at 12)


def test_interval_accounting_exact_at_boundaries():
    # plain cadence is untouched
    assert passes_after([4, 4, 4]) == [1, 2, 3]
    assert passes_after([2, 2, 2, 2]) == [0, 1, 1, 2]
    # credit is capped at one interval: a giant dispatch banks at most
    # one catch-up pass — it cannot force a pass at every boundary
    # forever after
    assert passes_after([16, 1, 1, 1]) == [1, 2, 2, 2]
