"""Asynchronous memos pipeline: snapshot -> plan (worker) -> commit.

The overlapped pipeline must be *bit-identical* to the synchronous pass
when nothing interferes: a clean commit lands the exact Algorithm-2
reservations the plan simulated on its cloned allocators (adopting the
clone wholesale when the destination tier saw no interleaved allocator
call).  Commits are **page-granular**: a page dirtied mid-plan — seen
through the store's incremental dirty-page epoch, not an array replay —
degrades alone while every other planned page still commits into exactly
the slot the synchronous pass would have picked.  Driven directly
against a TierStore so nothing else mutates state between boundaries —
every observable array (page table, pool contents, wear counters,
traffic, per-pass stats) is compared bit for bit.  Also pins the exact
token-granular interval accounting of ``maybe_step``."""
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import (StoreView, commit_reservations,
                                  plan_locked)
from repro.core.tiers import NO_SLOT, TierConfig, TierStore
from repro.faults import RUNG_OVERLAP, RUNG_SYNC


def make_store(seed=0):
    store = TierStore(TierConfig(
        n_pages=32, fast_slots=8, slow_slots=32, page_shape=(4,),
        dtype=jnp.float32, n_banks=2, n_slabs=4, gap_write_interval=5))
    rng = np.random.RandomState(seed)
    for p in range(32):
        assert store.allocate(p, int(store.tier[p]))
        store.write_page(p, rng.standard_normal(4).astype(np.float32))
    return store


def drive(mgr, n_steps=24, mid_plan_hook=None, bump_after_pass=None):
    """Golden-style scenario: phased hot sets forcing promotions and
    demotions, no data writes between boundaries (so every byte of state
    is comparable).  ``mid_plan_hook`` installs the async conflict
    injector; ``bump_after_pass`` replays the injector's version bumps
    into the synchronous oracle at the equivalent point."""
    if mid_plan_hook is not None:
        mgr._mid_plan_hook = mid_plan_hook
    sm = sysmon.init(32, mgr.store.cfg.n_banks, mgr.store.cfg.n_slabs)
    rng = np.random.RandomState(7)
    for step in range(n_steps):
        phase = step // 8
        hot = np.arange(phase * 6, phase * 6 + 6)
        warm = rng.randint(20, 32, size=3)
        sm = sysmon.record(sm, jnp.asarray(hot, jnp.int32), is_write=True)
        sm = sysmon.record(sm, jnp.asarray(warm, jnp.int32), is_write=False)
        n_before = len(mgr.reports)
        sm, rep = mgr.maybe_step(sm)
        if rep is not None and bump_after_pass is not None:
            bump_after_pass(mgr, n_before)
    mgr.flush()
    return sm


def collect(store, mgr):
    return {
        "tier": store.tier.copy(),
        "slot": store.slot.copy(),
        "version": store.version.copy(),
        "fast_pool": np.asarray(store.fast_pool, np.float32),
        "slow_pool": store.slow_pool.copy(),
        "pages": np.stack([store.read_page(p) for p in range(32)]),
        "wear": store.wear.wear_counts(),
        "remap": store.wear._remap.copy(),
        "writes_total": np.int64(store.wear.writes_total),
        "leveling": np.int64(store.wear.leveling_writes),
        "migrated": np.asarray([r.migrations.migrated for r in mgr.reports]),
        "to_fast": np.asarray([r.migrations.to_fast for r in mgr.reports]),
        "to_slow": np.asarray([r.migrations.to_slow for r in mgr.reports]),
        "n_marked": np.asarray([r.n_marked for r in mgr.reports]),
    }


def assert_identical(sync_state, async_state):
    for key in sync_state:
        np.testing.assert_array_equal(
            sync_state[key], async_state[key],
            err_msg=f"async pipeline diverged from the synchronous "
                    f"path at {key!r}")


def assert_no_double_booking(store):
    live = store.slot != NO_SLOT
    tiers, slots = store.tier[live], store.slot[live]
    for t in np.unique(tiers):
        ss = slots[tiers == t]
        assert len(set(ss.tolist())) == ss.size, "slot double-booked"


def cfg(async_plan):
    return MemosConfig(interval=4, adaptive_interval=False,
                       async_plan=async_plan)


def test_async_clean_commit_bit_identical_to_sync():
    """No mid-plan interference: every pass commits through the
    overlapped path and the final state matches the synchronous run bit
    for bit (adopted/replayed reservations land every page in the same
    slot), with zero pages degraded."""
    s_store, a_store = make_store(), make_store()
    s_mgr = MemosManager(s_store, cfg(False))
    a_mgr = MemosManager(a_store, cfg(True))
    drive(s_mgr)
    drive(a_mgr)
    assert a_mgr.pages_committed > 0 and a_mgr.pages_degraded == 0
    assert len(s_mgr.reports) == len(a_mgr.reports) > 0
    assert any(r.migrations.migrated for r in a_mgr.reports)
    assert all(r.committed_async for r in a_mgr.reports)
    assert not any(r.plan_conflict for r in a_mgr.reports)
    assert all(r.pages_degraded == 0 for r in a_mgr.reports)
    assert_identical(collect(s_store, s_mgr), collect(a_store, a_mgr))
    for t in range(a_store.n_tiers):
        a_store.alloc[t].check_consistency()


def one_pass(async_plan, hook=None):
    """Two explicit passes over a fixed access pattern: pass 1 builds
    classification history (commits clean), pass 2 — the probed pass,
    which actually migrates — gets the mid-plan hook installed just
    before its commit.  Returns pass 2's report."""
    store = make_store()
    mgr = MemosManager(store, cfg(async_plan))
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    rng = np.random.RandomState(7)

    def record4(sm):
        for _ in range(4):
            hot = np.arange(6)
            warm = rng.randint(20, 32, size=3)
            sm = sysmon.record(sm, jnp.asarray(hot, jnp.int32),
                               is_write=True)
            sm = sysmon.record(sm, jnp.asarray(warm, jnp.int32),
                               is_write=False)
        return sm

    sm = record4(sm)
    if async_plan:
        sm = mgr.begin_pass(sm)
        mgr.commit_pending()
        sm = record4(sm)
        sm = mgr.begin_pass(sm)
        mgr._mid_plan_hook = hook
        rep = mgr.commit_pending()
    else:
        sm, _ = mgr.run_pass(sm)
        sm = record4(sm)
        sm, rep = mgr.run_pass(sm)
    return store, mgr, rep


def test_single_page_dirtying_commits_remainder():
    """Exactly one planned page dirtied mid-plan: that page degrades
    (stays in its snapshot tier/slot, picked up by the next pass) while
    *every other* planned page commits into exactly the tier/slot the
    synchronous pass lands it in."""
    seen = {}

    def dirty_one(m, decision, plans):
        pl = next(p for p in plans if len(p))
        seen["page"] = int(pl.pages[0])
        seen["tier"] = int(m.store.tier[seen["page"]])
        seen["slot"] = int(m.store.slot[seen["page"]])
        seen["planned"] = [int(p) for q in plans for p in q.pages]
        m.store.bump_version(seen["page"])   # a write landing mid-plan

    a_store, a_mgr, a_rep = one_pass(True, hook=dirty_one)
    s_store, s_mgr, s_rep = one_pass(False)

    p = seen["page"]
    assert a_rep.committed_async and a_rep.plan_conflict
    assert a_rep.pages_degraded == 1
    assert a_rep.pages_committed == len(seen["planned"]) - 1
    # the dirtied page did not move
    assert int(a_store.tier[p]) == seen["tier"]
    assert int(a_store.slot[p]) == seen["slot"]
    # every other planned page landed exactly where the sync pass put it
    for q in seen["planned"]:
        if q == p:
            continue
        assert int(a_store.tier[q]) == int(s_store.tier[q]), \
            f"page {q} committed into the wrong tier"
        assert int(a_store.slot[q]) == int(s_store.slot[q]), \
            f"page {q} committed into the wrong slot"
    for t in range(a_store.n_tiers):
        a_store.alloc[t].check_consistency()
    assert_no_double_booking(a_store)


def test_freed_mid_plan_page_drops_without_conflict():
    """A planned page *released* mid-plan (a sequence retiring at the
    overlapped dispatch boundary) is dropped — its plan entry is void,
    not deferred work — while every other planned page still commits.
    No conflict is charged: ``pages_degraded`` stays 0 and the report
    does not flag ``plan_conflict``."""
    seen = {}

    def free_one(m, decision, plans):
        pl = next(p for p in plans if len(p))
        seen["page"] = int(pl.pages[0])
        seen["planned"] = [int(p) for q in plans for p in q.pages]
        m.store.release(seen["page"])   # retirement landing mid-plan

    store, mgr, rep = one_pass(True, hook=free_one)

    p = seen["page"]
    assert rep.committed_async
    assert rep.pages_dropped == 1
    assert rep.pages_degraded == 0 and not rep.plan_conflict
    assert rep.pages_committed == len(seen["planned"]) - 1
    assert mgr.pages_dropped == 1
    # the freed page stayed free — the stale plan didn't resurrect it
    assert int(store.slot[p]) == NO_SLOT
    # its reservation was returned: allocators stay consistent
    for t in range(store.n_tiers):
        store.alloc[t].check_consistency()
    assert_no_double_booking(store)


def test_forced_mid_plan_dirtying_every_pass():
    """Every pass gets one planned page dirtied mid-plan (version bump
    through the store, as a real write would): each commit degrades
    exactly that page, commits the remainder, and the store stays
    consistent across the whole run — no whole-plan discard, no
    synchronous re-plan."""
    a_store = make_store()
    a_mgr = MemosManager(a_store, cfg(True))
    bumped = {}                       # pass ordinal -> dirtied page

    def dirty_first_planned(mgr, decision, plans):
        for pl in plans:
            if len(pl):
                p = int(pl.pages[0])
                bumped[len(mgr.reports)] = p
                mgr.store.bump_version(p)   # a write landing mid-plan
                return

    drive(a_mgr, mid_plan_hook=dirty_first_planned)
    assert bumped, "scenario never planned anything"
    assert a_mgr.pages_degraded == len(bumped)
    assert a_mgr.pages_committed > 0
    assert all(r.committed_async for r in a_mgr.reports)
    conflicted = [r for r in a_mgr.reports if r.plan_conflict]
    assert len(conflicted) == len(bumped)
    assert all(r.pages_degraded == 1 for r in conflicted)
    # the degraded page still committed its siblings that pass
    assert any(r.pages_committed > 0 for r in conflicted)
    for t in range(a_store.n_tiers):
        a_store.alloc[t].check_consistency()
    assert_no_double_booking(a_store)


def test_replay_divergence_commits_alternate_slots():
    """An interleaved allocation that steals a planned block must NOT
    degrade the plan's clean pages: the replay patches each reservation
    to the slot the live allocator actually hands out (what a
    synchronous pass at this boundary would take) and every page still
    commits — allocator invariants intact, no slot double-booked, no
    page leaked."""
    store = make_store()
    mgr = MemosManager(store, cfg(True))
    stolen = []

    def steal_a_slot(m, decision, plans):
        # emulate a new_page allocation landing in the plan's destination
        # tier mid-dispatch: the replay can no longer land the same
        # slots.  Steal once — the slot is never freed, and leaking one
        # per pass would starve the 8-slot fast tier into genuine
        # capacity degrades, which is not what this test is about.
        if stolen:
            return
        for pl in plans:
            if len(pl):
                s = m.store.alloc[pl.dst_tier].alloc(0, None)
                if s is not None:
                    stolen.append((pl.dst_tier, s))
                return

    drive(mgr, mid_plan_hook=steal_a_slot)
    assert stolen, "hook never fired"
    # slot interference alone is not a conflict under page-granular
    # commits — nothing was dirtied, so nothing degrades
    assert mgr.pages_degraded == 0
    assert mgr.pages_committed > 0
    assert all(r.committed_async for r in mgr.reports)
    for t in range(store.n_tiers):
        store.alloc[t].check_consistency()
    assert any(r.migrations.migrated for r in mgr.reports)
    # the stolen slots are still held by the interloper: no plan may
    # have committed a page onto them
    live = store.slot != NO_SLOT
    for t, s in stolen:
        assert not ((store.tier[live] == t) & (store.slot[live] == s)).any()
    assert_no_double_booking(store)


def test_commit_reservations_exactness():
    """Unit: a plan simulated on a StoreView lands on the live store —
    O(1) clone adoption with *identical* slots when no allocator call
    interleaved; per-call replay patched to the live allocator's slots
    when one did (every reservation still lands, none double-booked)."""
    # quiet tier: generation unchanged -> clone adoption, exact slots
    store = make_store()
    view = StoreView(store)
    plan = plan_locked(view, range(6), 0,
                       bank_freq=np.ones(2), slab_freq=np.ones(4))
    assert len(plan) == 6
    planned_slots = plan.dst_slots.copy()
    n_free = store.alloc[0].n_free
    (ok,) = commit_reservations(store, view, [plan])
    assert ok.all()
    np.testing.assert_array_equal(plan.dst_slots, planned_slots)
    assert store.alloc[0].n_free == n_free - 6
    store.end_dirty_epoch()
    store.alloc[0].check_consistency()

    # interfering allocation: generation advanced -> replay; the
    # interloper sits exactly on the plan's first simulated slot, so the
    # replay must patch that reservation to a different live slot —
    # every page still lands, and no slot is handed out twice
    store2 = make_store()
    view2 = StoreView(store2)
    plan2 = plan_locked(view2, range(6), 0,
                        bank_freq=np.ones(2), slab_freq=np.ones(4))
    planned2 = plan2.dst_slots.copy()
    n_free2 = store2.alloc[0].n_free
    c, m = int(plan2.colors[0]), int(plan2.masks[0])
    s = store2.alloc[0].alloc(0, None if c < 0 else c,
                              None if m < 0 else m)
    assert s == int(planned2[0]), "interloper must steal slot 0"
    (ok2,) = commit_reservations(store2, view2, [plan2])
    assert ok2.all(), "interference must not drop clean reservations"
    got = plan2.dst_slots.tolist()
    assert s not in got, "patched plan still points at the stolen slot"
    assert len(set(got)) == len(got), "replay double-booked a slot"
    assert store2.alloc[0].n_free == n_free2 - 7   # interloper + 6 pages
    store2.end_dirty_epoch()
    store2.alloc[0].check_consistency()


# =============================================================================
# dirty-epoch soundness (the near-zero-cost validator)
# =============================================================================

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_dirty_epoch_never_misses_a_change(seed):
    """Property: over a random stream of store mutations (writes, version
    bumps, dispatch charges, migrations, alloc/release), the dirty set
    returned by ``end_dirty_epoch`` never misses a plan-invalidating
    change: every external version bump (``write_page``/``bump_version``)
    and every placement change (tier/slot) must be in the set — a miss
    would commit a stale page.  Dispatch access charges bump versions
    too, but are in-place by contract and must NOT dirty the epoch (a
    false positive there silently re-serializes the async pipeline)."""
    store = make_store(seed)
    rng = np.random.RandomState(100 + seed)
    view = StoreView(store)          # opens the epoch, like begin_pass
    external = set()                 # pages written outside a dispatch
    charged = np.zeros(32, np.int64)
    for _ in range(60):
        op = rng.randint(5)
        p = int(rng.randint(32))
        if op == 0:
            if int(store.slot[p]) != NO_SLOT:
                store.write_page(
                    p, rng.standard_normal(4).astype(np.float32))
                external.add(p)
        elif op == 1:
            store.bump_version(p)
            external.add(p)
        elif op == 2:
            # a fused-dispatch boundary charge over random tail pages
            pw = np.zeros(32, np.int64)
            pw[rng.randint(0, 32, size=3)] += 1
            store.charge_fast_accesses(pw, n_reads=4)
            charged += pw
        elif op == 3:
            if int(store.slot[p]) != NO_SLOT:
                dst = int(rng.randint(store.n_tiers))
                if int(store.tier[p]) != dst:
                    store.move_page(p, dst)
        else:
            if int(store.slot[p]) != NO_SLOT:
                store.release(p)
            else:
                store.allocate(p, int(rng.randint(store.n_tiers)))
    dirty = store.end_dirty_epoch()
    moved = set(np.nonzero((store.tier != view.tier)
                           | (store.slot != view.slot))[0].tolist())
    missed = (external | moved) - dirty
    assert not missed, f"dirty epoch missed changed pages {sorted(missed)}"
    # every version delta is accounted for: external bumps + charges —
    # and pages only charged (never written/moved) stayed clean
    only_charged = {int(p) for p in np.nonzero(charged)[0]} \
        - external - moved
    false_pos = only_charged & dirty
    assert not false_pos, \
        f"in-place dispatch charges dirtied pages {sorted(false_pos)}"


# =============================================================================
# worker death -> watchdog fallback -> breaker re-promotion
# =============================================================================

def test_worker_death_degrades_to_sync_then_reenables_overlap():
    """Kill the plan worker mid-flight (executor shut down, future
    resolving to an error — the process-level analogue of a worker
    thread dying): the commit must not deadlock — the watchdog falls
    back to a synchronous pass against live state, the degradation
    ladder demotes to sync, and after the breaker's healthy streak the
    pipeline re-promotes, lazily respawning a fresh executor and
    committing overlapped passes again.  Store stays consistent
    throughout."""
    store = make_store()
    mgr = MemosManager(store, MemosConfig(
        interval=4, adaptive_interval=False, async_plan=True,
        breaker_recovery_passes=2))
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    rng = np.random.RandomState(7)

    def record4(sm):
        for _ in range(4):
            sm = sysmon.record(sm, jnp.asarray(np.arange(6), jnp.int32),
                               is_write=True)
            sm = sysmon.record(sm, jnp.asarray(rng.randint(20, 32, 3),
                                               jnp.int32), is_write=False)
        return sm

    # pass 1: begin the overlapped pass, then the worker dies
    sm = record4(sm)
    sm = mgr.begin_pass(sm)
    assert mgr._executor is not None
    mgr._executor.shutdown(wait=True)           # executor gone
    dead: Future = Future()
    dead.set_exception(RuntimeError("plan worker died"))
    mgr._ticket.future = dead
    rep = mgr.commit_pending()                  # must return, not hang
    assert rep is not None and rep.fault_fallback == "RuntimeError"
    assert not rep.committed_async
    assert mgr.ladder.rung == RUNG_SYNC
    assert mgr._executor is None and mgr._ticket is None
    store.end_dirty_epoch()                     # no epoch left open
    for t in range(store.n_tiers):
        store.alloc[t].check_consistency()
    assert_no_double_booking(store)

    # passes 2-3: the rung dispatches synchronously and heals the streak
    for _ in range(2):
        sm = record4(sm)
        sm, rep = mgr.maybe_step(sm, steps=4)
        assert mgr._ticket is None              # no overlap while demoted
    assert mgr.ladder.rung == RUNG_OVERLAP

    # pass 4: overlap re-enabled — a fresh executor spawns, the pass
    # commits through the async path with no fault residue
    sm = record4(sm)
    sm, _ = mgr.maybe_step(sm, steps=4)
    assert mgr._ticket is not None and mgr._executor is not None
    rep = mgr.flush()
    assert rep is not None and rep.committed_async
    assert rep.fault_fallback is None
    for t in range(store.n_tiers):
        store.alloc[t].check_consistency()
    assert_no_double_booking(store)
    mgr.close()


# =============================================================================
# maybe_step interval accounting (the double-count bugfix)
# =============================================================================

def passes_after(steps_seq, interval=4):
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=interval,
                                          adaptive_interval=False))
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    counts = []
    for k in steps_seq:
        sm = sysmon.record(sm, jnp.asarray([0, 1], jnp.int32), is_write=True)
        sm, _ = mgr.maybe_step(sm, steps=k)
        counts.append(len(mgr.reports))
    return counts


def test_interval_accounting_exact_over_shrunken_dispatches():
    """A dispatch spanning more than one interval banks its overshoot:
    the skipped pass fires at the next boundary (even a 1-token one, the
    min-remaining-steps shrinkage near sequence ends) instead of pushing
    a full interval out — pass count tracks floor(tokens / interval)."""
    # 8 tokens at once (K = 2 x interval), then 1-token tail dispatches
    assert passes_after([8, 1, 1, 2]) == [1, 2, 2, 3]
    # the old remainder-modulo accounting lost the banked interval:
    # 8 % 4 = 0 -> the second pass needed 4 *more* tokens (fired at 12)


def test_interval_accounting_exact_at_boundaries():
    # plain cadence is untouched
    assert passes_after([4, 4, 4]) == [1, 2, 3]
    assert passes_after([2, 2, 2, 2]) == [0, 1, 1, 2]
    # credit is capped at one interval: a giant dispatch banks at most
    # one catch-up pass — it cannot force a pass at every boundary
    # forever after
    assert passes_after([16, 1, 1, 1]) == [1, 2, 2, 2]
