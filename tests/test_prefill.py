"""Bucketed packed prefill: bucket/packing policy invariants, AOT
warmup coverage (no data-dependent recompiles), structured submit
rejection, and the hard parity pin — prefill-then-decode must reproduce
the prompt-replay oracle bit for bit (tokens, KV pool contents, SysMon
raw counters, store accounting, pinned-tier wear)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, smoke
from repro.core.hierarchy import MemoryHierarchy
from repro.faults.errors import CapacityError
from repro.models import transformer as T
from repro.serving import PagedServingEngine, ServeConfig, bucket_for, pack_prompts
from repro.serving.prefill import bucket_list, next_pow2


@pytest.fixture(scope="module")
def model():
    cfg = smoke(registry()["qwen3_4b"])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def ref_greedy(cfg, params, prompt, n):
    lg, state = T.prefill(params, cfg,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=128)
    gen = []
    for _ in range(n):
        g = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
        gen.append(g)
        lg, state = T.decode_step(params, cfg, state,
                                  {"tokens": jnp.asarray([[g]], jnp.int32)})
    return gen


def _run_engine(cfg, params, prompts, max_new=6, **scfg_kw):
    kw = dict(page_size=8, max_batch=3, fast_slots=32, slow_slots=128,
              memos_enabled=False)
    kw.update(scfg_kw)
    eng = PagedServingEngine(cfg, params, ServeConfig(**kw))
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    return eng, reqs


# raw counters only: prefill intentionally collapses the sampling
# *cadence* (access_count / last_access / intv_* / sample_idx) to one
# streaming touch per burst — that divergence is the feature, so the
# parity pin covers the event-total fields replay must match exactly
SYSMON_RAW = ("reads", "writes", "bank_freq", "slab_freq")


def _assert_parity(ref, pre, rref, rpre, *, logits=True):
    for a, b in zip(rref, rpre):
        assert a.generated == b.generated
        assert a.tokens == b.tokens
    for f in SYSMON_RAW:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref.sysmon, f)),
            np.asarray(getattr(pre.sysmon, f)), err_msg=f"sysmon.{f}")
    sr, sp = ref.kv.store, pre.kv.store
    np.testing.assert_array_equal(sr.version, sp.version)
    assert sr.writes_to == sp.writes_to
    assert sr.reads_from == sp.reads_from
    for t, (pa, pb) in enumerate(zip(sr.pools, sp.pools)):
        np.testing.assert_array_equal(
            np.asarray(pa.data), np.asarray(pb.data),
            err_msg=f"pool[{t}] contents")
    if logits:
        np.testing.assert_array_equal(np.asarray(ref.last_logits),
                                      np.asarray(pre.last_logits))


# -- bucket / packing policy ---------------------------------------------------

def test_every_prompt_lands_in_smallest_covering_pow2_bucket():
    for n in range(1, 300):
        b = bucket_for(n, min_bucket=16, max_bucket=512)
        assert b >= max(n, 16)
        assert b & (b - 1) == 0, f"bucket {b} not a power of two"
        # smallest: half the bucket would not cover (or would dip under
        # the floor)
        assert b == 16 or b // 2 < max(n, 16)
    with pytest.raises(ValueError):
        bucket_for(513, min_bucket=16, max_bucket=512)
    assert bucket_list(16, 128) == [16, 32, 64, 128]
    assert next_pow2(1) == 1 and next_pow2(17) == 32


class _FakeReq:
    def __init__(self, n):
        self.prompt = list(range(n))


def test_packing_invariants():
    lens = [3, 5, 2, 9, 1, 1, 1, 1, 1, 30, 4]
    reqs = [_FakeReq(n) for n in lens]
    groups = pack_prompts(reqs, min_bucket=8, max_bucket=64,
                          max_segments=4)
    flat = [r for g in groups for r in g.requests]
    assert flat == reqs, "packing must preserve admission order"
    for g in groups:
        assert g.total_tokens <= g.bucket <= 64
        assert len(g.requests) <= 4
        # the bucket is the smallest covering pow2 for the packed total
        assert g.bucket == max(next_pow2(g.total_tokens), 8)
    # greedy escalation: the first four prompts (3+5+2+9 = 19) coalesce
    # into one bucket-32 group instead of one dispatch each
    assert [len(g.requests) for g in groups[:2]] == [4, 4]
    assert groups[0].bucket == 32
    # packing off -> one group per request, bucket per prompt
    solo = pack_prompts(reqs, min_bucket=8, max_bucket=64, pack=False)
    assert all(len(g.requests) == 1 for g in solo)
    assert all(g.bucket == bucket_for(len(g.requests[0].prompt), 8, 64)
               for g in solo)


# -- parity vs the prompt-replay oracle ----------------------------------------

def test_prefill_parity_vs_replay_oracle(model):
    """Prefill-then-decode == the prompt-replay reference engine, bit for
    bit: tokens, final logits, SysMon raw counters, version/read/write
    accounting, and every pool's contents."""
    cfg, params = model
    prompts = [list(range(5, 17)), list(range(30, 42)), list(range(50, 62))]
    ref, rr = _run_engine(cfg, params, prompts, reference=True)
    pre, rp = _run_engine(cfg, params, prompts, prefill=True, decode_block=4)
    _assert_parity(ref, pre, rr, rp)
    # the cadence counters must NOT match: the packed burst lands as one
    # streaming sampling, not one sampling per replayed token
    assert int(pre.sysmon.sample_idx) < int(ref.sysmon.sample_idx)


def test_packed_prefill_parity_and_packing_bit_identity(model):
    """Short prompts packed into one bucket row: (a) still bit-identical
    to the replay oracle, (b) bit-identical to the *unpacked* prefill
    (one dispatch per prompt) — segment isolation means packing can
    never change any segment's math."""
    cfg, params = model
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23, 24, 25, 26], [1, 2, 3, 4]]
    ref, rr = _run_engine(cfg, params, prompts, max_new=3, reference=True)
    pk, rpk = _run_engine(cfg, params, prompts, max_new=3, prefill=True,
                          decode_block=4)
    # logits=False: with unequal prompt lengths the prefill engine's rows
    # sit at different positions than the replay oracle's during the final
    # decode dispatch, so last_logits are computed at different per-row
    # offsets.  Tokens, pools, and counters are still pinned exactly.
    _assert_parity(ref, pk, rr, rpk, logits=False)
    solo, rsolo = _run_engine(cfg, params, prompts, max_new=3, prefill=True,
                              prefill_pack=False, decode_block=4)
    _assert_parity(pk, solo, rpk, rsolo)
    # the packed engine really did pack: fewer prefill dispatches
    assert len(pack_prompts([_FakeReq(len(p)) for p in prompts],
                            min_bucket=16, max_bucket=128)) == 1


def test_pinned_prefill_parity_including_wear(model):
    """Dual-pool prefill (prompt KV landing in the pinned-host tier) vs
    the K=1 dual-pool reference: tokens, pools, counters, and the
    pinned tier's wear array + write totals (gap interval large enough
    that no Start-Gap advance reshuffles rows mid-test)."""
    cfg, params = model
    hier = lambda: MemoryHierarchy.two_tier(  # noqa: E731
        2, 128, pinned_slow=True, gap_write_interval=10_000)
    prompts = [list(range(5, 17)), list(range(30, 42)), list(range(50, 62))]
    ref, rr = _run_engine(cfg, params, prompts, reference=True,
                          fast_slots=2, hierarchy=hier())
    pre, rp = _run_engine(cfg, params, prompts, prefill=True, decode_block=4,
                          fast_slots=2, hierarchy=hier())
    _assert_parity(ref, pre, rr, rp)
    wr, wp = ref.kv.store.wear_by_tier[1], pre.kv.store.wear_by_tier[1]
    assert wr.writes_total == wp.writes_total > 0
    assert wr.leveling_writes == wp.leveling_writes
    np.testing.assert_array_equal(np.asarray(wr.flush().wear),
                                  np.asarray(wp.flush().wear))
    np.testing.assert_array_equal(np.asarray(wr.state.remap),
                                  np.asarray(wp.state.remap))


def test_moe_prefill_expert_counts_exclude_padding(model):
    """MoE prefill: packed bucket padding rows must not inflate the
    expert-hotness histogram — counts match the replay oracle exactly."""
    cfg = smoke(registry()["olmoe_1b_7b"])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23, 24, 25, 26], [1, 2, 3, 4]]
    ref, rr = _run_engine(cfg, params, prompts, max_new=3, reference=True)
    pre, rp = _run_engine(cfg, params, prompts, max_new=3, prefill=True,
                          decode_block=4)
    for a, b in zip(rr, rp):
        assert a.generated == b.generated
    np.testing.assert_array_equal(ref.expert_counts, pre.expert_counts)


def test_prefill_with_memos_matches_dense_oracle(model):
    """Prefill under a live memos loop + HBM pressure: tiering decisions
    may differ from replay (prefill pages classify as streaming, by
    design) but generated tokens must still match the dense model."""
    cfg, params = model
    prompts = [list(range(5, 17)), [21, 22, 23], list(range(50, 59))]
    eng, reqs = _run_engine(cfg, params, prompts, memos_enabled=True,
                            memos_interval=5, fast_slots=12,
                            prefill=True, decode_block=4)
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 6)


# -- AOT warmup / no recompiles ------------------------------------------------

def test_warmup_precompiles_exactly_the_advertised_buckets(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, fast_slots=32, slow_slots=128,
        memos_enabled=False, prefill=True, prefill_max_bucket=32,
        decode_block=4))
    pr = eng.prefill_runner
    assert pr.buckets == [16, 32]
    eng.warmup()
    assert pr.n_compiles == len(pr.buckets)
    assert sorted(pr._plain) == pr.buckets
    n0 = pr.n_compiles
    # a mix of prompt lengths across both buckets: serving must never
    # trigger a data-dependent recompile
    for p in ([1] * 3, [2] * 17, [3] * 30, [4] * 5, [5] * 12):
        eng.submit(list(p), max_new=2)
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    assert pr.n_compiles == n0


def test_warmup_covers_pinned_variant(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=2, slow_slots=128,
        memos_enabled=False, prefill=True, prefill_max_bucket=16,
        decode_block=4,
        hierarchy=MemoryHierarchy.two_tier(2, 128, pinned_slow=True,
                                           gap_write_interval=10_000)))
    pr = eng.prefill_runner
    eng.warmup()
    assert pr.n_compiles == 2 * len(pr.buckets)     # plain + pinned
    n0 = pr.n_compiles
    eng.submit(list(range(12)), max_new=2)
    eng.run(max_steps=200)
    assert eng.batcher.all_done()
    assert pr.n_compiles == n0


# -- lifecycle edges -----------------------------------------------------------

def test_submit_rejects_structurally(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=32, slow_slots=128,
        max_pages_per_seq=4, prefill=True, prefill_max_bucket=16))
    with pytest.raises(CapacityError):
        eng.submit(list(range(30)), max_new=10)      # exceeds page budget
    with pytest.raises(CapacityError):
        eng.submit(list(range(20)), max_new=2)       # exceeds max bucket
    eng.submit(list(range(10)), max_new=2)           # fits: accepted


def test_max_new_one_finishes_at_prefill_boundary(model):
    """A single-token request completes inside the prefill dispatch: the
    first sampled token matches the dense oracle and the pages are
    released without ever entering the decode batch."""
    cfg, params = model
    prompts = [list(range(5, 17)), [21, 22, 23]]
    eng, reqs = _run_engine(cfg, params, prompts, max_new=1, prefill=True)
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 1)
        assert r.done and not r.pages
        assert r.first_token_step is not None


def test_prefill_ttft_stamped_at_admission_boundary(model):
    """Step-clock TTFT under prefill is pure queueing delay: a request
    admitted at step s gets first_token_step == s (the prompt no longer
    burns one decode step per token before the first emission)."""
    cfg, params = model
    prompts = [list(range(5, 17)), list(range(30, 42))]
    eng, reqs = _run_engine(cfg, params, prompts, prefill=True,
                            decode_block=4)
    for r in reqs:
        assert r.first_token_step == r.arrival == 0
    ref, rref = _run_engine(cfg, params, prompts, reference=True)
    for r in rref:
        # the replay oracle pays one step per prompt token first
        assert r.first_token_step == len(r.prompt) - 1
