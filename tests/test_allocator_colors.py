"""Sub-buddy ``color_mask`` invariants (paper Sec. 5.2 generalized
(i, j, k)-bit allocation).

Property-tested via the optional-hypothesis shim (skips cleanly when
hypothesis is absent) plus deterministic randomized fallbacks that always
run, so the invariants stay pinned in minimal environments:

  * any allocation with a mask returns a block whose color matches
    ``want & mask``;
  * free / realloc round-trips preserve the free-list accounting
    (``n_free``, block partition, color indexing).
"""
import numpy as np
import pytest

from helpers.optional_hypothesis import HAVE_HYPOTHESIS, given, settings, st
from repro.core.allocator import SubBuddyAllocator, SubBuddyConfig


def mask_invariant_rounds(n_pages, n_banks, n_slabs, requests):
    """Drive alloc/free rounds, asserting the color contract throughout."""
    cfg = SubBuddyConfig(n_pages=n_pages, n_banks=n_banks, n_slabs=n_slabs)
    a = SubBuddyAllocator(cfg)
    free_total = a.n_free
    live = []
    for want, mask, release in requests:
        want %= cfg.n_colors
        mask %= cfg.n_colors + 1
        blk = a.alloc(0, want, mask)
        if blk is not None:
            # the color contract: returned block matches want under mask
            assert cfg.color_of(blk) & mask == want & mask
            live.append(blk)
        if release and live:
            a.free(live.pop(np.random.RandomState(want).randint(len(live))), 0)
        a.check_consistency()
    # full round-trip: releasing everything restores the free accounting
    for blk in live:
        a.free(blk, 0)
    assert a.n_free == free_total
    a.check_consistency()
    # and the pool is fully allocatable again
    got = a.alloc_pages(n_pages)
    assert got is not None and len(set(got)) == n_pages
    assert a.n_free == 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        n_pages=st.integers(min_value=4, max_value=96),
        n_banks=st.sampled_from([1, 2, 4, 8]),
        n_slabs=st.sampled_from([1, 2, 4]),
        requests=st.lists(
            st.tuples(st.integers(min_value=0, max_value=511),
                      st.integers(min_value=0, max_value=511),
                      st.booleans()),
            min_size=1, max_size=40),
    )
    def test_color_mask_invariants_property(n_pages, n_banks, n_slabs,
                                            requests):
        mask_invariant_rounds(n_pages, n_banks, n_slabs, requests)
else:
    @given()
    def test_color_mask_invariants_property():
        pass                                    # skipped via the shim


@pytest.mark.parametrize("seed", range(6))
def test_color_mask_invariants_randomized(seed):
    """Deterministic fallback for environments without hypothesis."""
    rng = np.random.RandomState(seed)
    n_pages = int(rng.randint(4, 97))
    n_banks = int(2 ** rng.randint(0, 4))
    n_slabs = int(2 ** rng.randint(0, 3))
    requests = [(int(rng.randint(512)), int(rng.randint(512)),
                 bool(rng.rand() < 0.3)) for _ in range(40)]
    mask_invariant_rounds(n_pages, n_banks, n_slabs, requests)


def test_mask_zero_matches_any_color():
    a = SubBuddyAllocator(SubBuddyConfig(n_pages=16, n_banks=2, n_slabs=2))
    seen = {a.alloc(0, 3, 0) for _ in range(16)}
    assert None not in seen and len(seen) == 16     # mask 0: every page ok


def test_exact_mask_is_color_exact():
    cfg = SubBuddyConfig(n_pages=32, n_banks=4, n_slabs=2)
    a = SubBuddyAllocator(cfg)
    full = cfg.n_colors - 1
    for want in range(cfg.n_colors):
        blk = a.alloc(0, want, full)
        assert blk is not None and cfg.color_of(blk) == want
    a.check_consistency()


def test_double_free_detected():
    a = SubBuddyAllocator(SubBuddyConfig(n_pages=8, n_banks=2, n_slabs=2))
    blk = a.alloc(0)
    a.free(blk, 0)
    with pytest.raises(ValueError):
        a.free(blk, 0)
