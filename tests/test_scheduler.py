"""ContinuousBatcher unit tests: admission / preemption / resume / fail
ordering, free-slot reuse, and the priority-aware policy paths — plus the
pins that make the QoS work safe: the legacy (priority-blind) admission
order is bit-identical to the pre-QoS scheduler, and the priority-aware
order fixes the resumed-batch-starves-new-LC hazard."""
from repro.serving.scheduler import ContinuousBatcher, Request


def mk(rid, priority=0, tenant="default", prompt=None, max_new=3):
    r = Request(rid, prompt or [1, 2], max_new, tenant=tenant,
                priority=priority)
    return r


def fill(b, reqs):
    for r in reqs:
        b.submit(r)
    return b


# -- legacy (priority-blind) policy: exact pre-QoS behavior -------------------

def test_legacy_admit_fifo_and_slot_order():
    b = fill(ContinuousBatcher(3), [mk(i) for i in range(5)])
    admitted = b.admit()
    assert [r.rid for r in admitted] == [0, 1, 2]
    assert [r.slot for r in admitted] == [0, 1, 2]
    assert [r.rid for r in b.waiting] == [3, 4]


def test_legacy_preempted_drains_before_waiting():
    """The legacy admission order, pinned verbatim: resumed requests
    always win over new arrivals regardless of anything else.  This is
    the starvation hazard the priority-aware policy exists to fix — but
    with QoS off it must stay exactly as it always was."""
    b = fill(ContinuousBatcher(2), [mk(0), mk(1), mk(2)])
    b.admit()
    victim = b.preempt_lowest()
    assert victim.rid == 0            # start_step unset: max() keeps the
    #                                   first of the tied slots
    b.submit(mk(9))                   # new arrival AFTER the preemption
    admitted = b.admit()              # one free slot: the resumed req wins
    assert admitted == [victim], "resumed must come first under legacy"
    assert [r.rid for r in b.waiting] == [2, 9]


def test_legacy_preempt_is_pure_lifo():
    b = fill(ContinuousBatcher(3), [mk(i) for i in range(3)])
    for i, r in enumerate(b.admit()):
        r.start_step = i              # 0, 1, 2 — rid 2 admitted last
    assert b.preempt_lowest().rid == 2
    assert b.preempt_lowest().rid == 1


def test_free_slot_reuse():
    b = fill(ContinuousBatcher(2), [mk(i) for i in range(4)])
    b.admit()
    b.finish(b.running[0], step=3)    # frees slot 0
    nxt = b.admit()
    assert len(nxt) == 1 and nxt[0].rid == 2 and nxt[0].slot == 0
    assert set(b.running) == {0, 1}


def test_finish_and_fail_retire_everywhere():
    b = fill(ContinuousBatcher(2), [mk(i) for i in range(4)])
    b.admit()
    waiting_req = b.waiting[0]        # rid 2
    b.fail(waiting_req, step=1, error=RuntimeError("boom"))
    assert waiting_req.done and waiting_req.error is not None
    assert waiting_req.finish_ts is not None
    assert [r.rid for r in b.waiting] == [3]
    victim = b.preempt_lowest()
    b.fail(victim, step=2, error=RuntimeError("boom"))
    assert not b.preempted
    running = next(iter(b.running.values()))
    b.finish(running, step=4)
    assert running.finish_step == 4 and running.finish_ts is not None
    b.admit()
    b.finish(next(iter(b.running.values())), step=5)
    assert b.all_done()
    assert len(b.finished) == 4


def test_admit_limit_caps_running():
    b = fill(ContinuousBatcher(4), [mk(i) for i in range(4)])
    assert len(b.admit(limit=2)) == 2
    assert len(b.running) == 2
    assert b.admit(limit=2) == []     # already at the cap
    assert len(b.admit(limit=None)) == 2


# -- priority-aware policy ----------------------------------------------------

def test_priority_admit_highest_first_fifo_within():
    b = ContinuousBatcher(2, priority_aware=True)
    fill(b, [mk(0, priority=0), mk(1, priority=2), mk(2, priority=1),
             mk(3, priority=2)])
    admitted = b.admit()
    assert [r.rid for r in admitted] == [1, 3]   # both prio 2, FIFO
    b.finish(admitted[0], step=1)
    assert b.admit()[0].rid == 2                 # prio 1 before prio 0


def test_priority_fixes_resumed_batch_starving_new_lc():
    """The satellite-1 scenario: a preempted batch request must NOT
    starve a newly-arrived latency-critical request under the
    priority-aware policy (it did — and still does — under legacy)."""
    b = ContinuousBatcher(1, priority_aware=True)
    fill(b, [mk(0, priority=0, tenant="batch")])
    b.admit()
    victim = b.preempt_lowest()
    assert victim.rid == 0
    b.submit(mk(1, priority=2, tenant="lc"))
    admitted = b.admit()
    assert admitted[0].rid == 1, "LC arrival must beat the resumed batch req"
    assert [r.rid for r in b.preempted] == [0]


def test_priority_resumed_before_new_within_priority():
    b = ContinuousBatcher(1, priority_aware=True)
    fill(b, [mk(0, priority=1)])
    b.admit()
    victim = b.preempt_lowest()
    b.submit(mk(1, priority=1))       # same priority, new arrival
    assert b.admit()[0] is victim


def test_priority_preempt_lowest_then_lifo():
    b = ContinuousBatcher(3, priority_aware=True)
    fill(b, [mk(0, priority=2), mk(1, priority=0), mk(2, priority=0)])
    for i, r in enumerate(b.admit()):
        r.start_step = i
    v = b.preempt_lowest()
    assert v.rid == 2                 # lowest priority (0), LIFO within
    v = b.preempt_lowest()
    assert v.rid == 1
    v = b.preempt_lowest()
    assert v.rid == 0                 # only the prio-2 one left


def test_preempt_max_priority_guard():
    b = ContinuousBatcher(2, priority_aware=True)
    fill(b, [mk(0, priority=2), mk(1, priority=1)])
    b.admit()
    assert b.preempt_lowest(max_priority=0) is None
    v = b.preempt_lowest(max_priority=1)
    assert v.rid == 1
    # only a prio-2 victim remains; a guard below it refuses
    assert b.preempt_lowest(max_priority=1) is None
    assert b.preempt_lowest() is not None   # unbounded still works


def test_uniform_priorities_reduce_to_legacy_victim():
    """With every priority equal, the aware preemption picks exactly the
    legacy pure-LIFO victim — the reduction that makes one code path
    safe for both modes."""
    for aware in (False, True):
        b = ContinuousBatcher(3, priority_aware=aware)
        fill(b, [mk(i) for i in range(3)])
        for i, r in enumerate(b.admit()):
            r.start_step = i
        assert [b.preempt_lowest().rid for _ in range(3)] == [2, 1, 0]


def test_decision_counters():
    b = fill(ContinuousBatcher(2), [mk(i) for i in range(3)])
    b.admit()
    b.preempt_lowest()
    b.admit()
    assert b.n_admitted == 3          # 2 initial + 1 resume
    assert b.n_preempted == 1
