"""Unit + property tests for the memos core (predictor, allocator, sysmon,
placement, migration, tiering, cost model)."""
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.optional_hypothesis import given, settings, st

from repro.core import costmodel, patterns, placement, predictor, sysmon
from repro.core.allocator import SubBuddyAllocator, SubBuddyConfig
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import MigrationEngine
from repro.core.hierarchy import FAST, SLOW
from repro.core.tiers import TierConfig, TierStore


# =============================================================================
# predictor (paper Fig. 3/4)
# =============================================================================

def test_fig4_truth_table():
    """The paper's four canonical cases (bit 0 = most recent pass)."""
    cases = {
        0b10111111: predictor.WD_FREQ_H,   # case_1: dense WD history
        0b00100000: predictor.UN_WD,       # case_2: single old WD
        0b10011011: predictor.WD_FREQ_L,   # case_3: sparse WD
        0b00000111: predictor.WD_FREQ_H,   # case_4: Reverse (recent WD run)
        0b11111000: predictor.UN_WD,       # case_4': Reverse (recent quiet)
    }
    hist = jnp.asarray(list(cases.keys()), jnp.uint8)
    out = np.asarray(predictor.predict_future(hist))
    np.testing.assert_array_equal(out, np.asarray(list(cases.values())))


def test_reverse_detection():
    hist = jnp.asarray([0b00000111, 0b11111000, 0b10111111], jnp.uint8)
    rev = np.asarray(predictor.is_reverse(hist))
    np.testing.assert_array_equal(rev, [True, True, False])


@given(st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_predictor_invariants(h):
    """Reverse dominates; prediction in range; monotone in popcount
    when the suffix doesn't override."""
    out = int(predictor.predict_future(jnp.asarray([h], jnp.uint8))[0])
    assert out in (predictor.UN_WD, predictor.WD_FREQ_L, predictor.WD_FREQ_H)
    suffix = h & 0b111
    if suffix == 0b111:
        assert out == predictor.WD_FREQ_H
    elif suffix == 0:
        assert out == predictor.UN_WD
    else:
        ones = bin(h).count("1")
        if ones >= predictor.HI_THRESH:
            assert out == predictor.WD_FREQ_H
        elif ones >= predictor.LO_THRESH:
            assert out == predictor.WD_FREQ_L


@given(st.integers(0, 255), st.integers(0, 1))
@settings(max_examples=100, deadline=None)
def test_history_push_is_shift(h, bit):
    new = int(predictor.push_history(jnp.asarray([h], jnp.uint8),
                                     jnp.asarray([bit], jnp.uint8))[0])
    assert new == (((h << 1) | bit) & 0xFF)


def test_predict_trace_accuracy_on_persistent_pattern():
    """A stable WD/RD pattern must be predicted at ~100% accuracy — the
    mechanism behind the paper's 96% claim (Fig. 3)."""
    T, n = 64, 32
    wd = jnp.zeros((T, n), jnp.uint8).at[:, :16].set(1)  # half pages always-WD
    _, acc = predictor.predict_trace(wd)
    assert float(acc) > 0.99


# =============================================================================
# patterns (paper Sec. 3.1)
# =============================================================================

@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=200, deadline=None)
def test_wd_rule_weighted(reads, writes):
    code = int(patterns.classify_wd(jnp.asarray([reads]),
                                    jnp.asarray([writes]))[0])
    if reads + writes == 0:
        assert code == patterns.COLD
    elif 2 * writes >= reads:
        assert code == patterns.WD
    else:
        assert code == patterns.RD


# =============================================================================
# sub-buddy allocator (paper Sec. 6.2, Algorithm 3)
# =============================================================================

def test_color_exact_alloc():
    cfg = SubBuddyConfig(n_pages=512, n_banks=8, n_slabs=4)
    a = SubBuddyAllocator(cfg)
    for color in [0, 5, 31, 17]:
        p = a.alloc(0, color)
        assert p is not None and cfg.color_of(p) == color


def test_color_mask_generalized_allocation():
    """(i,j,k)-bit allocation: constrain only the slab bits."""
    cfg = SubBuddyConfig(n_pages=256, n_banks=8, n_slabs=4)
    a = SubBuddyAllocator(cfg)
    # match slab 2 in any bank: mask = n_slabs-1
    for _ in range(8):
        p = a.alloc(0, color=2, color_mask=cfg.n_slabs - 1)
        assert p is not None and cfg.slab_of(p) == 2


def test_buddy_merge_roundtrip():
    cfg = SubBuddyConfig(n_pages=64, n_banks=4, n_slabs=4, max_order=6)
    a = SubBuddyAllocator(cfg)
    total = a.n_free
    pages = [a.alloc(0) for _ in range(64)]
    assert a.n_free == 0 and None not in pages
    for p in pages:
        a.free(p, 0)
    assert a.n_free == total
    # after coalescing, a max-order block is allocatable again
    assert a.alloc(6) is not None


def test_double_free_raises():
    a = SubBuddyAllocator(SubBuddyConfig(n_pages=16, n_banks=2, n_slabs=2))
    p = a.alloc(0)
    a.free(p, 0)
    with pytest.raises(ValueError):
        a.free(p, 0)


@given(st.lists(st.sampled_from(["alloc", "free"]), min_size=1, max_size=200),
       st.randoms())
@settings(max_examples=50, deadline=None)
def test_allocator_never_double_allocates(ops, rnd):
    cfg = SubBuddyConfig(n_pages=128, n_banks=4, n_slabs=4, max_order=5)
    a = SubBuddyAllocator(cfg)
    live: set[int] = set()
    for op in ops:
        if op == "alloc":
            color = rnd.randrange(cfg.n_colors) if rnd.random() < 0.5 else None
            p = a.alloc(0, color)
            if p is not None:
                assert p not in live, "double allocation!"
                assert 0 <= p < cfg.n_pages
                if color is not None:
                    assert cfg.color_of(p) == color
                live.add(p)
        elif live:
            p = live.pop()
            a.free(p, 0)
    assert a.n_free == cfg.n_pages - len(live)


# =============================================================================
# sysmon (paper Sec. 4.2, Algorithm 1)
# =============================================================================

def test_sysmon_bank_slab_frequency_tables():
    st_ = sysmon.init(16, n_banks=4, n_slabs=2)
    st_ = sysmon.record(st_, jnp.asarray([0, 1, 2, 3, 0]))  # page 0 twice
    bank = np.asarray(st_.bank_freq)
    assert bank.sum() == 5
    st_, summary = sysmon.end_pass(st_)
    assert np.asarray(summary.reads).sum() == 5
    # counters reset after the pass
    assert np.asarray(st_.reads).sum() == 0


def test_sysmon_reuse_classes():
    st_ = sysmon.init(8, 2, 2)
    # page 0: touched every sampling (thrashing); page 1: every 8th (rare)
    for t in range(32):
        ids = [0] + ([1] if t % 8 == 0 else [])
        st_ = sysmon.record(st_, jnp.asarray(ids))
    st_, summary = sysmon.end_pass(st_)
    rc = np.asarray(summary.reuse_class)
    assert rc[0] == patterns.THRASHING
    assert rc[1] in (patterns.RARELY_TOUCHED, patterns.FREQ_TOUCHED)
    assert rc[7] == patterns.RARELY_TOUCHED  # untouched


# =============================================================================
# placement (paper Sec. 5.2/5.3, Algorithm 2)
# =============================================================================

def test_channel_allocation_principles():
    wd = np.asarray([patterns.WD, patterns.RD, patterns.COLD, patterns.RD])
    hot = np.asarray([True, False, False, True])
    fut = np.asarray([predictor.WD_FREQ_H, predictor.UN_WD,
                      predictor.UN_WD, predictor.UN_WD])
    reuse = np.asarray([patterns.FREQ_TOUCHED, patterns.RARELY_TOUCHED,
                        patterns.RARELY_TOUCHED, patterns.THRASHING])
    tgt = placement.target_tier(wd, hot, fut, reuse)
    assert tgt[0] == FAST          # hot + WD
    assert tgt[1] == SLOW          # cold RD
    assert tgt[2] == SLOW          # cold
    assert tgt[3] == SLOW          # RD thrashing stream stays slow


def test_algorithm2_coldest_bank_slab():
    bank_freq = np.asarray([5, 1, 9, 3])
    slab_freq = np.asarray([0, 7, 2, 9, 1, 3, 8, 2, 5, 5, 5, 5, 5, 5, 5, 0])
    got = placement.coldest_bank_and_slab(bank_freq, slab_freq,
                                          lambda b, s: True)
    assert got == (1, 4)  # bank 1 coldest; slab 4 coldest non-reserved

    # slabs 0/15 are reserved even though coldest
    got2 = placement.coldest_bank_and_slab(
        bank_freq, slab_freq, lambda b, s: s not in (4,))
    assert got2 == (1, 2)  # next coldest with free rows


def test_hotness_list_priority():
    class S:  # minimal summary stub
        wd_code = np.asarray([patterns.WD] * 4)
        hot = np.asarray([True] * 4)
        future = np.asarray([predictor.WD_FREQ_L, predictor.WD_FREQ_H,
                             predictor.WD_FREQ_H, predictor.WD_FREQ_L])
        reuse_class = np.asarray([patterns.FREQ_TOUCHED] * 4)
        hotness = np.asarray([9.0, 1.0, 5.0, 2.0])
    dec = placement.plan(S(), current_tier=np.asarray([SLOW] * 4))
    # WD_FREQ_H pages first (idx 2 hotter than 1), then L by hotness
    np.testing.assert_array_equal(dec.hotness_list, [2, 1, 0, 3])


def test_bandwidth_balancer_stop_rule():
    b = placement.BandwidthBalancer(fast_bw_bound=0.9)
    assert not b.update(0.5)
    assert b.update(0.95)          # saturated -> spill
    assert b.update(0.93)          # still high -> keep spilling
    assert not b.update(0.7)       # utilization dropped -> stop
