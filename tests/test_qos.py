"""Multi-tenant QoS subsystem tests (repro.qos + the scheduler/placement/
memos/engine hooks):

  * trace generation is deterministic and round-trips byte-for-byte
    through the JSONL schema;
  * the power governor's throttle/recovery state machine;
  * placement with page weights: all-ones parity (bit-identical to the
    pre-QoS planner), demotion resistance for weighted pages, weighted
    ranking; energy-aware intermediate fill stays valid;
  * the headline compatibility pin: an engine with ``qos=None`` and one
    with a bare ``QoSConfig()`` produce **bit-identical** scheduler
    decisions and served tokens;
  * tenant priorities actually reorder service end to end;
  * wall-clock timestamps + TTFT/e2e/ITL histograms publish per tenant.
"""
import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import registry, smoke
from repro.core import placement
from repro.core.patterns import RD, WD
from repro.core.predictor import UN_WD, WD_FREQ_H
from repro.models import transformer as T
from repro.qos import (BATCH, LATENCY_CRITICAL, PowerGovernor, QoSConfig,
                       tenant_for_class)
from repro.qos.traces import (ArrivalSpec, canonical_specs, generate_trace,
                              read_trace, write_trace)
from repro.serving import PagedServingEngine, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = smoke(registry()["qwen3_4b"])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(autouse=True)
def fresh_registry():
    obs.reset()
    yield
    obs.reset()


# -- traces -------------------------------------------------------------------

def test_trace_generation_deterministic():
    specs = [ArrivalSpec("a", process="poisson", rate_rps=5.0),
             ArrivalSpec("b", tier_class=BATCH, process="bursty",
                         rate_rps=6.0, burst_size=3),
             ArrivalSpec("c", process="diurnal", rate_rps=4.0)]
    m1, e1 = generate_trace("t", specs, 3.0, seed=42)
    m2, e2 = generate_trace("t", specs, 3.0, seed=42)
    assert m1 == m2
    assert [(e.rid, e.t, e.tenant, e.prompt, e.max_new) for e in e1] == \
        [(e.rid, e.t, e.tenant, e.prompt, e.max_new) for e in e2]
    # adding a stream never perturbs existing streams' arrivals
    m3, e3 = generate_trace("t", specs + [ArrivalSpec("d")], 3.0, seed=42)
    a_times = [e.t for e in e1 if e.tenant == "a"]
    assert [e.t for e in e3 if e.tenant == "a"] == a_times
    assert all(e.t < 3.0 for e in e1)
    assert [e.rid for e in e1] == sorted(e.rid for e in e1)


def test_trace_jsonl_roundtrip_byte_identical(tmp_path):
    name, (specs, dur, seed) = next(iter(canonical_specs().items()))
    meta, events = generate_trace(name, specs, dur, seed)
    p1 = write_trace(tmp_path / "a.jsonl", meta, events)
    meta2, events2 = read_trace(p1)
    p2 = write_trace(tmp_path / "b.jsonl", meta2, events2)
    assert p1.read_bytes() == p2.read_bytes()
    assert meta2["n_requests"] == len(events2) == len(events)


# -- power governor -----------------------------------------------------------

def test_power_governor_throttle_and_hysteresis():
    g = PowerGovernor(budget_mw=100.0, recover_passes=2)
    assert not g.pressure and g.batch_limit(4) == 4
    assert g.observe(150.0)           # over: throttle 1
    assert g.observe(120.0)           # over: throttle 2
    assert g.pressure and g.throttle == 2 and g.batch_limit(4) == 2
    assert g.peak_power_mw == 150.0 and g.over_budget_passes == 2
    assert not g.observe(90.0)        # calm 1: no release yet
    assert g.throttle == 2
    assert not g.observe(80.0)        # calm 2: release one level
    assert g.throttle == 1
    g.observe(85.0)
    g.observe(85.0)                   # two more calm passes: released
    assert g.throttle == 0 and not g.pressure
    # throttle never exceeds max and batch_limit never drops below 1
    for _ in range(20):
        g.observe(1e9)
    assert g.throttle == g.max_throttle
    assert g.batch_limit(4) == 1


# -- placement: page weights + energy-aware fill ------------------------------

def _summary(n, wd_code, hot, future, reuse, hotness):
    class S:
        pass

    s = S()
    s.wd_code = np.asarray(wd_code)
    s.hot = np.asarray(hot, bool)
    s.future = np.asarray(future)
    s.reuse_class = np.asarray(reuse)
    s.hotness = np.asarray(hotness, np.float64)
    return s


def test_plan_all_ones_weight_is_bit_identical():
    rng = np.random.RandomState(3)
    n = 64
    s = _summary(n, rng.randint(0, 3, n), rng.rand(n) < 0.3,
                 rng.randint(0, 3, n), rng.randint(0, 3, n),
                 rng.rand(n) * 10)
    cur = rng.randint(0, 2, n).astype(np.int8)
    base = placement.plan(s, cur.copy())
    ones = placement.plan(s, cur.copy(), page_weight=np.ones(n))
    none = placement.plan(s, cur.copy(), page_weight=None,
                          energy_aware=False)
    for a, b in ((base, ones), (base, none)):
        assert np.array_equal(a.target_tier, b.target_tier)
        assert np.array_equal(a.migrate, b.migrate)
        assert np.array_equal(a.hotness_list, b.hotness_list)


def test_weighted_pages_resist_demotion():
    n = 4
    # all pages cold RD in tier 0: the rule wants them all demoted
    s = _summary(n, [RD] * n, [False] * n, [UN_WD] * n, [0] * n,
                 [1.0] * n)
    cur = np.zeros(n, np.int8)
    base = placement.plan(s, cur.copy())
    assert base.migrate.all(), "sanity: unweighted pages all demote"
    w = np.ones(n)
    w[1] = 4.0                        # the LC tenant's page
    dec = placement.plan(s, cur.copy(), page_weight=w)
    assert dec.target_tier[1] == 0 and not dec.migrate[1]
    assert dec.migrate[[0, 2, 3]].all(), "neutral pages still demote"
    # promotion is never blocked by weight
    s2 = _summary(n, [WD] * n, [True] * n, [WD_FREQ_H] * n, [0] * n,
                  [5.0] * n)
    dec2 = placement.plan(s2, np.ones(n, np.int8), page_weight=w)
    assert dec2.migrate.all() and (dec2.target_tier == 0).all()


def test_weight_scales_migration_ranking():
    n = 3
    s = _summary(n, [WD] * n, [True] * n, [WD_FREQ_H] * n, [0] * n,
                 [1.0, 2.0, 3.0])
    cur = np.ones(n, np.int8)
    base = placement.plan(s, cur.copy())
    assert list(base.hotness_list) == [2, 1, 0]
    w = np.array([10.0, 1.0, 1.0])
    dec = placement.plan(s, cur.copy(), page_weight=w)
    assert list(dec.hotness_list) == [0, 2, 1], \
        "weight multiplies hotness in the HL ranking"


def test_energy_aware_fill_valid_and_two_tier_noop():
    from repro.core.hierarchy import MemoryHierarchy
    rng = np.random.RandomState(5)
    n = 48
    s = _summary(n, rng.randint(0, 3, n), rng.rand(n) < 0.2,
                 rng.randint(0, 3, n), rng.randint(0, 3, n), rng.rand(n))
    s.reads = rng.randint(0, 50, n)
    s.writes = rng.randint(0, 50, n)
    cur = rng.randint(0, 2, n).astype(np.int8)
    # two-tier: no intermediate tiers, so energy_aware changes nothing
    base = placement.plan(s, cur.copy())
    ea = placement.plan(s, cur.copy(), energy_aware=True)
    assert np.array_equal(base.target_tier, ea.target_tier)
    # three-tier: decision stays structurally valid under the energy cost
    h3 = MemoryHierarchy.three_tier(8, 8, 64)
    cur3 = rng.randint(0, 3, n).astype(np.int8)
    d3 = placement.plan(s, cur3, hierarchy=h3, energy_aware=True)
    assert set(np.unique(d3.target_tier)).issubset({0, 1, 2})
    assert int((d3.target_tier == 1).sum()) <= 8


# -- engine integration -------------------------------------------------------

def _serve(cfg, params, qos, submits, **kw):
    scfg = dict(page_size=8, max_batch=2, fast_slots=12, slow_slots=128,
                memos_interval=5, qos=qos)
    scfg.update(kw)
    eng = PagedServingEngine(cfg, params, ServeConfig(**scfg))
    reqs = [eng.submit(p, max_new=n, tenant=t) for p, n, t in submits]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    eng.close()
    return eng, reqs


def test_bare_qos_config_bit_identical_to_none(model):
    """The acceptance pin: with no tenants configured, scheduler
    decisions and served tokens are bit-identical to pre-QoS behavior —
    under memory pressure (preemptions) and across memos passes."""
    cfg, params = model
    submits = [([5, 7, 9, 11, 13], 6, None), ([21, 22, 23], 6, None),
               ([1, 2, 3, 4, 5, 6, 7, 8, 9], 6, None)]
    eng_a, reqs_a = _serve(cfg, params, None, submits, max_batch=3)
    eng_b, reqs_b = _serve(cfg, params, QoSConfig(), submits, max_batch=3)
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.generated == rb.generated
        assert ra.finish_step == rb.finish_step
        assert ra.start_step == rb.start_step
        assert ra.first_token_step == rb.first_token_step
    assert [r.rid for r in eng_a.batcher.finished] == \
        [r.rid for r in eng_b.batcher.finished]
    assert np.array_equal(eng_a.kv.store.tier, eng_b.kv.store.tier)
    assert eng_a.batcher.n_preempted == eng_b.batcher.n_preempted
    assert eng_a.step_count == eng_b.step_count


def test_priority_reorders_service_end_to_end(model):
    """One decode slot, two queued batch requests, then an LC arrival:
    priority-aware serves the LC request before the queued batch ones;
    the blind engine serves strict FIFO."""
    cfg, params = model
    qos = QoSConfig(tenants=(tenant_for_class("lc", LATENCY_CRITICAL),
                             tenant_for_class("bat", BATCH)))
    submits = [([3, 4, 5], 4, "bat"), ([6, 7, 8], 4, "bat"),
               ([9, 10, 11], 4, "lc")]
    eng_aware, r_aware = _serve(cfg, params, qos, submits, max_batch=1,
                                fast_slots=32)
    eng_blind, r_blind = _serve(cfg, params, None, submits, max_batch=1,
                                fast_slots=32)
    fin_aware = [r.tenant for r in eng_aware.batcher.finished]
    fin_blind = [r.rid for r in eng_blind.batcher.finished]
    assert fin_blind == [0, 1, 2], "blind engine is FIFO"
    assert fin_aware.index("lc") < 2, \
        "LC must overtake at least one queued batch request"
    assert r_aware[2].first_token_step < r_blind[2].first_token_step
    # same tokens regardless of order (greedy decode is per-sequence)
    for ra, rb in zip(r_aware, r_blind):
        assert ra.generated == rb.generated
    # tenant identity landed on the requests
    assert r_aware[2].priority > r_aware[0].priority
    assert r_aware[2].weight == 4.0 and r_aware[0].weight == 1.0


def test_timestamps_and_histograms_publish(model):
    cfg, params = model
    qos = QoSConfig(tenants=(tenant_for_class("lc", LATENCY_CRITICAL),))
    _, reqs = _serve(cfg, params, qos,
                     [([5, 6, 7], 4, "lc"), ([8, 9, 10], 4, None)])
    for r in reqs:
        assert r.submit_ts is not None
        assert r.first_token_ts is not None and r.finish_ts is not None
        assert r.finish_ts >= r.first_token_ts >= r.submit_ts
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.e2e_s is not None and r.e2e_s >= r.ttft_s
    flat = obs.get_registry().flat()
    assert flat["serving.ttft_s.count"] == 2
    assert flat["serving.e2e_latency_s.count"] == 2
    assert flat["qos.ttft_s.lc.count"] == 1
    assert flat["qos.ttft_s.default.count"] == 1
    assert flat["qos.e2e_s.lc.p50"] > 0
    assert flat["qos.itl_s.lc.count"] == 3    # max_new-1 token gaps
    assert flat["serving.admissions"] >= 2


def test_power_cap_shrinks_admission_and_recovers(model):
    """A tight budget must drive the governor's throttle up (admission
    narrows below max_batch) and telemetry must record the over-budget
    passes; with no budget the governor is absent entirely."""
    cfg, params = model
    submits = [([i + 1, i + 2, i + 3], 8, None) for i in range(4)]
    eng_free, _ = _serve(cfg, params, QoSConfig(), submits,
                         max_batch=4, fast_slots=4, slow_slots=128,
                         memos_interval=4)
    assert eng_free.memos.governor is None
    peak = max((r.power_mw for r in eng_free.memos.reports), default=0.0)
    assert peak > 0, "pressure config must generate slow-tier power"
    qos = QoSConfig(power_budget_mw=peak * 0.2)
    eng_cap, reqs = _serve(cfg, params, qos, submits,
                           max_batch=4, fast_slots=4, slow_slots=128,
                           memos_interval=4)
    gov = eng_cap.memos.governor
    assert gov is not None and gov.over_budget_passes > 0
    assert any(r.power_throttle > 0 for r in eng_cap.memos.reports)
    assert any(r.power_pressure for r in eng_cap.memos.reports)
    assert all(r.generated for r in reqs), "capped engine still serves"
    flat = obs.get_registry().flat()
    assert flat["power.budget_mw"] == pytest.approx(peak * 0.2)
    assert flat["power.over_budget_passes"] > 0


def test_report_roundtrip_with_power_fields(model):
    cfg, params = model
    qos = QoSConfig(power_budget_mw=0.001)
    eng, _ = _serve(cfg, params, qos, [([5, 6, 7], 6, None)],
                    memos_interval=4)
    from repro.core.memos import MemosReport
    r = eng.memos.reports[-1]
    rt = MemosReport.from_dict(r.to_dict())
    assert rt.power_mw == r.power_mw
    assert rt.power_throttle == r.power_throttle
    assert rt.power_pressure == r.power_pressure
    assert "power_mw" in r.flat_metrics()
