"""N-tier MemoryHierarchy API: two-tier parity against the pre-redesign
golden trace, MediumSpec validation, bf16 host-pool bit-pattern storage,
color-geometry clamp warning, 3-tier migration/memos end-to-end, and
per-tier wear telemetry."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from helpers import gen_two_tier_golden as golden

from repro.core import costmodel as cm
from repro.core import sysmon
from repro.core.hierarchy import FAST, SLOW, MediumSpec, MemoryHierarchy
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import BatchedMigrationEngine, MigrationEngine
from repro.core.placement import target_tier
from repro.core.tiers import NO_SLOT, StoreConfig, TierConfig, TierStore


def make_3tier_store(n=24, hbm=4, dram=8, nvm=24, shape=(4,), seed=0,
                     **hier_kw):
    h = MemoryHierarchy.three_tier(hbm, dram, nvm, **hier_kw)
    s = TierStore(StoreConfig(n_pages=n, page_shape=shape, hierarchy=h,
                              n_banks=2, n_slabs=2))
    rng = np.random.RandomState(seed)
    for p in range(n):
        assert s.allocate(p, h.deepest)
        s.write_page(p, rng.standard_normal(shape).astype(np.float32))
    return s


# =============================================================================
# two-tier parity: MemoryHierarchy.two_tier vs the pre-redesign TierStore
# =============================================================================

def test_two_tier_parity_vs_golden():
    """Replays the pinned scenario (see tests/helpers/gen_two_tier_golden)
    through the redesigned store and compares every observable array —
    page table, pool contents, SysMon counters, wear counters, traffic —
    bit for bit against the fixture captured from the pre-redesign
    hardcoded-FAST/SLOW implementation."""
    ref = np.load(golden.OUT)
    store, mgr, sm = golden.run_scenario()
    got = golden.collect(store, mgr, sm)
    assert set(ref.files) == set(got)
    for key in ref.files:
        np.testing.assert_array_equal(
            np.asarray(got[key]), ref[key],
            err_msg=f"two-tier parity diverged from pre-redesign "
                    f"behavior at {key!r}")


def test_two_tier_shim_matches_explicit_hierarchy():
    """TierConfig and an explicit two_tier StoreConfig build identical
    stores."""
    a = TierStore(TierConfig(n_pages=8, fast_slots=4, slow_slots=8,
                             page_shape=(4,), n_banks=2, n_slabs=2))
    b = TierStore(StoreConfig(
        n_pages=8, page_shape=(4,),
        hierarchy=MemoryHierarchy.two_tier(4, 8), n_banks=2, n_slabs=2))
    assert a.hierarchy == b.hierarchy
    assert a.cfg.fast_slots == b.cfg.fast_slots == 4
    assert a.cfg.slow_slots == b.cfg.slow_slots == 8
    assert [type(p) for p in a.pools] == [type(p) for p in b.pools]


# =============================================================================
# MediumSpec / MemoryHierarchy validation
# =============================================================================

def test_medium_spec_validation():
    with pytest.raises(ValueError):
        MediumSpec("X", 4, cm.HBM, residency="vram")
    with pytest.raises(ValueError):
        MediumSpec("X", 0, cm.HBM, residency="device")
    with pytest.raises(ValueError):        # wear needs a host pool
        MediumSpec("X", 4, cm.HBM, residency="device", wear_tracked=True)
    with pytest.raises(ValueError):        # leveling needs tracking
        MediumSpec("X", 4, cm.NVM, wear_leveling=True)
    with pytest.raises(ValueError):        # a hierarchy needs >= 2 tiers
        MemoryHierarchy(tiers=(MediumSpec("X", 4, cm.HBM),))


def test_hierarchy_tier_subsets():
    h = MemoryHierarchy.three_tier(4, 8, 16)
    assert h.n_tiers == 3 and h.deepest == 2
    assert h.device_tiers() == [0, 1]
    assert h.host_tiers() == [2]
    assert h.wear_tiers() == [2]
    assert h.total_slots() == 28
    h2 = h.with_tier(2, wear_tracked=False, wear_leveling=False)
    assert h2.wear_tiers() == []


# =============================================================================
# satellite: bf16 host pools store the uint16 bit-pattern, not float32
# =============================================================================

def test_bf16_host_pool_stores_bitpattern():
    s = TierStore(TierConfig(n_pages=4, fast_slots=2, slow_slots=4,
                             page_shape=(8,), dtype=jnp.bfloat16,
                             n_banks=1, n_slabs=2, track_wear=False))
    assert s.pools[1].data.dtype == np.uint16, \
        "bf16 host pool must hold uint16 bit-patterns, not widen to f32"
    rng = np.random.RandomState(0)
    vals = rng.standard_normal((4, 8)).astype(np.float32)
    for p in range(4):
        assert s.allocate(p, SLOW)
        s.write_page(p, vals[p])
    # round trip is exactly the bf16 quantization of the input (bit-exact
    # vs the device-pool cast), not a lossless f32 store
    for p in range(4):
        expect = vals[p].astype(jnp.bfloat16).astype(np.float32)
        np.testing.assert_array_equal(s.read_page(p), expect)
    # the batched path hits the same bits
    batch = rng.standard_normal((2, 8)).astype(np.float32)
    s.slow_write_batch(np.array([0, 2]), batch)
    np.testing.assert_array_equal(
        s.slow_read_batch(np.array([0, 2])),
        batch.astype(jnp.bfloat16).astype(np.float32))


def test_bf16_migration_roundtrip_bitexact():
    """fast(bf16) -> host(uint16 bits) -> fast loses nothing beyond the
    initial bf16 cast."""
    s = TierStore(TierConfig(n_pages=6, fast_slots=6, slow_slots=6,
                             page_shape=(4,), dtype=jnp.bfloat16,
                             n_banks=1, n_slabs=2))
    rng = np.random.RandomState(1)
    vals = rng.standard_normal((6, 4)).astype(np.float32)
    for p in range(6):
        assert s.allocate(p, FAST)
        s.write_page(p, vals[p])
    first = np.stack([s.read_page(p) for p in range(6)])
    eng = BatchedMigrationEngine(s, chunk_pages=2)
    eng.migrate_optimistic(range(6), SLOW)
    eng.migrate_locked(range(6), FAST)
    after = np.stack([s.read_page(p) for p in range(6)])
    np.testing.assert_array_equal(first, after)


# =============================================================================
# satellite: color-geometry clamping warns instead of silently rewriting
# =============================================================================

def test_color_geometry_clamp_warns():
    with pytest.warns(UserWarning, match="clamped"):
        s = TierStore(TierConfig(n_pages=16, fast_slots=8, slow_slots=16,
                                 page_shape=(2,), n_banks=32, n_slabs=16))
    # the shrink loop halves banks first, then slabs, until every color
    # exists in the smallest pool
    assert s.cfg.n_banks * s.cfg.n_slabs <= 8
    assert (s.cfg.n_banks, s.cfg.n_slabs) == (1, 8)
    assert s.alloc[FAST].cfg.n_colors == 8


def test_color_geometry_fits_no_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = TierStore(TierConfig(n_pages=16, fast_slots=8, slow_slots=16,
                                 page_shape=(2,), n_banks=2, n_slabs=4))
    assert (s.cfg.n_banks, s.cfg.n_slabs) == (2, 4)


def test_color_geometry_default_autosizes_silently():
    """The default geometry (n_banks/n_slabs unset) adapts to the
    smallest pool without warning — only an explicit request that can't
    fit warns."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = TierStore(TierConfig(n_pages=16, fast_slots=8, slow_slots=16,
                                 page_shape=(2,)))
    assert s.cfg.n_banks * s.cfg.n_slabs <= 8
    assert s.cfg.n_banks >= 1 and s.cfg.n_slabs >= 1


# =============================================================================
# 3-tier store: moves across every tier pair, engine parity, invariants
# =============================================================================

def assert_alloc_invariants(s: TierStore):
    for tier in range(s.n_tiers):
        cap = s.hierarchy[tier].slots
        live = np.nonzero((s.slot != NO_SLOT) & (s.tier == tier))[0]
        slots = s.slot[live]
        assert len(set(slots.tolist())) == live.size, \
            f"tier {tier}: two pages share a physical slot"
        assert ((slots >= 0) & (slots < cap)).all()
        assert s.alloc[tier].n_free == cap - live.size, \
            f"tier {tier}: allocator free count disagrees with page table"


@pytest.mark.parametrize("quantize", [False, True])
def test_three_tier_moves_preserve_contents(quantize):
    s = make_3tier_store(quantize_nvm=quantize)
    eng = BatchedMigrationEngine(s, chunk_pages=3)
    expect = {p: s.read_page(p).copy() for p in range(24)}
    # walk pages through every boundary: 2->0 (host->device), 0->1
    # (device->device), 1->2 (device->host), 2->1, 1->0
    for pages, dst in ([range(8), 0], [range(4), 1], [range(4), 2],
                       [range(2), 1], [range(2), 0]):
        eng.migrate_locked(pages, dst)
        assert_alloc_invariants(s)
    tol = (1 / 127 + 1e-6) if quantize else 0.0
    for p in range(24):
        np.testing.assert_allclose(s.read_page(p), expect[p], atol=2 * tol)
    # every pair the walk crossed shows traffic
    for pair in [(2, 0), (0, 1), (1, 2), (2, 1), (1, 0)]:
        assert s.traffic[pair] > 0, f"no traffic across {pair}"


def test_three_tier_engine_parity():
    """Reference and batched engines stay in lockstep on a 3-tier store."""
    ref_s = make_3tier_store(seed=3)
    bat_s = make_3tier_store(seed=3)
    ref = MigrationEngine(ref_s)
    bat = BatchedMigrationEngine(bat_s, chunk_pages=3)
    rng = np.random.RandomState(4)
    for round_ in range(10):
        pages = rng.choice(24, size=rng.randint(1, 10), replace=False)
        dst = int(rng.randint(3))
        locked = rng.rand() < 0.5
        st_r = (ref.migrate_locked if locked else
                ref.migrate_optimistic)(pages, dst)
        st_b = (bat.migrate_locked if locked else
                bat.migrate_optimistic)(pages, dst)
        assert (st_r.migrated, st_r.to_fast, st_r.to_slow) == \
            (st_b.migrated, st_b.to_fast, st_b.to_slow), f"round {round_}"
        np.testing.assert_array_equal(ref_s.tier, bat_s.tier)
        np.testing.assert_array_equal(ref_s.slot, bat_s.slot)
        for p in range(24):
            np.testing.assert_array_equal(ref_s.read_page(p),
                                          bat_s.read_page(p))
        assert ref_s.traffic == bat_s.traffic
        assert_alloc_invariants(bat_s)


def test_target_tier_three_level_utility_split():
    """Hot pages -> tier 0; warm read-heavy pages fill the DRAM-sim
    middle tier by benefit; cold pages sink to NVM."""
    h = MemoryHierarchy.three_tier(4, 2, 16)
    n = 8
    wd = np.full(n, 0, np.int8)
    hot = np.zeros(n, bool)
    hot[:2] = True                       # pages 0,1 demand tier 0
    future = np.zeros(n, np.int8)
    reuse = np.zeros(n, np.int8)
    reads = np.array([9, 9, 50, 40, 3, 2, 0, 0])
    writes = np.zeros(n, np.int64)
    tgt = target_tier(wd, hot, future, reuse, hierarchy=h,
                      reads=reads, writes=writes)
    assert tgt[0] == 0 and tgt[1] == 0
    # the 2-slot middle tier takes the two highest-benefit tolerant pages
    assert tgt[2] == 1 and tgt[3] == 1
    assert (tgt[4:] == 2).all()
    # untouched pages never occupy an intermediate tier
    assert (tgt[6:] == 2).all()


def test_three_tier_memos_loop_distributes_and_migrates():
    """End to end: the memos loop on a 3-tier store promotes the hot set
    to HBM, parks the warm set in the DRAM-sim tier, sinks the cold set
    to NVM, and moves pages across both boundaries."""
    s = make_3tier_store(n=24, hbm=4, dram=6, nvm=24, seed=5)
    mgr = MemosManager(s, MemosConfig(interval=2, adaptive_interval=False))
    sm = sysmon.init(24, s.cfg.n_banks, s.cfg.n_slabs)
    expect = {p: s.read_page(p).copy() for p in range(24)}
    rng = np.random.RandomState(6)
    for step in range(24):
        phase = step // 12
        hot = jnp.arange(phase * 4, phase * 4 + 4)      # shifts once
        warm = jnp.asarray(rng.randint(8, 12, size=2))  # read-mostly
        sm = sysmon.record(sm, hot, is_write=True)
        sm = sysmon.record(sm, warm, is_write=False)
        sm, rep = mgr.maybe_step(sm)
    used = s.tier_used()
    assert used[0] > 0 and used[2] > 0
    assert sum(used) == 24
    # both hierarchy boundaries carried traffic during the run
    b01 = s.traffic[(0, 1)] + s.traffic[(1, 0)]
    b12 = s.traffic[(1, 2)] + s.traffic[(2, 1)]
    b02 = s.traffic[(0, 2)] + s.traffic[(2, 0)]
    assert b01 + b02 > 0, "nothing crossed the HBM boundary"
    assert b12 + b02 > 0, "nothing crossed the NVM boundary"
    # the current hot set ends HBM-resident; contents survive everything
    assert all(int(s.tier[p]) == 0 for p in range(4, 8))
    for p in range(24):
        np.testing.assert_array_equal(s.read_page(p), expect[p])


# =============================================================================
# pinned-host tiers: device-addressable slow pool
# =============================================================================

def two_tier_store(pinned, n=16, fast=4, slow=16, quantize=False, **kw):
    h = MemoryHierarchy.two_tier(fast, slow, pinned_slow=pinned,
                                 quantize_slow=quantize, **kw)
    s = TierStore(StoreConfig(n_pages=n, page_shape=(4, 2), hierarchy=h,
                              n_banks=2, n_slabs=2))
    rng = np.random.RandomState(11)
    for p in range(n):
        assert s.allocate(p, h.deepest)
        s.write_page(p, rng.standard_normal((4, 2)).astype(np.float32))
    return s


def test_pinned_tier_migration_matches_host_tier():
    """A pinned-host slow tier behaves exactly like the numpy host tier
    under the batched engine — same page table, same contents, same wear
    accounting — it just never leaves the jax runtime."""
    host = two_tier_store(pinned=False)
    pin = two_tier_store(pinned=True)
    assert pin.is_pinned_tier(1) and pin.is_addressable_tier(1)
    assert not pin.is_device_tier(1)
    ref_eng = BatchedMigrationEngine(host, chunk_pages=3)
    pin_eng = BatchedMigrationEngine(pin, chunk_pages=3)
    rng = np.random.RandomState(12)
    for _ in range(8):
        pages = rng.choice(16, size=rng.randint(1, 8), replace=False)
        dst = int(rng.randint(2))
        locked = rng.rand() < 0.5
        (ref_eng.migrate_locked if locked else
         ref_eng.migrate_optimistic)(pages, dst)
        (pin_eng.migrate_locked if locked else
         pin_eng.migrate_optimistic)(pages, dst)
        np.testing.assert_array_equal(host.tier, pin.tier)
        np.testing.assert_array_equal(host.slot, pin.slot)
        for p in range(16):
            np.testing.assert_array_equal(host.read_page(p),
                                          pin.read_page(p))
    np.testing.assert_array_equal(host.wear.wear_counts(),
                                  pin.wear.wear_counts())
    assert host.wear.writes_total == pin.wear.writes_total
    pin.wear.check()


@pytest.mark.parametrize("pinned", [False, True])
def test_quantized_slow_tier_roundtrip(pinned):
    """int8 quantization through the pinned pool's fused
    gather/scatter kernels matches the numpy host pool's quantizer
    (demotion gather fuses the quantize on device: one kernel)."""
    s = two_tier_store(pinned=pinned, quantize=True, track_wear=False)
    eng = BatchedMigrationEngine(s, chunk_pages=3)
    expect = {p: s.read_page(p).copy() for p in range(16)}
    eng.migrate_locked(range(4), 0)       # dequantized promotion
    eng.migrate_optimistic(range(4), 1)   # requantized demotion
    tol = 2 * (1 / 127 + 1e-6)
    for p in range(16):
        np.testing.assert_allclose(s.read_page(p), expect[p], atol=5 * tol)


def test_pinned_leveling_rotation_preserves_contents():
    """Start-Gap leveling rotates the pinned jax pool underneath stable
    logical slots: contents survive arbitrary rotation, the remap stays a
    permutation, leveling writes are charged."""
    s = two_tier_store(pinned=True, gap_write_interval=3)
    expect = {p: s.read_page(p).copy() for p in range(16)}
    rng = np.random.RandomState(13)
    for i in range(30):                       # drive many advances
        p = int(rng.randint(16))
        v = rng.standard_normal((4, 2)).astype(np.float32)
        s.write_page(p, v)
        expect[p] = s.read_page(p).copy()
    assert s.leveler.stats.advances > 0, "leveler never advanced"
    assert s.wear.leveling_writes == 2 * s.leveler.stats.advances
    s.wear.check()
    for p in range(16):
        np.testing.assert_array_equal(s.read_page(p), expect[p])


# =============================================================================
# satellite: per-tier allocator color geometry
# =============================================================================

def test_per_tier_allocator_geometry():
    """Each tier's allocator geometry derives from its own pool size: a
    small HBM tier no longer collapses a large NVM tier's color space
    (the monitor geometry still clamps to the smallest pool)."""
    s = TierStore(StoreConfig(
        n_pages=64, page_shape=(2,),
        hierarchy=MemoryHierarchy.two_tier(8, 512)))
    # monitor geometry: sized to the smallest pool, as before
    assert s.cfg.n_banks * s.cfg.n_slabs <= 8
    # tier-0 allocator matches its 8-slot pool; the 512-slot tier keeps
    # the full default 32 x 16 grid
    assert s.alloc[0].cfg.n_colors <= 8
    assert (s.alloc[1].cfg.n_banks, s.alloc[1].cfg.n_slabs) == (32, 16)
    # explicit geometry that fits everywhere is used verbatim per tier
    s2 = TierStore(StoreConfig(
        n_pages=16, page_shape=(2,),
        hierarchy=MemoryHierarchy.two_tier(8, 512), n_banks=2, n_slabs=4))
    assert (s2.alloc[0].cfg.n_banks, s2.alloc[0].cfg.n_slabs) == (2, 4)
    assert (s2.alloc[1].cfg.n_banks, s2.alloc[1].cfg.n_slabs) == (2, 4)


# =============================================================================
# satellite: bandwidth-aware spill / cascade targeting
# =============================================================================

def test_backing_tier_order_ranks_by_headroom():
    h = MemoryHierarchy(tiers=(
        MediumSpec("HBM", 4, cm.HBM, residency="device"),
        MediumSpec("DRAM", 8, cm.DRAM, residency="device",
                   bandwidth_gbps=0.001),          # tiny channel
        MediumSpec("NVM", 16, cm.NVM, residency="host",
                   bandwidth_gbps=1000.0),
    ))
    s = TierStore(StoreConfig(n_pages=16, page_shape=(4,), hierarchy=h,
                              n_banks=2, n_slabs=2))
    # nothing has flowed yet: plain tier order
    assert s.backing_tier_order() == [1, 2]
    # saturate the DRAM channel's window -> NVM has more headroom
    s.traffic[(0, 1)] += 10 * s.page_nbytes
    assert s.backing_tier_order() == [2, 1]
    # rolling the window forgives the old traffic
    s.roll_traffic_window()
    assert s.backing_tier_order() == [1, 2]


def test_new_page_cascade_prefers_headroom():
    from repro.serving.kv_cache import PagedKVCache, PagedKVConfig
    h = MemoryHierarchy(tiers=(
        MediumSpec("HBM", 2, cm.HBM, residency="device"),
        MediumSpec("DRAM", 4, cm.DRAM, residency="device",
                   bandwidth_gbps=0.001),
        MediumSpec("NVM", 16, cm.NVM, residency="host",
                   bandwidth_gbps=1000.0),
    ))
    kv = PagedKVCache(PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=2,
                                    page_size=2, hierarchy=h, n_pages=16))
    s = kv.store
    # fill the serving tier
    assert kv.new_page() is not None and kv.new_page() is not None
    # saturated DRAM channel: the cascade skips it for the NVM tier
    s.traffic[(0, 1)] += 100 * s.page_nbytes
    pid = kv.new_page()
    assert pid is not None and int(s.tier[pid]) == 2
    # with the window rolled the middle tier is preferred again
    s.roll_traffic_window()
    pid2 = kv.new_page()
    assert pid2 is not None and int(s.tier[pid2]) == 1


def test_memos_spill_targets_headroom_tier():
    """The bandwidth balancer's spill lands in the backing tier with the
    most channel headroom, not blindly in tier 1."""
    h = MemoryHierarchy(tiers=(
        MediumSpec("HBM", 8, cm.HBM, residency="device"),
        MediumSpec("DRAM", 8, cm.DRAM, residency="device",
                   bandwidth_gbps=0.001),
        MediumSpec("NVM", 32, cm.NVM, residency="host",
                   bandwidth_gbps=1000.0),
    ))
    s = TierStore(StoreConfig(n_pages=16, page_shape=(4,), hierarchy=h,
                              n_banks=2, n_slabs=2))
    for p in range(8):
        assert s.allocate(p, 0)
        s.write_page(p, np.full(4, p, np.float32))
    s.traffic[(0, 1)] += 100 * s.page_nbytes     # DRAM channel saturated
    mgr = MemosManager(s, MemosConfig(interval=1, adaptive_interval=False))
    sm = sysmon.init(16, s.cfg.n_banks, s.cfg.n_slabs)
    # read-dominated tier-0 pages + saturated fast channel -> spill
    sm = sysmon.record(sm, jnp.arange(8, dtype=jnp.int32), is_write=False)
    sm, rep = mgr.maybe_step(sm, fast_bw_util=0.99)
    assert rep is not None and rep.spilled > 0, "balancer never spilled"
    spilled_tiers = {int(t) for t in s.tier[:8] if int(t) != 0}
    assert spilled_tiers == {2}, \
        f"spill ignored bandwidth headroom (landed in {spilled_tiers})"


# =============================================================================
# wear/energy telemetry attaches per wear_tracked tier
# =============================================================================

def test_wear_attaches_to_any_wear_tracked_tier():
    """A hierarchy with two wear-tracked host tiers gets two independent
    trackers and two energy meters feeding the memos report."""
    h = MemoryHierarchy(tiers=(
        MediumSpec("HBM", 4, cm.HBM, residency="device"),
        MediumSpec("CXL-NVM", 8, cm.NVM, residency="host",
                   wear_tracked=True),
        MediumSpec("NVM", 16, cm.NVM, residency="host", wear_tracked=True,
                   wear_leveling=True, gap_write_interval=4),
    ))
    s = TierStore(StoreConfig(n_pages=16, page_shape=(4,), hierarchy=h,
                              n_banks=2, n_slabs=2))
    assert set(s.wear_by_tier) == {1, 2}
    assert set(s.leveler_by_tier) == {2}
    for p in range(16):
        assert s.allocate(p, 2)
        s.write_page(p, np.full(4, p, np.float32))
    eng = BatchedMigrationEngine(s)
    eng.migrate_locked(range(4), 1)      # demotion commits charge tier 1
    assert s.wear_by_tier[1].writes_total == 4
    assert s.wear_by_tier[2].writes_total == 16
    s.write_page(0, np.zeros(4, np.float32))   # page 0 now lives in tier 1
    assert s.wear_by_tier[1].writes_total == 5
    mgr = MemosManager(s, MemosConfig(interval=1, adaptive_interval=False))
    assert set(mgr.meters) == {1, 2}
    # meters report per-pass deltas: writes landing after meter creation
    s.write_page(1, np.zeros(4, np.float32))   # tier 1
    s.write_page(8, np.zeros(4, np.float32))   # tier 2
    sm = sysmon.init(16, s.cfg.n_banks, s.cfg.n_slabs)
    sm = sysmon.record(sm, jnp.asarray([0, 1]), is_write=True)
    sm, rep = mgr.maybe_step(sm)
    assert set(rep.nvm_by_tier) == {1, 2}
    assert rep.nvm is rep.nvm_by_tier[2]        # compat alias: deepest
    assert rep.nvm_by_tier[1].slow_writes >= 1
    assert rep.nvm_by_tier[2].slow_writes >= 1
    assert rep.nvm_by_tier[1].wear_max >= 1
    s.wear_by_tier[1].check()
    s.wear_by_tier[2].check()
