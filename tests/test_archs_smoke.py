"""Per-arch smoke tests: reduced same-family configs, one forward/train
step on CPU, shape + finiteness asserts, and prefill/decode consistency
against the teacher-forced forward pass (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, registry, smoke
from repro.models import transformer as T

REG = registry()


def _batches(sc, B=2, S=16, extra=4):
    if sc.input_mode == "embeds":
        full = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                            (B, S + extra, sc.d_model))}
        batch = {"embeds": full["embeds"][:, :S]}
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra),
                                  0, sc.vocab)
        full = {"tokens": toks}
        batch = {"tokens": toks[:, :S]}
    return full, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    sc = smoke(REG[arch_id])
    params = T.init_params(sc, jax.random.PRNGKey(0))
    B, S = 2, 16
    _, batch = _batches(sc, B, S)
    tb = dict(batch, labels=jnp.zeros((B, S), jnp.int32))

    loss, metrics = T.loss_fn(params, sc, tb)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"

    h, _ = T.forward_hidden(params, sc, batch)
    assert h.shape == (B, S, sc.d_model)
    logits = T.logits_out(params, sc, h)
    assert logits.shape[-1] >= sc.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))

    grads, _ = jax.grad(lambda p: T.loss_fn(p, sc, tb), has_aux=True)(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (arch_id, path)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads)) ** 0.5
    assert gn > 0, f"{arch_id}: zero gradient"

    if sc.is_moe:
        assert "expert_counts" in metrics
        assert int(metrics["expert_counts"].sum()) == B * S * sc.top_k * sc.n_layers


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_matches_forward(arch_id):
    """Prefill + step-by-step decode must reproduce teacher-forced logits."""
    sc = smoke(REG[arch_id])
    params = T.init_params(sc, jax.random.PRNGKey(0))
    B, S, extra = 2, 16, 3
    full, batch = _batches(sc, B, S, extra)

    h, _ = T.forward_hidden(params, sc, full)
    flogits = T.logits_out(params, sc, h)

    lg, state = T.prefill(params, sc, batch, cache_len=S + extra + 1)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(flogits[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(extra):
        nb = ({"tokens": full["tokens"][:, S + t:S + t + 1]}
              if "tokens" in full
              else {"embeds": full["embeds"][:, S + t:S + t + 1]})
        lg, state = T.decode_step(params, sc, state, nb)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(flogits[:, S + t]),
                                   rtol=2e-4, atol=2e-4)


def test_sliding_window_cache_is_ring_buffer():
    """A windowed cache of size W must reproduce full-cache logits once the
    context exceeds W (mixtral SWA / gemma3 local layers at 500k rely on it)."""
    sc = smoke(REG["mixtral_8x7b"])
    assert sc.sliding_window == 16
    params = T.init_params(sc, jax.random.PRNGKey(0))
    B, S, extra = 1, 24, 4  # S > window
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0, sc.vocab)
    h, _ = T.forward_hidden(params, sc, {"tokens": toks})
    flogits = T.logits_out(params, sc, h)
    # cache_len larger than window: windowed layers still clamp to W=16
    lg, state = T.prefill(params, sc, {"tokens": toks[:, :S]}, cache_len=64)
    assert state["attn"][0]["k"].shape[1] == 16  # ring buffer of window size
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(flogits[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(extra):
        lg, state = T.decode_step(params, sc, state,
                                  {"tokens": toks[:, S + t:S + t + 1]})
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(flogits[:, S + t]),
                                   rtol=2e-4, atol=2e-4)


def test_param_counts_full_configs():
    """Full configs approximate their published sizes (sanity, no alloc)."""
    expected = {
        "olmoe_1b_7b": (6.5e9, 7.5e9),
        "mixtral_8x7b": (45e9, 48e9),
        "qwen2_vl_72b": (65e9, 75e9),
        "qwen2_5_14b": (13e9, 16e9),
        "phi3_mini_3_8b": (3.3e9, 4.3e9),
        "qwen3_4b": (3.5e9, 4.5e9),
        "gemma3_4b": (3.2e9, 4.8e9),
        "zamba2_7b": (6e9, 8.5e9),
        "mamba2_1_3b": (1.0e9, 1.6e9),
        "musicgen_medium": (1.3e9, 2.2e9),
    }
    for a, (lo, hi) in expected.items():
        n = REG[a].param_count()
        assert lo <= n <= hi, f"{a}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
