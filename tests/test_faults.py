"""Fault-injection subsystem: checksums, quarantine, retries, watchdog.

Covers the detection/recovery contract end to end at store and memos
granularity (the serving-level storm lives in benchmarks/fault_storm.py
and its CI smoke):

* the page checksum detects every injected single-bit flip across the
  host storage formats (bf16-as-uint16 numpy pages, float32 numpy pages,
  int8 pinned jax pages) and never fires on a clean round trip;
* the injector is deterministic per seed and inert when disabled;
* bad-slot quarantine retires the slot from the allocator permanently
  (no re-allocation, no free) while the allocator's partition invariant
  holds;
* migration bulk moves retry injected transient faults with backoff and
  fail closed (reservations returned, pages left in place) when the
  retry budget is exhausted;
* the async-plan watchdog converts injected worker exceptions, hangs,
  and artificial delays into synchronous fallbacks, and the degradation
  ladder demotes/re-promotes on the configured streaks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, obs
from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import make_engine
from repro.core.tiers import NO_SLOT, StoreConfig, TierConfig, TierStore
from repro.core.hierarchy import MemoryHierarchy
from repro.faults import (RUNG_OFF, RUNG_OVERLAP, RUNG_SYNC,
                          DegradationLadder, FaultConfig, FaultInjector)
from repro.kernels.page_checksum import checksum_np, page_checksum_ref


@pytest.fixture(autouse=True)
def _clean_global_state():
    faults.reset()
    obs.reset()
    yield
    faults.reset()
    obs.reset()


def make_store(seed=0, dtype=jnp.float32, enabled=True):
    """A populated two-tier store (numpy slow pool); the injector must be
    configured *before* construction — TierStore latches
    ``get_injector().enabled`` into its PageIntegrity."""
    if enabled:
        faults.configure(FaultConfig(seed=seed))
    store = TierStore(TierConfig(
        n_pages=32, fast_slots=8, slow_slots=32, page_shape=(8,),
        dtype=dtype, n_banks=2, n_slabs=4, gap_write_interval=5))
    rng = np.random.RandomState(seed)
    for p in range(32):
        assert store.allocate(p, int(store.tier[p]))
        store.write_page(p, rng.standard_normal(8).astype(np.float32))
    return store


def make_pinned_store(seed=0, quantize=False):
    """Two-tier store whose slow pool is a pinned-host jax buffer."""
    faults.configure(FaultConfig(seed=seed))
    hier = MemoryHierarchy.two_tier(8, 32, pinned_slow=True,
                                    quantize_slow=quantize,
                                    gap_write_interval=5)
    store = TierStore(StoreConfig(n_pages=32, page_shape=(8,),
                                  hierarchy=hier, n_banks=2, n_slabs=4))
    rng = np.random.RandomState(seed)
    for p in range(32):
        assert store.allocate(p, int(store.tier[p]))
        store.write_page(p, rng.standard_normal(8).astype(np.float32))
    return store


def slow_slots_of(store):
    t = store.hierarchy.deepest
    live = np.nonzero((store.tier == t) & (store.slot != NO_SLOT))[0]
    return t, [int(store.slot[p]) for p in live], live


# =============================================================================
# checksum kernel + integrity properties
# =============================================================================

def test_checksum_ref_matches_numpy_across_dtypes():
    rng = np.random.RandomState(0)
    for dt in (np.float32, np.uint16, np.int8):
        pages = (rng.standard_normal((4, 16)) * 64).astype(dt)
        np.testing.assert_array_equal(
            checksum_np(pages), np.asarray(page_checksum_ref(jnp.asarray(pages))))


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_checksum_catches_every_flip_host_pool(seed, dtype):
    """Seeded sweep (hypothesis is unavailable): on bf16-as-uint16 and
    float32 numpy host pages, every injected single-bit flip is caught by
    ``verify`` and the un-flipped page never false-positives."""
    store = make_store(seed=seed, dtype=dtype)
    t, slots, _ = slow_slots_of(store)
    assert slots and store.integrity.enabled
    assert store.integrity.verify(store, t, slots) == []
    pool = store.pools[t]
    row_bytes = FaultInjector._row_bytes(pool)
    rng = np.random.RandomState(100 + seed)
    for _ in range(20):
        s = int(rng.choice(slots))
        phys = int(store._phys(t, np.asarray([s]))[0])
        byte, bit = int(rng.randint(row_bytes)), int(rng.randint(8))
        FaultInjector._xor_bit(pool, phys, byte, bit)
        assert store.integrity.verify(store, t, slots) == [s], \
            f"missed flip at slot {s} byte {byte} bit {bit}"
        FaultInjector._xor_bit(pool, phys, byte, bit)    # undo
        assert store.integrity.verify(store, t, slots) == []


@pytest.mark.parametrize("quantize", [False, True])
def test_checksum_catches_every_flip_pinned_pool(quantize):
    """Same property on a pinned-host jax pool (native bf16/float32 or
    fused-int8 rows): the checksum dispatch over stored bits agrees with
    the record taken at write time, and any single-bit flip breaks it."""
    store = make_pinned_store(seed=3, quantize=quantize)
    t, slots, _ = slow_slots_of(store)
    assert slots and store.integrity.covers(store, t)
    assert store.integrity.verify(store, t, slots) == []
    pool = store.pools[t]
    row_bytes = FaultInjector._row_bytes(pool)
    rng = np.random.RandomState(9)
    for _ in range(8):
        s = int(rng.choice(slots))
        phys = int(store._phys(t, np.asarray([s]))[0])
        byte, bit = int(rng.randint(row_bytes)), int(rng.randint(8))
        FaultInjector._xor_bit(pool, phys, byte, bit)
        assert store.integrity.verify(store, t, slots) == [s]
        FaultInjector._xor_bit(pool, phys, byte, bit)
        assert store.integrity.verify(store, t, slots) == []


def test_checksum_stable_under_wear_remap():
    """Start-Gap physically relocates rows but carries the data: the
    (tier, logical slot) checksum must survive leveler advances."""
    store = make_store(seed=4)
    t, slots, _ = slow_slots_of(store)
    lv = store.leveler_by_tier.get(t)
    assert lv is not None
    # hammer host writes until several gap advances have happened
    rng = np.random.RandomState(2)
    _, _, live = slow_slots_of(store)
    for _ in range(64):
        p = int(rng.choice(live))
        store.write_page(p, rng.standard_normal(8).astype(np.float32))
    assert lv.stats.advances > 0
    t, slots, _ = slow_slots_of(store)
    assert store.integrity.verify(store, t, slots) == []


def test_scrub_finds_and_injection_disabled_is_inert():
    store = make_store(seed=5)
    t, slots, _ = slow_slots_of(store)
    pool = store.pools[t]
    phys = int(store._phys(t, np.asarray([slots[0]]))[0])
    FaultInjector._xor_bit(pool, phys, 0, 3)
    # round-robin scrub over all recorded slots must surface it
    bad = []
    for _ in range(8):
        bad += store.integrity.scrub(store, budget=8)
    assert (t, slots[0]) in bad
    # disabled build: integrity never records, verify/scrub are no-ops
    faults.reset()
    store2 = make_store(enabled=False)
    assert not store2.integrity.enabled and store2.integrity.sums == {}
    t2, slots2, _ = slow_slots_of(store2)
    assert store2.integrity.verify(store2, t2, slots2) == []
    assert store2.integrity.scrub(store2, budget=8) == []


# =============================================================================
# injector determinism + media model
# =============================================================================

def test_injector_deterministic_per_seed_and_inert_when_disabled():
    cfg = FaultConfig(seed=11, media_flip_rate=0.2, media_stuck_rate=0.05)
    outs = []
    for _ in range(2):
        store = make_store(seed=1)
        inj = FaultInjector(cfg)
        n = sum(inj.tick(store) for _ in range(5))
        t = store.hierarchy.deepest
        outs.append((n, dict(inj.counts), store.pools[t].data.copy()))
    assert outs[0][0] == outs[1][0] > 0
    assert outs[0][1] == outs[1][1]
    np.testing.assert_array_equal(outs[0][2], outs[1][2])

    store = make_store(seed=1)
    t = store.hierarchy.deepest
    before = store.pools[t].data.copy()
    off = FaultInjector(None)
    assert off.tick(store) == 0 and off.total_injected == 0
    np.testing.assert_array_equal(before, store.pools[t].data)


def test_stuck_at_faults_reassert_after_rewrite():
    store = make_store(seed=6)
    inj = FaultInjector(FaultConfig(seed=6, media_stuck_rate=0.3))
    for _ in range(4):
        inj.tick(store)
    assert inj.counts["media_stuck"] > 0
    t = store.hierarchy.deepest
    tier_faults = inj._stuck.get(t)
    assert tier_faults, "no stuck-at fault registered on the slow tier"
    phys, byte, bit, val = tier_faults[0]
    # rewrite the whole row clean, then tick: the bit re-asserts
    flat = store.pools[t].data[phys].view(np.uint8).reshape(-1)
    flat[byte] = np.uint8(0 if val else 0xFF)
    inj.tick(store)
    assert (int(flat[byte]) >> bit) & 1 == val


def test_wear_bias_targets_worn_slots():
    """Fault probability scales with per-slot wear: a heavily-worn row
    collects more flips than pristine rows over many ticks."""
    store = make_store(seed=7)
    t = store.hierarchy.deepest
    w = store.wear_by_tier[t]
    _, _, live = slow_slots_of(store)
    hot = int(live[0])
    hot_phys = int(store._phys(t, store.slot[[hot]].astype(np.int64))[0])
    w.record_phys(np.repeat(hot_phys, 500))      # pre-worn slot
    inj = FaultInjector(FaultConfig(seed=7, media_flip_rate=0.02,
                                    wear_bias=50.0))
    per_row = np.zeros(store.pools[t].data.shape[0], np.int64)
    for _ in range(40):
        before = store.pools[t].data.copy()
        inj.tick(store)
        diff = np.nonzero((before != store.pools[t].data).any(axis=1))[0]
        per_row[diff] += 1
    assert inj.counts["media_flip"] > 0
    others = np.delete(per_row, hot_phys)
    assert per_row[hot_phys] > others.mean() * 2, \
        f"wear bias ignored: hot row {per_row[hot_phys]} hits vs " \
        f"per-row mean {others.mean():.1f}"


# =============================================================================
# quarantine + allocator retire
# =============================================================================

def test_quarantine_retires_slot_and_unbinds_page():
    store = make_store(seed=8)
    t, slots, live = slow_slots_of(store)
    s, owner = slots[0], int(live[0])
    n_free = store.alloc[t].n_free
    assert store.quarantine_slot(t, s, reason="test")
    assert s in store.quarantined[t]
    assert int(store.slot[owner]) == NO_SLOT
    assert owner in store.quarantine_log
    assert (t, s) not in store.integrity.sums
    assert store.quarantine_slot(t, s) is False          # idempotent
    with pytest.raises(ValueError, match="quarantined"):
        store.alloc[t].free(s, 0)
    store.alloc[t].check_consistency()
    # the slot is never handed out again, even draining the whole pool
    got = []
    while True:
        g = store.alloc[t].alloc(0)
        if g is None:
            break
        got.append(g)
    assert s not in got
    assert store.alloc[t].n_free == 0 and n_free == len(got)
    assert store.alloc[t].n_retired == 1


def test_alloc_injection_drives_allocate_failures():
    store = make_store(seed=9)
    faults.configure(FaultConfig(alloc_fail_rate=1.0))
    p = int(np.nonzero(store.slot == NO_SLOT)[0][0]) if \
        (store.slot == NO_SLOT).any() else None
    if p is None:
        store.release(0)
        p = 0
    assert store.allocate(p, store.hierarchy.deepest) is False
    faults.configure(FaultConfig(alloc_fail_rate=0.0))
    assert store.allocate(p, store.hierarchy.deepest) is True


# =============================================================================
# migration retry / fail-closed
# =============================================================================

def test_migration_retries_transient_faults_then_fails_closed():
    # rate 1.0: every attempt of every group fails -> fail closed
    store = make_store(seed=10)
    faults.configure(FaultConfig(seed=10, migrate_fail_rate=1.0))
    eng = make_engine(store, "batched")
    eng.retry_backoff_s = 1e-6
    t, _, live = slow_slots_of(store)
    pages = [int(p) for p in live[:4]]
    before = [(int(store.tier[p]), int(store.slot[p])) for p in pages]
    st = eng.migrate_locked(pages, 0)
    assert st.migrated == 0 and st.failed >= len(pages)
    after = [(int(store.tier[p]), int(store.slot[p])) for p in pages]
    assert before == after, "failed move must leave pages in place"
    for tt in range(store.n_tiers):
        store.alloc[tt].check_consistency()

    # mid rate with a deep retry budget: the storm is ridden out
    store2 = make_store(seed=10)
    faults.configure(FaultConfig(seed=10, migrate_fail_rate=0.5))
    eng2 = make_engine(store2, "batched")
    eng2.retry_backoff_s = 1e-6
    eng2.max_retries = 12
    _, _, live2 = slow_slots_of(store2)
    st2 = eng2.migrate_locked([int(p) for p in live2[:4]], 0)
    assert st2.migrated == 4 and st2.failed == 0
    inj = faults.get_injector()
    assert inj.counts["migrate"] > 0
    assert obs.get_registry().counter(
        "faults.recovered_migrate_retry").value > 0


def test_promotion_preflight_quarantines_corrupt_source():
    """A corrupt slow-tier page must never be promoted: the pre-flight
    verify quarantines its slot, the owner lands in quarantine_log, and
    the remaining planned pages still move."""
    store = make_store(seed=12)
    faults.configure(FaultConfig(seed=12))   # enabled, no rates
    eng = make_engine(store, "batched")
    t, slots, live = slow_slots_of(store)
    victim = int(live[0])
    vslot = int(store.slot[victim])
    phys = int(store._phys(t, np.asarray([vslot]))[0])
    FaultInjector._xor_bit(store.pools[t], phys, 1, 5)
    pages = [int(p) for p in live[:4]]
    st = eng.migrate_locked(pages, 0)
    assert st.failed == 1 and st.migrated == len(pages) - 1
    assert int(store.slot[victim]) == NO_SLOT
    assert victim in store.quarantine_log
    assert vslot in store.quarantined[t]
    for p in pages[1:]:
        assert int(store.tier[p]) == 0
    for tt in range(store.n_tiers):
        store.alloc[tt].check_consistency()


# =============================================================================
# watchdog + degradation ladder
# =============================================================================

def record4(sm, seed=7):
    rng = np.random.RandomState(seed)
    for _ in range(4):
        sm = sysmon.record(sm, jnp.asarray(np.arange(6), jnp.int32),
                           is_write=True)
        sm = sysmon.record(sm, jnp.asarray(rng.randint(20, 32, 3), jnp.int32),
                           is_write=False)
    return sm


def mk_mgr(store, **kw):
    return MemosManager(store, MemosConfig(
        interval=4, adaptive_interval=False, async_plan=True,
        plan_timeout_s=kw.pop("plan_timeout_s", 5.0),
        breaker_recovery_passes=kw.pop("recovery", 2), **kw))


def test_injected_plan_exception_falls_back_and_breaker_repromotes():
    store = make_store(seed=13)
    faults.configure(FaultConfig(seed=13, plan_exception_rate=1.0))
    mgr = mk_mgr(store)
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    sm = record4(sm)
    sm = mgr.begin_pass(sm)
    rep = mgr.commit_pending()
    assert rep.fault_fallback == "InjectedPlanFault"
    assert not rep.committed_async
    assert mgr.ladder.rung == RUNG_SYNC
    # the fallback produced a full synchronous pass; the pipeline is idle
    assert mgr._ticket is None
    # storm over: healthy sync passes re-promote after the streak
    faults.configure(FaultConfig(seed=13))
    for i in range(2):
        sm = record4(sm)
        sm, rep = mgr.maybe_step(sm, steps=4)
        assert rep is None or not rep.committed_async
    assert mgr.ladder.rung == RUNG_OVERLAP
    # and the next boundary overlaps again, committing cleanly
    sm = record4(sm)
    sm, _ = mgr.maybe_step(sm, steps=4)
    assert mgr._ticket is not None
    rep = mgr.flush()
    assert rep is not None and rep.committed_async
    assert rep.fault_fallback is None
    mgr.close()


def test_plan_hang_trips_watchdog_timeout():
    store = make_store(seed=14)
    faults.configure(FaultConfig(seed=14, plan_delay_rate=1.0,
                                 plan_delay_s=0.5))
    mgr = mk_mgr(store, plan_timeout_s=0.05)
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    sm = record4(sm)
    sm = mgr.begin_pass(sm)
    rep = mgr.commit_pending()
    assert rep.fault_fallback == "timeout"
    assert mgr.ladder.rung == RUNG_SYNC
    assert mgr._executor is None        # hung worker abandoned
    mgr.close()


def test_repeated_failures_walk_ladder_to_memos_off():
    store = make_store(seed=15)
    faults.configure(FaultConfig(seed=15, plan_exception_rate=1.0,
                                 migrate_fail_rate=1.0))
    mgr = mk_mgr(store)
    mgr.engine.retry_backoff_s = 1e-6
    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    rungs = []
    for _ in range(4):
        sm = record4(sm)
        sm, _ = mgr.maybe_step(sm, steps=4)
        rep = mgr.flush()
        rungs.append(mgr.ladder.rung)
    # overlap -> sync (plan fault) -> off (migration fault); OFF passes
    # are serve-only and count healthy, so the tail may start climbing
    assert rungs[0] == RUNG_SYNC and RUNG_OFF in rungs
    assert mgr.ladder.demotions >= 2
    mgr.close()


def test_ladder_unit_semantics():
    lad = DegradationLadder(top=RUNG_OVERLAP, recovery_passes=3)
    assert lad.rung == RUNG_OVERLAP and lad.rung_name == "overlap"
    assert lad.record_failure("x") and lad.rung == RUNG_SYNC
    assert lad.record_failure("y") and lad.rung == RUNG_OFF
    assert not lad.record_failure("z") and lad.rung == RUNG_OFF
    for _ in range(2):
        assert not lad.record_healthy()
    assert lad.record_healthy() and lad.rung == RUNG_SYNC
    lad.record_healthy()
    lad.record_failure("w")              # failure resets the streak
    assert lad.rung == RUNG_OFF
    assert lad.failures == ["x", "y", "z", "w"]
    assert lad.demotions == 3 and lad.promotions == 1
