"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.hotness_update import (sysmon_pass, sysmon_pass_ref,
                                          touch_update, touch_update_ref)
from repro.kernels.page_gather import (page_gather, page_gather_ref,
                                       page_scatter, page_scatter_ref)
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref, ssd_sequential_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 128, 4, 2, 64), (1, 256, 4, 4, 64), (2, 96, 8, 2, 80),
    (1, 64, 6, 3, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, Hq, Hkv, D, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, Hq, D), dtype)
    k = jax.random.normal(k2, (B, S, Hkv, D), dtype)
    v = jax.random.normal(k3, (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    qf = (q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
          * jnp.asarray(D ** -0.5, dtype))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    ref = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    ref = ref.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# --- paged decode attention -----------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,D,page,n_pages", [
    (3, 8, 2, 64, 16, 4), (2, 4, 4, 128, 8, 8), (1, 16, 2, 64, 32, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, Hq, Hkv, D, page, n_pages, dtype):
    n_slots = B * n_pages + 7
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (n_slots, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (n_slots, page, Hkv, D), dtype)
    bt = jax.random.permutation(ks[3], n_slots)[:B * n_pages]
    bt = bt.reshape(B, n_pages).astype(jnp.int32)
    lengths = jnp.asarray(
        np.random.RandomState(0).randint(1, page * n_pages + 1, B), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    G = Hq // Hkv
    qg = (q * jnp.asarray(D ** -0.5, dtype)).reshape(B, Hkv, G, D)
    ref = paged_attention_ref(qg, kp, vp, bt, lengths).reshape(B, Hq, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# --- SSD scan ---------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (2, 64, 4, 8, 16, 16), (1, 128, 8, 16, 32, 32), (2, 48, 2, 8, 8, 16),
])
def test_ssd_scan_sweep(B, L, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)
    # also against the sequential ground truth
    ys, hs = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ys),
                               atol=1e-3, rtol=1e-3)


def test_ssd_scan_padding():
    """Non-multiple L pads with identity steps."""
    B, L, H, P, N = 1, 37, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    ys, _ = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ys),
                               atol=1e-3, rtol=1e-3)


# --- page gather / scatter ------------------------------------------------------

@pytest.mark.parametrize("n_slots,k,shape", [(32, 4, (8, 4)), (64, 16, (16,)),
                                             (16, 16, (4, 4, 2))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_page_gather_scatter(n_slots, k, shape, dtype):
    pool = jnp.arange(n_slots * int(np.prod(shape))).reshape(
        (n_slots, *shape)).astype(dtype)
    idx = jax.random.permutation(jax.random.PRNGKey(4), n_slots)[:k]
    idx = idx.astype(jnp.int32)
    out = page_gather(pool, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(page_gather_ref(pool, idx)))
    pages = (jnp.ones((k, *shape)) * 7).astype(dtype)
    new = page_scatter(pool.copy(), idx, pages, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(new), np.asarray(page_scatter_ref(pool, idx, pages)))


@pytest.mark.parametrize("n_slots,k,shape", [(32, 4, (8, 4)), (16, 8, (4,))])
def test_page_gather_quant_parity(n_slots, k, shape):
    """Fused gather + int8 quantize: the Pallas kernel (interpret mode),
    the XLA dispatch path, and a numpy oracle matching the host-pool
    quantizer agree bit for bit."""
    from repro.kernels.page_gather import page_gather_quant, quantize_pages_ref
    from repro.kernels.page_gather.page_gather import page_gather_quant_pallas
    pool = jax.random.normal(jax.random.PRNGKey(6), (n_slots, *shape),
                             jnp.float32) * 3.0
    idx = jax.random.permutation(jax.random.PRNGKey(7), n_slots)[:k]
    idx = idx.astype(jnp.int32)

    def np_oracle(pool, idx):
        pages = np.asarray(pool)[np.asarray(idx)]
        axes = tuple(range(1, pages.ndim))
        scale = np.maximum(np.max(np.abs(pages), axis=axes), 1e-8) / 127.0
        b = scale.reshape((-1,) + (1,) * (pages.ndim - 1))
        q = np.clip(np.round(pages / b), -127, 127).astype(np.int8)
        return q, scale.astype(np.float32)

    qn, sn = np_oracle(pool, idx)
    qx, sx = page_gather_quant(pool, idx)             # XLA dispatch path
    np.testing.assert_array_equal(np.asarray(qx), qn)
    np.testing.assert_array_equal(np.asarray(sx), sn)
    qp, sp = page_gather_quant_pallas(pool, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(qp), qn)
    np.testing.assert_array_equal(np.asarray(sp), sn)
    qr, sr = quantize_pages_ref(pool[idx])
    np.testing.assert_array_equal(np.asarray(qr), qn)


def test_page_quant_roundtrip_matches_host_pool():
    """scatter_quant -> gather_dequant reproduces the HostPool int8
    round trip exactly (same scale rule, same clip/round)."""
    from repro.core.hierarchy import MediumSpec
    from repro.core import costmodel as cm
    from repro.core.tiers import HostPool
    from repro.kernels.page_gather import (page_gather_dequant,
                                           page_scatter_quant)
    spec = MediumSpec("NVM", 8, cm.NVM, residency="host", quantize_int8=True)
    hp = HostPool(spec, (4, 2), jnp.float32)
    vals = np.asarray(jax.random.normal(jax.random.PRNGKey(8), (3, 4, 2)),
                      np.float32) * 5.0
    phys = np.asarray([1, 4, 6])
    hp.write_batch(phys, vals)
    want = hp.read_batch(phys)

    pq = jnp.zeros((8, 4, 2), jnp.int8)
    ps = jnp.ones((8,), jnp.float32)
    pq, ps = page_scatter_quant(pq, ps, jnp.asarray(phys, jnp.int32),
                                jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(pq[phys]), hp.data[phys])
    got = page_gather_dequant(pq, ps, jnp.asarray(phys, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), want)


# --- fused SysMon pass -----------------------------------------------------------

@pytest.mark.parametrize("n,block", [(300, 128), (1024, 256), (17, 64)])
def test_sysmon_pass_kernel(n, block):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    reads = jax.random.randint(ks[0], (n,), 0, 10)
    writes = jax.random.randint(ks[1], (n,), 0, 10)
    hist = jax.random.randint(ks[2], (n,), 0, 256)
    wd, nh, fut = sysmon_pass(reads, writes, hist, block=block, interpret=True)
    wdr, nhr, futr = sysmon_pass_ref(reads, writes, hist)
    np.testing.assert_array_equal(np.asarray(wd), np.asarray(wdr))
    np.testing.assert_array_equal(np.asarray(nh), np.asarray(nhr))
    np.testing.assert_array_equal(np.asarray(fut), np.asarray(futr))


@pytest.mark.parametrize("n,k", [(64, 9), (300, 200), (512, 1)])
def test_touch_update_kernel(n, k):
    """Per-sampling touch scatter-add: Pallas (interpret), XLA fallback,
    and numpy oracle all agree, including duplicate ids, masked (padded)
    events, and the touched dedupe."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    ids = jax.random.randint(ks[0], (k,), 0, n)
    is_write = jax.random.bernoulli(ks[1], 0.4, (k,))
    valid = jax.random.bernoulli(ks[2], 0.8, (k,))
    want = touch_update_ref(n, np.asarray(ids), np.asarray(is_write),
                            np.asarray(valid))
    for interpret in (True, None):      # Pallas interpreter / XLA scatter
        got = touch_update(n, ids, is_write, valid, interpret=interpret,
                           block=128)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), w)
    # scalar is_write broadcast + no mask
    got = touch_update(n, ids, True, interpret=True, block=128)
    want = touch_update_ref(n, np.asarray(ids), True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)
