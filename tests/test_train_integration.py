"""End-to-end training: loss decreases on the learnable synthetic task,
checkpoint/restart resumes bit-exactly, a simulated crash recovers, and
the fault-tolerance controller logic behaves."""
import tempfile
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import (Checkpointer, HeartbeatMonitor, StragglerPolicy,
                              plan_elastic_remesh)
from repro.configs import get_arch, smoke
from repro.data import Prefetcher, ShardInfo, SyntheticLM
from repro.launch.train import train_loop
from repro.optim import adamw


def test_loss_decreases_dense():
    cfg = smoke(get_arch("qwen3_4b"))
    losses, _, _ = train_loop(cfg, steps=40, global_batch=8, seq_len=32,
                              n_micro=2, log_every=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[::8]


def test_loss_decreases_moe():
    cfg = smoke(get_arch("olmoe_1b_7b"))
    losses, _, _ = train_loop(cfg, steps=60, global_batch=8, seq_len=32,
                              n_micro=2, log_every=0)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.4


def test_checkpoint_resume_is_bit_exact():
    cfg = smoke(get_arch("phi3_mini_3_8b"))
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted run
        losses_a, params_a, _ = train_loop(cfg, steps=20, global_batch=4,
                                           seq_len=16, n_micro=1,
                                           log_every=0)
        # interrupted at step 10, then resumed from the checkpoint
        losses_b1, _, _ = train_loop(cfg, steps=10, global_batch=4,
                                     seq_len=16, n_micro=1, ckpt_dir=d,
                                     ckpt_every=10, log_every=0)
        losses_b2, params_b, _ = train_loop(cfg, steps=20, global_batch=4,
                                            seq_len=16, n_micro=1,
                                            ckpt_dir=d, ckpt_every=10,
                                            log_every=0)
        # resumed run starts at step 10 and matches the tail exactly
        np.testing.assert_allclose(losses_b2, losses_a[10:], rtol=1e-6)
        for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_recovery():
    cfg = smoke(get_arch("mamba2_1_3b"))
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError, match="simulated crash"):
            train_loop(cfg, steps=20, global_batch=4, seq_len=16, n_micro=1,
                       ckpt_dir=d, ckpt_every=5, crash_at=12, log_every=0)
        losses, _, _ = train_loop(cfg, steps=20, global_batch=4, seq_len=16,
                                  n_micro=1, ckpt_dir=d, ckpt_every=5,
                                  log_every=0)
        assert len(losses) == 10  # resumed from step 10, not from scratch


def test_data_pipeline_deterministic_and_sharded():
    a = SyntheticLM(100, 16, 8, seed=3)
    b = SyntheticLM(100, 16, 8, seed=3)
    np.testing.assert_array_equal(a.batch(7)["tokens"], b.batch(7)["tokens"])
    assert not np.array_equal(a.batch(7)["tokens"], a.batch(8)["tokens"])
    # shard-disjoint streams with the right local batch
    s0 = SyntheticLM(100, 16, 8, seed=3, shard=ShardInfo(0, 2))
    s1 = SyntheticLM(100, 16, 8, seed=3, shard=ShardInfo(1, 2))
    b0, b1 = s0.batch(0)["tokens"], s1.batch(0)["tokens"]
    assert b0.shape == (4, 16)
    assert not np.array_equal(b0, b1)


def test_prefetcher_orders_batches():
    src = SyntheticLM(50, 8, 4, seed=0)
    pf = Prefetcher(src, start_step=5, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
    finally:
        pf.close()


def test_heartbeat_and_straggler_policy():
    hb = HeartbeatMonitor(n_hosts=4, dead_timeout_s=10, straggler_factor=2.5)
    now = 1000.0
    for h in range(4):
        for _ in range(5):
            hb.beat(h, 1.0 if h != 2 else 4.0, now=now)
    assert hb.stragglers() == [2]
    assert hb.dead_hosts(now=now + 20) == [0, 1, 2, 3]
    assert hb.dead_hosts(now=now + 1) == []

    pol = StragglerPolicy(patience=2)
    acts = {}
    for _ in range(4):
        acts = pol.observe([2])
    assert acts[2] == "remesh"
    # flag clears when the host recovers
    assert pol.observe([]) == {}


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"),
                               lost_chips=16)
    assert plan.new_shape[-1] == 16          # TP group preserved
    assert plan.chips_after <= 512 - 16
    assert plan.grad_accum_scale >= 2        # global batch preserved
    plan2 = plan_elastic_remesh((16, 16), ("data", "model"), lost_chips=1)
    assert plan2.new_shape == (8, 16)


def test_zero_spec_shards_an_unsharded_dim():
    from jax.sharding import PartitionSpec as P
    spec = adamw.zero_spec((80, 4096, 32, 128), P(None, None, "model", None),
                           ("data",), 16)
    assert spec[0] == "data"                 # layer dim got the data axis
    spec2 = adamw.zero_spec((81, 3584), P(None, "model"), ("data",), 16)
    assert spec2 == P(None, "model")         # 81 indivisible: unchanged
