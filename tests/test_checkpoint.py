"""Checkpointer: atomic commit, async error surfacing, retention,
structure checks, restore-with-shardings."""
import os
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        t = tree()
        ck.save(3, t, extra={"note": "hi"}, block=True)
        restored, step, extra = ck.restore(jax.eval_shape(lambda: tree()))
        assert step == 3 and extra == {"note": "hi"}
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_newest():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, tree(), block=True)
        assert ck.steps() == [3, 4]


def test_no_partial_checkpoint_visible():
    """Temp dirs never surface as restorable steps."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, tree(), block=True)
        (Path(d) / ".tmp_step_9").mkdir()       # simulated crashed writer
        assert ck.steps() == [1]
        restored, step, _ = ck.restore(jax.eval_shape(lambda: tree()))
        assert step == 1


def test_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, tree(), block=True)
        with pytest.raises(AssertionError):
            ck.restore({"different": jnp.zeros(3)})


def test_snapshot_consistency_under_mutation():
    """The host snapshot is taken synchronously: mutating the live tree
    after save() must not affect what lands on disk."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        t = {"x": np.zeros(4)}
        ck.save(1, t)
        t["x"][:] = 99.0                       # mutate while writer runs
        ck.wait()
        restored, _, _ = ck.restore({"x": np.zeros(4)})
        np.testing.assert_array_equal(restored["x"], np.zeros(4))
