"""Parity + invariant suite for the batched device-resident migration engine.

The batched engine (one Pallas/XLA bulk move per direction) must be
observationally identical to the retained numpy reference engine: same
tier/slot tables, same pool contents, same dirty-discard behavior, for
randomized plans.  On top of parity, allocator invariants (no slot
double-booking, page-table/allocator consistency) and the serving-side
guarantee that block tables only ever point at live fast slots.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import (BatchedMigrationEngine, MigrationEngine,
                                  make_engine, plan_locked)
from repro.core.hierarchy import FAST, SLOW
from repro.core.tiers import NO_SLOT, TierConfig, TierStore
from repro.serving.kv_cache import PagedKVCache, PagedKVConfig


def make_store(n=48, fast=16, slow=64, quantize=False, shape=(4,),
               dtype=jnp.float32, seed=0):
    s = TierStore(TierConfig(n_pages=n, fast_slots=fast, slow_slots=slow,
                             page_shape=shape, dtype=dtype,
                             quantize_slow=quantize))
    rng = np.random.RandomState(seed)
    for p in range(n):
        assert s.allocate(p, SLOW)
        s.write_page(p, rng.standard_normal(shape).astype(np.float32))
    return s


def assert_state_equal(a: TierStore, b: TierStore):
    np.testing.assert_array_equal(a.tier, b.tier)
    np.testing.assert_array_equal(a.slot, b.slot)
    np.testing.assert_array_equal(a.version, b.version)
    for p in np.nonzero(a.slot != NO_SLOT)[0]:
        np.testing.assert_array_equal(a.read_page(int(p)), b.read_page(int(p)),
                                      err_msg=f"page {p} contents diverge")
    assert a.traffic == b.traffic


def assert_alloc_invariants(s: TierStore):
    """No slot double-booking; page table consistent with the allocators."""
    for tier, cap in ((FAST, s.cfg.fast_slots), (SLOW, s.cfg.slow_slots)):
        live = np.nonzero((s.slot != NO_SLOT) & (s.tier == tier))[0]
        slots = s.slot[live]
        assert len(set(slots.tolist())) == live.size, \
            f"tier {tier}: two pages share a physical slot"
        assert ((slots >= 0) & (slots < cap)).all()
        assert s.alloc[tier].n_free == cap - live.size, \
            f"tier {tier}: allocator free count disagrees with page table"


# =============================================================================
# parity: batched engine vs numpy reference on randomized plans
# =============================================================================

@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("chunk", [3, 64])
def test_locked_parity_randomized(quantize, chunk):
    ref_s = make_store(quantize=quantize)
    bat_s = make_store(quantize=quantize)
    ref = MigrationEngine(ref_s)
    bat = BatchedMigrationEngine(bat_s, chunk_pages=chunk)
    rng = np.random.RandomState(1)
    for round_ in range(12):
        k = rng.randint(1, 20)
        pages = rng.choice(48, size=k, replace=False)
        dst = FAST if rng.rand() < 0.5 else SLOW
        if rng.rand() < 0.5:
            bank_freq = rng.randint(0, 10, 8).astype(np.float64)
            slab_freq = rng.randint(0, 10, 16).astype(np.float64)
            reuse = rng.randint(0, 3, 48)
        else:
            bank_freq = slab_freq = reuse = None
        st_r = ref.migrate_locked(pages, dst, bank_freq, slab_freq, reuse)
        st_b = bat.migrate_locked(pages, dst, bank_freq, slab_freq, reuse)
        assert (st_r.migrated, st_r.to_fast, st_r.to_slow) == \
            (st_b.migrated, st_b.to_fast, st_b.to_slow), f"round {round_}"
        assert_state_equal(ref_s, bat_s)
        assert_alloc_invariants(bat_s)


@pytest.mark.parametrize("quantize", [False, True])
def test_optimistic_parity_randomized(quantize):
    ref_s = make_store(quantize=quantize)
    bat_s = make_store(quantize=quantize)
    ref = MigrationEngine(ref_s, max_retries=2)
    bat = BatchedMigrationEngine(bat_s, max_retries=2, chunk_pages=5)
    rng = np.random.RandomState(2)
    for round_ in range(12):
        k = rng.randint(1, 20)
        pages = rng.choice(48, size=k, replace=False)
        dst = FAST if rng.rand() < 0.5 else SLOW
        dirty = rng.choice(pages, size=min(3, k), replace=False)
        val = rng.standard_normal(4).astype(np.float32)

        def writer_for(store):
            def writer():
                for p in dirty:
                    store.write_page(int(p), val)
            return writer

        st_r = ref.migrate_optimistic(pages, dst,
                                      concurrent_writer=writer_for(ref_s))
        st_b = bat.migrate_optimistic(pages, dst,
                                      concurrent_writer=writer_for(bat_s))
        assert (st_r.migrated, st_r.dirty_discards, st_r.retries) == \
            (st_b.migrated, st_b.dirty_discards, st_b.retries), f"round {round_}"
        assert_state_equal(ref_s, bat_s)
        assert_alloc_invariants(bat_s)


def test_optimistic_dirty_page_not_committed():
    s = make_store()
    eng = BatchedMigrationEngine(s, max_retries=0)
    before = s.read_page(1).copy()

    def writer():
        s.write_page(1, np.zeros(4, np.float32))

    stats = eng.migrate_optimistic([0, 1, 2], FAST, concurrent_writer=writer)
    assert stats.dirty_discards == 1
    assert s.tier[0] == FAST and s.tier[2] == FAST
    assert s.tier[1] == SLOW            # dirtied mid-copy: not committed
    np.testing.assert_array_equal(s.read_page(1), np.zeros(4))
    assert not np.array_equal(before, np.zeros(4))


def test_bf16_pool_parity():
    """Lossy fast-pool dtype: both engines apply the identical cast."""
    ref_s = make_store(dtype=jnp.bfloat16)
    bat_s = make_store(dtype=jnp.bfloat16)
    ref = MigrationEngine(ref_s)
    bat = BatchedMigrationEngine(bat_s, chunk_pages=4)
    pages = list(range(0, 14))
    ref.migrate_locked(pages, FAST)
    bat.migrate_locked(pages, FAST)
    assert_state_equal(ref_s, bat_s)


def test_memos_pass_parity_end_to_end():
    """A full memos loop (plan -> migrate -> balance) drives both engines to
    the same hierarchy state."""
    stores = {k: make_store(n=32, fast=8) for k in ("reference", "batched")}
    mgrs = {k: MemosManager(s, MemosConfig(interval=1, adaptive_interval=False,
                                           engine=k))
            for k, s in stores.items()}
    assert isinstance(mgrs["batched"].engine, BatchedMigrationEngine)
    assert isinstance(mgrs["reference"].engine, MigrationEngine)
    sms = {k: sysmon.init(32, 4, 4) for k in stores}
    rng = np.random.RandomState(3)
    for step in range(12):
        hot = rng.choice(32, size=6, replace=False).astype(np.int32)
        reports = {}
        for k in stores:
            sms[k] = sysmon.record(sms[k], jnp.asarray(hot), is_write=True)
            sms[k], reports[k] = mgrs[k].maybe_step(sms[k])
        r, b = reports["reference"], reports["batched"]
        assert (r is None) == (b is None)
        if r is not None:
            assert r.n_marked == b.n_marked
            assert (r.migrations.migrated, r.migrations.to_fast,
                    r.migrations.to_slow) == \
                (b.migrations.migrated, b.migrations.to_fast,
                 b.migrations.to_slow), f"step {step}"
        assert_state_equal(stores["reference"], stores["batched"])
        assert_alloc_invariants(stores["batched"])


# =============================================================================
# plans
# =============================================================================

def test_plan_reserves_slots_and_counts_trivial():
    s = make_store(n=16, fast=4)
    free_before = s.alloc[FAST].n_free
    plan = plan_locked(s, range(8), FAST)
    # capacity-bounded: only 4 destination slots exist
    assert len(plan) == 4 and plan.trivial == 0
    assert s.alloc[FAST].n_free == free_before - 4
    assert (s.tier[plan.pages] == SLOW).all()     # plan does not move data
    eng = BatchedMigrationEngine(s)
    st = eng.execute_plan(plan)
    assert st.migrated == 4 and (s.tier[plan.pages] == FAST).all()
    np.testing.assert_array_equal(s.slot[plan.pages], plan.dst_slots)
    # re-planning pages already in FAST reports them trivially migrated
    plan2 = plan_locked(s, plan.pages, FAST)
    assert len(plan2) == 0 and plan2.trivial == 4
    assert eng.execute_plan(plan2).migrated == 4
    assert_alloc_invariants(s)


def test_released_pages_are_skipped_not_corrupted():
    """Pages freed between planning inputs and the migrate call (slot ==
    NO_SLOT) must be skipped by both engines, leaving state untouched."""
    ref_s, bat_s = make_store(), make_store()
    for s in (ref_s, bat_s):
        s.release(3)
        s.release(5)
    pages = [2, 3, 4, 5, 6]
    st_r = MigrationEngine(ref_s).migrate_locked(pages, FAST)
    st_b = BatchedMigrationEngine(bat_s).migrate_locked(pages, FAST)
    assert st_r.migrated == st_b.migrated == 3
    assert_state_equal(ref_s, bat_s)
    assert bat_s.slot[3] == NO_SLOT and bat_s.slot[5] == NO_SLOT
    assert_alloc_invariants(bat_s)


def test_duplicate_pages_in_one_batch():
    """A page id repeated in one locked batch moves once; the repeat counts
    as a trivial (already-there) migration, matching the reference."""
    ref_s, bat_s = make_store(), make_store()
    bank = np.zeros(8)
    bank_r, bank_b = bank.copy(), bank.copy()
    slab = np.ones(16)
    st_r = MigrationEngine(ref_s).migrate_locked([5, 5, 7], FAST,
                                                 bank_r, slab)
    st_b = BatchedMigrationEngine(bat_s).migrate_locked([5, 5, 7], FAST,
                                                        bank_b, slab)
    assert st_r.migrated == st_b.migrated == 3
    assert_state_equal(ref_s, bat_s)
    assert_alloc_invariants(bat_s)


def test_duplicate_pages_optimistic_batch():
    """Repeated page ids in one optimistic batch are deduped (first
    occurrence wins) by both engines."""
    ref_s, bat_s = make_store(), make_store()
    MigrationEngine(ref_s).migrate_locked([3], FAST)
    BatchedMigrationEngine(bat_s).migrate_locked([3], FAST)
    st_r = MigrationEngine(ref_s).migrate_optimistic([3, 3], SLOW)
    st_b = BatchedMigrationEngine(bat_s).migrate_optimistic([3, 3], SLOW)
    assert st_r.migrated == st_b.migrated == 1
    assert_state_equal(ref_s, bat_s)
    assert_alloc_invariants(bat_s)


def test_capacity_bound_respected_batched():
    s = make_store(n=32, fast=4)
    eng = BatchedMigrationEngine(s)
    stats = eng.migrate_locked(range(32), FAST)
    assert stats.migrated <= 4
    assert (np.asarray(s.tier) == FAST).sum() <= 4
    assert_alloc_invariants(s)


# =============================================================================
# serving: block tables always point at live fast slots
# =============================================================================

def test_block_tables_point_at_live_fast_slots():
    kv = PagedKVCache(PagedKVConfig(n_layers=2, n_kv_heads=2, head_dim=8,
                                    page_size=4, fast_slots=8, slow_slots=32))
    eng = make_engine(kv.store, "batched")
    pids = [kv.new_page(FAST) for _ in range(12)]
    assert all(p is not None for p in pids)
    resident = [p for p in pids if kv.is_resident(p)]
    overflow = [p for p in pids if not kv.is_resident(p)]
    assert len(resident) == 8 and len(overflow) == 4   # HBM full -> host

    slots = kv.fast_slots_of(resident)
    assert len(set(slots.tolist())) == len(resident)   # no double-booking
    assert ((slots >= 0) & (slots < 8)).all()
    assert (kv.store.tier[resident] == FAST).all()

    # demote half, promote the overflow: the vectorized block-table fill
    # must only ever be offered live fast slots
    eng.migrate_optimistic(resident[:4], SLOW)
    eng.migrate_locked(overflow, FAST)
    live = [p for p in pids if kv.is_resident(p)]
    slots = kv.fast_slots_of(live)
    assert len(set(slots.tolist())) == len(live)
    assert ((slots >= 0) & (slots < 8)).all()
    with pytest.raises(AssertionError):
        kv.fast_slots_of(resident[:4])                 # demoted: must refuse
    assert_alloc_invariants(kv.store)


def test_resident_mask_matches_scalar_path():
    kv = PagedKVCache(PagedKVConfig(n_layers=1, n_kv_heads=1, head_dim=4,
                                    page_size=2, fast_slots=4, slow_slots=16))
    pids = [kv.new_page(FAST) for _ in range(8)]
    mask = kv.resident_mask(pids)
    np.testing.assert_array_equal(mask,
                                  [kv.is_resident(p) for p in pids])
