"""Unified tracing + metrics subsystem (repro.obs).

Pins the observability contracts the serving/memos instrumentation
relies on:

  * span nesting + thread attribution — a forced async memos pass puts
    ``memos.plan`` on the worker thread, time-overlapping the main
    thread's dispatch span (the overlap the Chrome-trace export exists
    to make visible);
  * ring-buffer wraparound — a full ring drops oldest events, never
    stalls or grows;
  * disabled-mode zero cost — disabled tracing records zero events,
    retains zero attributes, and hands out one shared no-op span;
  * log-bucketed histogram quantiles, the exporters' formats, and the
    MemosReport to_dict/from_dict/flat_metrics serialization contract.
"""
import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import sysmon
from repro.core.memos import (MemosConfig, MemosManager, MemosReport,
                              aggregate_reports)
from repro.core.migration import MigrationStats
from repro.core.tiers import TierConfig, TierStore
from repro.obs.trace import NULL_SPAN, Tracer


@pytest.fixture(autouse=True)
def obs_isolation():
    """Every test starts and ends with tracing off and empty sinks."""
    obs.configure(trace=False)
    obs.reset()
    yield
    obs.configure(trace=False)
    obs.reset()


# =============================================================================
# tracer
# =============================================================================

def test_span_nesting_and_attrs():
    tr = Tracer(enabled=True)
    with tr.span("parent", step=3) as p:
        with tr.span("child"):
            pass
        p.set(k=16)
    ev = tr.events()
    # spans record at exit: child lands first, both on this thread
    assert [e.name for e in ev] == ["child", "parent"]
    child, parent = ev
    assert child.tid == parent.tid == threading.get_ident()
    assert parent.attrs == {"step": 3, "k": 16}
    # context-manager discipline: the child interval nests inside the
    # parent's [start, start + dur)
    assert parent.ts_ns <= child.ts_ns
    assert child.ts_ns + child.dur_ns <= parent.ts_ns + parent.dur_ns
    assert tr.thread_names[child.tid] == threading.current_thread().name


def test_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=8, enabled=True)
    for i in range(20):
        tr.instant(f"e{i}")
    ev = tr.events()
    assert [e.name for e in ev] == [f"e{i}" for i in range(12, 20)]
    assert tr.n_recorded == 20
    assert tr.n_dropped == 12
    tr.clear()
    assert tr.events() == [] and tr.n_recorded == 0


def test_disabled_mode_records_nothing():
    tr = Tracer(enabled=False)
    s = tr.span("x", big_attr=list(range(1000)))
    assert s is NULL_SPAN                       # one shared no-op object
    assert tr.span("y") is NULL_SPAN
    with s:
        s.set(more="attrs")
    tr.instant("z")
    assert tr.events() == [] and tr.n_recorded == 0
    # the module-level API takes the same fast path
    assert not obs.tracing_enabled()
    assert obs.span("serve.dispatch", k=16) is NULL_SPAN
    obs.instant("nope")
    assert obs.get_tracer().n_recorded == 0


def test_configure_flip_and_capacity():
    obs.configure(trace=True)
    with obs.span("a"):
        pass
    assert obs.get_tracer().n_recorded == 1
    obs.configure(capacity=16)                  # resize drops events
    assert obs.get_tracer().capacity == 16
    assert obs.get_tracer().n_recorded == 0
    assert obs.tracing_enabled()                # flag survives the resize


# =============================================================================
# metrics
# =============================================================================

def test_histogram_quantiles_exact_for_equal_stream():
    h = obs.get_registry().histogram("lat_s")
    for _ in range(100):
        h.observe(0.25)
    assert h.quantile(0.5) == pytest.approx(0.25)
    assert h.quantile(0.99) == pytest.approx(0.25)
    assert h.count == 100 and h.mean == pytest.approx(0.25)


def test_histogram_weighted_and_ordered():
    h = obs.get_registry().histogram("tok_s")
    h.observe(0.001, n=90)                      # 90 fast tokens
    h.observe(0.1, n=10)                        # 10 slow tokens
    assert h.count == 100
    assert h.quantile(0.5) == pytest.approx(0.001, rel=0.25)
    assert h.quantile(0.99) == pytest.approx(0.1, rel=0.25)
    assert h.min == 0.001 and h.max == 0.1
    d = h.to_dict()
    assert d["p50"] <= d["p90"] <= d["p99"] <= d["max"]


def test_registry_kind_mismatch_and_flat():
    reg = obs.get_registry()
    reg.counter("a").inc(3)
    reg.gauge("b").set(1.5)
    reg.histogram("c").observe(2.0)
    with pytest.raises(TypeError):
        reg.gauge("a")
    flat = reg.flat()
    assert flat["a"] == 3 and flat["b"] == 1.5
    assert flat["c.count"] == 1 and flat["c.p50"] == pytest.approx(2.0)
    reg.reset()
    assert reg.flat() == {}


# =============================================================================
# exporters
# =============================================================================

def test_chrome_trace_export(tmp_path):
    obs.configure(trace=True)
    with obs.span("outer", step=1):
        obs.instant("marker")
    p = obs.export.write_chrome_trace(tmp_path / "t.json", obs.get_tracer())
    doc = json.loads(p.read_text())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    insts = [e for e in evs if e["ph"] == "i"]
    assert meta and meta[0]["name"] == "thread_name"
    assert len(spans) == 1 and spans[0]["name"] == "outer"
    assert spans[0]["args"] == {"step": 1} and spans[0]["dur"] >= 0
    assert insts[0]["s"] == "t"
    # timestamps rebase to the earliest event
    assert min(e["ts"] for e in spans + insts) == 0
    assert doc["otherData"]["dropped_events"] == 0


def test_prometheus_text():
    reg = obs.get_registry()
    reg.counter("memos.passes", "passes").inc(2)
    reg.gauge("store.t0_used").set(7)
    reg.histogram("serving.dispatch_latency_s").observe(0.01, n=4)
    text = obs.export.prometheus_text(reg)
    assert "# TYPE repro_memos_passes counter" in text
    assert "repro_memos_passes 2" in text
    assert "repro_store_t0_used 7" in text
    assert 'repro_serving_dispatch_latency_s_bucket{le="+Inf"} 4' in text
    assert "repro_serving_dispatch_latency_s_count 4" in text


def test_jsonl_export():
    obs.configure(trace=True)
    with obs.span("a"):
        pass
    lines = obs.export.to_jsonl(obs.get_tracer()).strip().splitlines()
    rec = json.loads(lines[0])
    assert rec["name"] == "a" and rec["ph"] == "X" and rec["thread"]


# =============================================================================
# MemosReport serialization
# =============================================================================

def make_store(seed=0):
    store = TierStore(TierConfig(
        n_pages=32, fast_slots=8, slow_slots=32, page_shape=(4,),
        dtype=jnp.float32, n_banks=2, n_slabs=4, gap_write_interval=5))
    rng = np.random.RandomState(seed)
    for p in range(32):
        assert store.allocate(p, int(store.tier[p]))
        store.write_page(p, rng.standard_normal(4).astype(np.float32))
    return store


def drive(mgr, n_steps=24):
    sm = sysmon.init(32, mgr.store.cfg.n_banks, mgr.store.cfg.n_slabs)
    rng = np.random.RandomState(7)
    for step in range(n_steps):
        phase = step // 8
        hot = np.arange(phase * 6, phase * 6 + 6)
        warm = rng.randint(20, 32, size=3)
        sm = sysmon.record(sm, jnp.asarray(hot, jnp.int32), is_write=True)
        sm = sysmon.record(sm, jnp.asarray(warm, jnp.int32), is_write=False)
        sm, _ = mgr.maybe_step(sm)
    mgr.flush()
    return sm


def test_memos_report_roundtrip():
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False))
    drive(mgr)
    assert mgr.reports and any(r.migrations.migrated for r in mgr.reports)
    for rep in mgr.reports:
        d = rep.to_dict()
        blob = json.dumps(d)                    # must be JSON-safe
        back = MemosReport.from_dict(json.loads(blob))
        assert back == rep
        assert back.to_dict() == d
        flat = rep.flat_metrics()
        assert flat["migrated"] == rep.migrations.migrated
        assert flat["tier0_pages"] == rep.tier_pages[0]
        for t in rep.nvm_by_tier:
            assert f"nvm.t{t}.wear_max" in flat


def test_migration_stats_roundtrip():
    st = MigrationStats(migrated=5, bytes_moved=1280, to_fast=2, to_slow=3)
    st.note_move(0, 1, 3)
    st.note_move(1, 0, 2)
    back = MigrationStats.from_dict(json.loads(json.dumps(st.to_dict())))
    assert back == st


def test_aggregate_reports():
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False))
    drive(mgr)
    agg = aggregate_reports(mgr.reports)
    assert agg["passes"] == len(mgr.reports)
    assert agg["migrated"] == sum(r.migrations.migrated
                                  for r in mgr.reports)
    assert agg["tier_pages"] == list(mgr.reports[-1].tier_pages)
    assert aggregate_reports([])["passes"] == 0


# =============================================================================
# instrumentation: spans + metrics out of a real memos pass
# =============================================================================

def test_sync_pass_spans_and_metrics():
    obs.configure(trace=True)
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False))
    drive(mgr)
    names = {e.name for e in obs.get_tracer().events()}
    assert "memos.pass_sync" in names
    assert "migrate.move_group" in names        # batched per-(src,dst) moves
    flat = obs.get_registry().flat()
    assert flat["memos.passes"] == len(mgr.reports)
    assert flat["memos.pages_migrated"] == sum(
        r.migrations.migrated for r in mgr.reports)
    assert "store.t0_used" in flat and "store.t0_slots" in flat
    assert "sysmon.hot_pages" in flat


def test_forced_async_pass_thread_attribution(monkeypatch):
    """Force a real plan/dispatch overlap: the worker's ``memos.plan``
    span must carry the worker tid and time-overlap the main thread's
    dispatch span recorded while the plan slept."""
    import repro.core.memos as memos_mod
    obs.configure(trace=True)
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False,
                                          async_plan=True))
    # slow the placement step itself so the sleep lands inside the
    # worker's timed plan window (plan_t0 .. plan_t1)
    orig_plan = memos_mod.plan
    monkeypatch.setattr(
        memos_mod, "plan",
        lambda *a, **k: (time.sleep(0.05), orig_plan(*a, **k))[1])

    sm = sysmon.init(32, store.cfg.n_banks, store.cfg.n_slabs)
    sm = sysmon.record(sm, jnp.asarray(np.arange(6), jnp.int32),
                       is_write=True)
    sm = mgr.begin_pass(sm)
    with obs.span("serve.dispatch", k=16):      # the overlapped dispatch
        time.sleep(0.08)
    rep = mgr.commit_pending()
    mgr.close()

    by_name = {e.name: e for e in obs.get_tracer().events()}
    plan, disp = by_name["memos.plan"], by_name["serve.dispatch"]
    commit = by_name["memos.commit"]
    main_tid = threading.get_ident()
    assert disp.tid == commit.tid == main_tid
    assert plan.tid != main_tid                 # worker thread
    assert obs.get_tracer().thread_names[plan.tid].startswith("memos-plan")
    # the plan interval overlaps the dispatch interval in time
    assert plan.ts_ns < disp.ts_ns + disp.dur_ns
    assert plan.ts_ns + plan.dur_ns > disp.ts_ns
    # and the slept plan was (mostly) hidden under the longer dispatch
    assert rep.committed_async
    assert rep.overlap_efficiency is not None
    assert rep.overlap_efficiency > 0.5
    assert rep.plan_ms >= 50.0
    assert mgr.overlap_efficiency == pytest.approx(rep.overlap_efficiency)


def test_disabled_tracing_still_publishes_metrics():
    """Metrics are always-on; tracing off must not suppress them (the
    overhead gate compares tracing on/off at identical metric output)."""
    store = make_store()
    mgr = MemosManager(store, MemosConfig(interval=4,
                                          adaptive_interval=False))
    drive(mgr)
    assert obs.get_tracer().n_recorded == 0
    flat = obs.get_registry().flat()
    assert flat["memos.passes"] == len(mgr.reports) > 0
