"""Migration engine + TierStore properties: bit-exact moves, optimistic
dirty-discard, conservation of pages, memos end-to-end loop."""
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.optional_hypothesis import given, settings, st

from repro.core import sysmon
from repro.core.memos import MemosConfig, MemosManager
from repro.core.migration import MigrationEngine
from repro.core.hierarchy import FAST, SLOW
from repro.core.tiers import NO_SLOT, TierConfig, TierStore


def make_store(n=32, fast=16, slow=64, quantize=False):
    s = TierStore(TierConfig(n_pages=n, fast_slots=fast, slow_slots=slow,
                             page_shape=(4,), quantize_slow=quantize))
    for p in range(n):
        assert s.allocate(p, SLOW)
        s.write_page(p, np.full(4, float(p), np.float32))
    return s


def test_move_preserves_contents_bitexact():
    s = make_store()
    eng = MigrationEngine(s)
    eng.migrate_locked(range(8), FAST)
    for p in range(8):
        assert s.tier[p] == FAST
        np.testing.assert_array_equal(s.read_page(p), np.full(4, float(p)))
    eng.migrate_locked(range(8), SLOW)
    for p in range(8):
        assert s.tier[p] == SLOW
        np.testing.assert_array_equal(s.read_page(p), np.full(4, float(p)))


def test_optimistic_discards_dirty_pages():
    s = make_store()
    eng = MigrationEngine(s, max_retries=0)
    def writer():
        s.write_page(1, np.zeros(4, np.float32))
    stats = eng.migrate_optimistic([0, 1, 2], FAST, concurrent_writer=writer)
    assert stats.dirty_discards == 1
    assert s.tier[0] == FAST and s.tier[2] == FAST
    assert s.tier[1] == SLOW          # dirtied mid-copy: not committed
    np.testing.assert_array_equal(s.read_page(1), np.zeros(4))


def test_optimistic_retries_dirty_pages():
    s = make_store()
    eng = MigrationEngine(s, max_retries=2)
    def writer():
        s.write_page(1, np.full(4, 42.0, np.float32))
    stats = eng.migrate_optimistic([0, 1], FAST, concurrent_writer=writer)
    assert stats.migrated == 2        # retried after the discard
    assert s.tier[1] == FAST
    np.testing.assert_array_equal(s.read_page(1), np.full(4, 42.0))


@given(st.lists(st.integers(0, 31), min_size=1, max_size=40, unique=True),
       st.booleans())
@settings(max_examples=40, deadline=None)
def test_migration_conservation(pages, to_fast):
    """Every logical page stays allocated exactly once; contents survive."""
    s = make_store()
    eng = MigrationEngine(s)
    dst = FAST if to_fast else SLOW
    eng.migrate_locked(pages, dst)
    assert (s.slot != NO_SLOT).all()
    slots = [(int(s.tier[p]), int(s.slot[p])) for p in range(32)]
    assert len(set(slots)) == 32, "two pages share a physical slot"
    for p in range(32):
        np.testing.assert_array_equal(s.read_page(p), np.full(4, float(p)))


def test_quantized_slow_tier_roundtrip():
    """int8 'soft-NVM' tier: lossy but bounded error."""
    s = make_store(quantize=True)
    data = np.linspace(-1, 1, 4).astype(np.float32)
    s.write_page(3, data)
    out = s.read_page(3)
    assert np.max(np.abs(out - data)) < 1.0 / 127 + 1e-6


def test_capacity_bound_respected():
    s = make_store(n=32, fast=4)
    eng = MigrationEngine(s)
    stats = eng.migrate_locked(range(32), FAST)
    assert stats.migrated <= 4
    assert (np.asarray(s.tier) == FAST).sum() <= 4


def test_memos_loop_moves_hot_to_fast_and_cold_back():
    s = make_store(n=32, fast=8)
    mgr = MemosManager(s, MemosConfig(interval=1, adaptive_interval=False))
    sm = sysmon.init(32, 4, 4)
    # phase 1: pages 0..3 written hot
    for _ in range(8):
        sm = sysmon.record(sm, jnp.arange(4), is_write=True)
    sm, rep = mgr.maybe_step(sm)
    assert all(s.tier[p] == FAST for p in range(4))
    # phase 2: pages 0..3 go cold; 8..11 hot now.  After enough passes the
    # WD history decays and the cold pages drain back to the slow tier.
    for _ in range(10):
        for _ in range(8):
            sm = sysmon.record(sm, jnp.arange(8, 12), is_write=True)
        sm, rep = mgr.maybe_step(sm)
    assert all(s.tier[p] == FAST for p in range(8, 12))
    assert all(s.tier[p] == SLOW for p in range(4)), \
        np.asarray(s.tier[:12]).tolist()
    # contents intact after all the shuffling
    for p in range(32):
        np.testing.assert_array_equal(s.read_page(p), np.full(4, float(p)))
