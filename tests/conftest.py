"""Shared test configuration: a global per-test wall-clock timeout.

pytest-timeout is not available in the pinned environment, so the hang
guard is a plain SIGALRM: any single test exceeding ``TEST_TIMEOUT_S``
(default 300 s, override via the env var) fails with a TimeoutError
instead of wedging the whole suite — the failure mode a fault-injection
test that deadlocks the async memos worker would otherwise produce.
Non-main-thread and non-POSIX runs skip the guard silently.
"""
from __future__ import annotations

import os
import signal
import threading

import pytest

TEST_TIMEOUT_S = int(os.environ.get("TEST_TIMEOUT_S", "300"))


@pytest.fixture(autouse=True)
def _global_test_timeout(request):
    if (TEST_TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def _trip(signum, frame):
        raise TimeoutError(
            f"test exceeded the global {TEST_TIMEOUT_S}s timeout: "
            f"{request.node.nodeid}")

    prev = signal.signal(signal.SIGALRM, _trip)
    signal.alarm(TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)
