"""Serving-engine integration: paged decode must equal model-level dense
decode; preemption + memos tiering round-trips are lossless; scheduler
invariants hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, smoke
from repro.core.placement import FAST, SLOW
from repro.models import transformer as T
from repro.serving import ContinuousBatcher, PagedServingEngine, Request, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = smoke(registry()["qwen3_4b"])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def ref_greedy(cfg, params, prompt, n):
    lg, state = T.prefill(params, cfg,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=128)
    gen = []
    for _ in range(n):
        g = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
        gen.append(g)
        lg, state = T.decode_step(params, cfg, state,
                                  {"tokens": jnp.asarray([[g]], jnp.int32)})
    return gen


def test_engine_matches_model_decode(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=32, slow_slots=128,
        memos_interval=6))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23]]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run(max_steps=200)
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 6)


def test_engine_under_hbm_pressure_preempts_and_recovers(model):
    """12 HBM slots, 3 concurrent seqs + page_size 8 forces preemption;
    pages round-trip through the host tier bit-exactly."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, fast_slots=12, slow_slots=128,
        memos_interval=5))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run(max_steps=400)
    assert eng.batcher.all_done()
    st = eng.kv.store
    assert st.traffic[(FAST, SLOW)] > 0 or st.traffic[(SLOW, FAST)] > 0 or \
        len(eng.batcher.finished) == 3
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 6), \
            "tiering round-trip corrupted KV"


def test_moe_engine_tracks_expert_hotness():
    cfg = smoke(registry()["olmoe_1b_7b"])
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=32, slow_slots=64))
    eng.submit([3, 1, 4, 1, 5], max_new=4)
    eng.run(max_steps=50)
    counts = eng.expert_counts
    assert counts is not None and counts.sum() > 0
    # every processed token routes to top_k experts per MoE layer
    steps_tokens = 5 + 4 - 1
    assert counts.sum() == steps_tokens * cfg.top_k * cfg.n_layers


def test_scheduler_invariants():
    b = ContinuousBatcher(max_batch=2)
    reqs = [Request(i, [1, 2], 3) for i in range(4)]
    for r in reqs:
        b.submit(r)
    admitted = b.admit()
    assert len(admitted) == 2
    assert set(b.running) == {0, 1}
    victim = b.preempt_lowest()
    assert victim.preempted and victim.slot is None
    again = b.admit()                      # resumed before new requests
    assert victim in again
    b.finish(b.running[0], step=5)
    assert not b.all_done()
