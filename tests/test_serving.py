"""Serving-engine integration: paged decode must equal model-level dense
decode; the fused K-step dispatch must be bit-identical to the retained
K=1 reference path (tokens, SysMon counters, version/write accounting,
pool contents); preemption + memos tiering round-trips are lossless;
scheduler invariants hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, smoke
from repro.core.hierarchy import FAST, SLOW, MemoryHierarchy
from repro.models import transformer as T
from repro.serving import ContinuousBatcher, PagedServingEngine, Request, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = smoke(registry()["qwen3_4b"])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def ref_greedy(cfg, params, prompt, n):
    lg, state = T.prefill(params, cfg,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=128)
    gen = []
    for _ in range(n):
        g = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
        gen.append(g)
        lg, state = T.decode_step(params, cfg, state,
                                  {"tokens": jnp.asarray([[g]], jnp.int32)})
    return gen


def test_engine_matches_model_decode(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=32, slow_slots=128,
        memos_interval=6))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23]]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run(max_steps=200)
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 6)


def test_engine_under_hbm_pressure_preempts_and_recovers(model):
    """12 HBM slots, 3 concurrent seqs + page_size 8 forces preemption;
    pages round-trip through the host tier bit-exactly."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, fast_slots=12, slow_slots=128,
        memos_interval=5))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run(max_steps=400)
    assert eng.batcher.all_done()
    st = eng.kv.store
    assert st.traffic[(FAST, SLOW)] > 0 or st.traffic[(SLOW, FAST)] > 0 or \
        len(eng.batcher.finished) == 3
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 6), \
            "tiering round-trip corrupted KV"


def _run_engine(cfg, params, prompts, max_new=6, **scfg_kw):
    kw = dict(page_size=8, max_batch=3, fast_slots=32, slow_slots=128)
    kw.update(scfg_kw)
    eng = PagedServingEngine(cfg, params, ServeConfig(**kw))
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    return eng, reqs


SYSMON_FIELDS = ("reads", "writes", "access_count", "hist", "last_access",
                 "intv_cnt", "intv_sum", "intv_sqsum", "bank_freq",
                 "slab_freq", "sample_idx")


@pytest.mark.parametrize("k", [1, 4, 16])
def test_fused_decode_parity_vs_reference(model, k):
    """K-step fused dispatch == retained K=1 reference path, bit for bit:
    generated tokens, final-step logits, every SysMon counter, the
    fast-tier version/read/write accounting, and the pool contents.
    Memos is disabled here so no pass boundary resets counters — the
    comparison covers the raw fused access stream."""
    cfg, params = model
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23]]
    ref, rref = _run_engine(cfg, params, prompts, memos_enabled=False,
                            reference=True)
    fus, rfus = _run_engine(cfg, params, prompts, memos_enabled=False,
                            decode_block=k)
    for a, b in zip(rref, rfus):
        assert a.generated == b.generated
        assert a.tokens == b.tokens
    np.testing.assert_array_equal(np.asarray(ref.last_logits),
                                  np.asarray(fus.last_logits))
    for f in SYSMON_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref.sysmon, f)),
            np.asarray(getattr(fus.sysmon, f)), err_msg=f"sysmon.{f}")
    sr, sf = ref.kv.store, fus.kv.store
    np.testing.assert_array_equal(sr.version, sf.version)
    assert sr.writes_to == sf.writes_to
    assert sr.reads_from == sf.reads_from
    np.testing.assert_array_equal(np.asarray(sr.fast_pool),
                                  np.asarray(sf.fast_pool))


def test_fused_decode_parity_with_memos_migrating(model):
    """Fused dispatches with a live memos loop migrating between them:
    pass boundaries align (interval divisible by K), so tokens AND SysMon
    counters stay bit-identical to the reference engine, and pages a pass
    demoted out from under a running sequence round-trip losslessly."""
    cfg, params = model
    # 8 HBM slots + 3 concurrent sequences force preemption: cold pages
    # drain to host between dispatches and are promoted back on resume
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    kw = dict(max_new=16, memos_interval=8, fast_slots=8)
    ref, rref = _run_engine(cfg, params, prompts, reference=True, **kw)
    fus, rfus = _run_engine(cfg, params, prompts, decode_block=8, **kw)
    assert fus.memos.reports, "memos never ran between dispatches"
    st = fus.kv.store
    assert st.traffic[(FAST, SLOW)] > 0 and st.traffic[(SLOW, FAST)] > 0, \
        "no tiering traffic — the scenario exerts no HBM pressure"
    for a, b in zip(rref, rfus):
        assert a.generated == b.generated, "tiering round-trip corrupted KV"
        assert a.generated == ref_greedy(cfg, params, a.prompt, 16)
    # dispatch boundaries hit the same token multiples (K divides the
    # interval; maybe_step carries the remainder), so pass boundaries —
    # and therefore the WD history the predictor feeds on — must align
    assert len(ref.memos.reports) == len(fus.memos.reports)
    np.testing.assert_array_equal(np.asarray(ref.sysmon.hist),
                                  np.asarray(fus.sysmon.hist),
                                  err_msg="sysmon.hist")


def test_fused_dispatch_amortization(model):
    """The fused engine issues one dispatch per K tokens: step_count is
    token-granular in both engines, but the number of step() calls (one
    host round-trip each) collapses by ~K."""
    cfg, params = model

    def history(**kw):
        eng = PagedServingEngine(cfg, params, ServeConfig(
            page_size=8, max_batch=1, fast_slots=32, slow_slots=128,
            memos_enabled=False, **kw))
        eng.submit([3, 1, 4], max_new=30)
        hist = eng.run(max_steps=600)
        assert eng.batcher.all_done()
        return hist

    n_ref = len(history(reference=True))
    n_fused = len(history(decode_block=16))
    assert n_ref == 32                   # one step per token: 2 prompt + 30
    assert n_fused <= -(-32 // 16) + 2   # one step per dispatch (+pow2 tail)


def test_three_tier_serving_end_to_end(model):
    """The HBM -> DRAM-sim -> NVM-sim hierarchy serves correctly under
    pressure: 8 HBM slots + a 12-slot DRAM-sim middle tier + host NVM,
    3 concurrent sequences, memos passes migrating between dispatches.
    Generated tokens must equal the dense-model oracle (tiering round
    trips are lossless) and pages must cross both hierarchy boundaries."""
    cfg, params = model
    # 8 + 4 device slots < the ~13-page working set, so pages spill all
    # the way to the host NVM tier and get promoted back on demand
    hier = MemoryHierarchy.three_tier(8, 4, 128)
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, hierarchy=hier, memos_interval=8,
        decode_block=8))
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    reqs = [eng.submit(p, max_new=24) for p in prompts]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    assert eng.memos.reports, "memos never ran between dispatches"
    st = eng.kv.store
    assert st.n_tiers == 3
    hbm_boundary = st.traffic[(0, 1)] + st.traffic[(1, 0)] \
        + st.traffic[(0, 2)] + st.traffic[(2, 0)]
    nvm_boundary = st.traffic[(1, 2)] + st.traffic[(2, 1)] \
        + st.traffic[(0, 2)] + st.traffic[(2, 0)]
    assert hbm_boundary > 0, "no pages crossed the HBM boundary"
    assert nvm_boundary > 0, "no pages crossed the NVM boundary"
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 24), \
            "3-tier round trip corrupted KV"
    occ = eng.kv.occupancy()
    assert occ["t1_dram_total"] == 4 and "t2_nvm_used" in occ


def test_overlap_plan_serving_parity(model):
    """Async memos pipeline under real serving pressure: the overlapped
    snapshot->plan->commit engine generates the same tokens as the
    synchronous engine and the dense-model oracle, closes SysMon passes
    at the same boundaries (identical WD history), and commits every
    pass exactly once (clean commit or degraded-sync, never dropped)."""
    cfg, params = model
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    kw = dict(max_new=16, memos_interval=8, fast_slots=8, decode_block=8)
    sync_e, sync_r = _run_engine(cfg, params, prompts, **kw)
    over_e, over_r = _run_engine(cfg, params, prompts, overlap_plan=True,
                                 **kw)
    assert over_e.memos.reports, "overlapped memos never committed"
    assert all(r.committed_async for r in over_e.memos.reports)
    assert over_e.memos.pages_committed > 0, \
        "the overlapped pipeline never committed a planned page"
    # page-granular accounting: every planned page is either committed
    # or degraded, never both, never dropped
    assert over_e.memos.pages_committed + over_e.memos.pages_degraded == \
        sum(r.pages_committed + r.pages_degraded
            for r in over_e.memos.reports)
    st = over_e.kv.store
    assert st.traffic[(FAST, SLOW)] > 0 and st.traffic[(SLOW, FAST)] > 0, \
        "no tiering traffic — the scenario exerts no HBM pressure"
    for a, b in zip(sync_r, over_r):
        assert a.generated == b.generated, "overlap commit corrupted KV"
        assert a.generated == ref_greedy(cfg, params, a.prompt, 16)
    assert len(sync_e.memos.reports) == len(over_e.memos.reports)
    np.testing.assert_array_equal(np.asarray(sync_e.sysmon.hist),
                                  np.asarray(over_e.sysmon.hist),
                                  err_msg="sysmon.hist")


def test_overlap_plan_forced_mid_plan_dirtying(model):
    """Every overlapped pass gets a planned page dirtied mid-plan under
    real serving/migration pressure: the dirty-epoch commit must degrade
    each dirtied page (it never moves on stale data), still commit the
    rest of the plan page-granularly, and keep serving losslessly."""
    cfg, params = model
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, fast_slots=8, slow_slots=128,
        memos_interval=8, decode_block=8, overlap_plan=True))

    dirtied = []

    def dirty_first_planned(mgr, decision, plans):
        for pl in plans:
            if len(pl):
                mgr.store.bump_version(int(pl.pages[0]))
                dirtied.append(int(pl.pages[0]))
                return

    eng.memos._mid_plan_hook = dirty_first_planned
    reqs = [eng.submit(p, max_new=16) for p in prompts]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    assert dirtied, "no pass ever planned a migration"
    # every injected bump degrades its page (the dispatch's own tail
    # writes can degrade more on top — >=, not ==)
    assert eng.memos.pages_degraded >= len(dirtied), \
        "a dirtied page slipped through the dirty-epoch commit"
    # ...but a conflict no longer discards the pass: clean siblings of
    # the dirtied pages still committed
    assert eng.memos.pages_committed > 0, \
        "page-granular commit landed nothing under pressure"
    # conflicts fire exactly at the commits where a plan was non-empty
    # (the hook's bump guarantees at least one degrade there)
    assert sum(r.plan_conflict for r in eng.memos.reports) == len(dirtied)
    assert all(r.committed_async for r in eng.memos.reports)
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 16), \
            "degraded commit corrupted KV"


@pytest.mark.parametrize("k", [1, 4, 8])
def test_pinned_tier_fused_parity_vs_reference(model, k):
    """Dual-pool serving (pinned-host deepest tier): the fused K-step
    dispatch — slow-tier KV appends and the wear_update scatter-add
    riding the scan — is bit-identical to the per-token reference path:
    tokens, every SysMon counter, version/read/write accounting, both
    pool buffers, and the pinned tier's wear counters."""
    cfg, params = model
    # 2 fast slots force most pages (tails included) into the pinned pool;
    # a huge gap interval keeps Start-Gap swaps out of this comparison
    # window (test_pinned_tier_fused_leveling_parity covers the swaps)
    def hier():
        return MemoryHierarchy.two_tier(2, 128, pinned_slow=True,
                                        gap_write_interval=10_000)
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    kw = dict(max_new=16, memos_enabled=False, hierarchy=hier())
    ref, rref = _run_engine(cfg, params, prompts, reference=True, **kw)
    fus, rfus = _run_engine(cfg, params, prompts, decode_block=k, **kw)
    assert ref.pinned_tier == fus.pinned_tier == 1
    sr, sf = ref.kv.store, fus.kv.store
    assert sr.wear_by_tier[1].writes_total > 0, \
        "no KV append ever landed in the pinned tier"
    for a, b in zip(rref, rfus):
        assert a.generated == b.generated
        assert a.generated == ref_greedy(cfg, params, a.prompt, 16)
    for f in SYSMON_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref.sysmon, f)),
            np.asarray(getattr(fus.sysmon, f)), err_msg=f"sysmon.{f}")
    np.testing.assert_array_equal(sr.version, sf.version)
    assert sr.writes_to == sf.writes_to
    assert sr.reads_from == sf.reads_from
    np.testing.assert_array_equal(np.asarray(sr.fast_pool),
                                  np.asarray(sf.fast_pool))
    np.testing.assert_array_equal(np.asarray(sr.pools[1].data),
                                  np.asarray(sf.pools[1].data))
    np.testing.assert_array_equal(sr.wear_by_tier[1].wear_counts(),
                                  sf.wear_by_tier[1].wear_counts())
    assert sr.wear_by_tier[1].writes_total == sf.wear_by_tier[1].writes_total


@pytest.mark.parametrize("k", [1, 4, 8])
def test_pinned_tier_fused_leveling_parity(model, k):
    """In-dispatch Start-Gap: with a tiny gap interval the fused dispatch
    advances the gap *inside the dispatch* (post-scan row swaps + remap
    rotation + wear charge) instead of serializing at the boundary.  The
    leveling trajectory — remap permutation, gap position,
    advance/rotation counts, leveling-write charge, pool bytes — must be
    bit-identical to the reference path, which levels on the host after
    every token: advance totals drain exactly one interval each, so the
    end-of-run state is cadence-independent.  Only the per-row
    attribution of app writes to pre- vs post-swap physical rows depends
    on cadence, so the wear-count *array* is exact at K=1 (identical
    cadence) and conserved in total for K>1."""
    cfg, params = model

    def hier():
        return MemoryHierarchy.two_tier(2, 16, pinned_slow=True,
                                        gap_write_interval=4)
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    kw = dict(max_new=16, memos_enabled=False, hierarchy=hier())
    ref, rref = _run_engine(cfg, params, prompts, reference=True, **kw)
    fus, rfus = _run_engine(cfg, params, prompts, decode_block=k, **kw)
    assert fus._gap_interval == 4
    wr, wf = ref.kv.store.wear_by_tier[1], fus.kv.store.wear_by_tier[1]
    lr = ref.kv.store.leveler_by_tier[1]
    lf = fus.kv.store.leveler_by_tier[1]
    assert lf.stats.advances > 0, "the scenario never advanced the gap"
    assert lf.stats.advances == lr.stats.advances
    assert lf.stats.gap == lr.stats.gap
    assert lf.stats.rotations == lr.stats.rotations
    assert lf._pending == lr._pending
    assert wf.leveling_writes == wr.leveling_writes > 0
    assert wf.writes_total == wr.writes_total
    np.testing.assert_array_equal(wf._remap, wr._remap)
    if k == 1:
        np.testing.assert_array_equal(wf.wear_counts(), wr.wear_counts())
    else:
        assert wf.wear_counts().sum() == wr.wear_counts().sum()
    wr.check()
    wf.check()
    for a, b in zip(rref, rfus):
        assert a.generated == b.generated
        assert a.generated == ref_greedy(cfg, params, a.prompt, 16)
    np.testing.assert_array_equal(
        np.asarray(ref.kv.store.pools[1].data),
        np.asarray(fus.kv.store.pools[1].data))


def test_pinned_three_tier_overlap_end_to_end(model):
    """The full tentpole in one scenario: HBM -> DRAM-sim -> pinned NVM
    hierarchy served with the overlapped memos pipeline.  Pages cross
    both hierarchy boundaries, pinned-resident pages are attended and
    appended in place, wear telemetry accumulates on device, and the
    generated tokens equal the dense-model oracle."""
    cfg, params = model
    hier = MemoryHierarchy.three_tier(8, 4, 128, pinned_nvm=True)
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=3, hierarchy=hier, memos_interval=8,
        decode_block=8, overlap_plan=True))
    assert eng.pinned_tier == 2
    prompts = [[5, 7, 9, 11, 13], [21, 22, 23], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    reqs = [eng.submit(p, max_new=24) for p in prompts]
    eng.run(max_steps=600)
    assert eng.batcher.all_done()
    assert eng.memos.reports, "memos never committed between dispatches"
    st = eng.kv.store
    hbm_boundary = st.traffic[(0, 1)] + st.traffic[(1, 0)] \
        + st.traffic[(0, 2)] + st.traffic[(2, 0)]
    nvm_boundary = st.traffic[(1, 2)] + st.traffic[(2, 1)] \
        + st.traffic[(0, 2)] + st.traffic[(2, 0)]
    assert hbm_boundary > 0, "no pages crossed the HBM boundary"
    assert nvm_boundary > 0, "no pages crossed the NVM boundary"
    assert st.wear_by_tier[2].writes_total > 0
    for p, r in zip(prompts, reqs):
        assert r.generated == ref_greedy(cfg, params, p, 24), \
            "pinned 3-tier round trip corrupted KV"


def test_moe_engine_tracks_expert_hotness():
    cfg = smoke(registry()["olmoe_1b_7b"])
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=8, max_batch=2, fast_slots=32, slow_slots=64))
    eng.submit([3, 1, 4, 1, 5], max_new=4)
    eng.run(max_steps=50)
    counts = eng.expert_counts
    assert counts is not None and counts.sum() > 0
    # every processed token routes to top_k experts per MoE layer
    steps_tokens = 5 + 4 - 1
    assert counts.sum() == steps_tokens * cfg.top_k * cfg.n_layers


def test_scheduler_invariants():
    b = ContinuousBatcher(max_batch=2)
    reqs = [Request(i, [1, 2], 3) for i in range(4)]
    for r in reqs:
        b.submit(r)
    admitted = b.admit()
    assert len(admitted) == 2
    assert set(b.running) == {0, 1}
    victim = b.preempt_lowest()
    assert victim.preempted and victim.slot is None
    again = b.admit()                      # resumed before new requests
    assert victim in again
    b.finish(b.running[0], step=5)
    assert not b.all_done()
