from .base import (ARCH_IDS, LONG_CONTEXT_SKIP, SHAPES, ArchConfig,
                   ShapeConfig, cells, get_arch, registry, smoke)

__all__ = ["ARCH_IDS", "LONG_CONTEXT_SKIP", "SHAPES", "ArchConfig",
           "ShapeConfig", "cells", "get_arch", "registry", "smoke"]
