"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64-expert top-8 MoE."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,               # per-expert FFN width
    expert_d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    softmax_before_topk=True,
    rope_theta=1e4,
    qk_norm=True,            # OLMoE uses QK-norm
)
