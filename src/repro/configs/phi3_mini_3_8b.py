"""Phi-3-mini-3.8B [arXiv:2404.14219] — dense, MHA-as-GQA (kv = heads)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3_mini_3_8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    rope_theta=1e4,
)
