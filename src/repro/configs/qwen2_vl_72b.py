"""Qwen2-VL-72B [arXiv:2409.12191; hf] — M-RoPE; vision frontend stubbed
(input_specs provides precomputed patch embeddings per the assignment)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),   # (temporal, height, width) of head_dim/2
    input_mode="embeds",
)
