"""Architecture + shape configuration system.

Each assigned architecture gets a module in this package defining an
``ArchConfig`` with its exact published dimensions; ``registry()`` maps
``--arch <id>`` to it.  ``smoke(cfg)`` derives the reduced same-family
config used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None       # default: d_model // n_heads
    # --- attention options ----------------------------------------------------
    rope_theta: float = 1e4
    rope_theta_global: float | None = None   # gemma3 global layers
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int | None = None        # SWA on all attn layers (mixtral)
    local_global: tuple[int, int] | None = None  # (local:global ratio, local window)
    soft_cap: float | None = None
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE
    # --- FFN / MoE -------------------------------------------------------------
    mlp_kind: str = "swiglu"        # swiglu | gelu (musicgen)
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int | None = None  # d_ff of each expert (olmoe: 1024)
    softmax_before_topk: bool = True
    aux_loss_weight: float = 0.01
    moe_capacity_factor: float = 1.25   # EP per-shard capacity (GShard-style)
    # --- SSM / hybrid -----------------------------------------------------------
    layout: str = "attn"            # attn | mamba | hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    shared_attn_every: int = 0      # hybrid: shared attn block every k layers
    # --- embeddings / frontend ---------------------------------------------------
    input_mode: str = "tokens"      # tokens | embeds (stubbed vlm/audio frontend)
    tie_embeddings: bool = False
    scale_embed: bool = False       # gemma-style sqrt(d) embedding scale
    gemma_norm: bool = False        # RMSNorm uses (1 + scale)
    norm_eps: float = 1e-6
    # --- runtime ------------------------------------------------------------------
    chunk: int = 128                # SSD chunk length
    remat: bool = True
    attn_q_chunk: int | None = None  # flash-style query chunking (§Perf)
    kv_cache_quant: bool = False     # int8 KV cache + per-head scales (§Perf)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_window_pattern(self) -> list[int]:
        """Per-layer attention window (0 = full causal); [] for pure SSM."""
        if self.layout == "mamba":
            return []
        if self.local_global is not None:
            ratio, win = self.local_global
            # gemma3 pattern: `ratio` local layers then 1 global
            out = []
            for i in range(self.n_layers):
                out.append(0 if (i % (ratio + 1)) == ratio else win)
            return out
        w = self.sliding_window or 0
        return [w] * self.n_layers

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, L = self.d_model, self.n_layers
        dh = self.head_dim
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.layout == "mamba":
            di = self.ssm_expand * d
            per = (2 * d * di + 2 * d * self.ssm_state + d * (di // self.ssm_headdim)
                   + di * d + 2 * d)
            return n + L * per
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        if self.is_moe:
            eff = self.expert_d_ff or self.d_ff
            ffn = self.n_experts * 3 * d * eff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff if self.mlp_kind == "swiglu" else 2 * d * self.d_ff
        per = attn + ffn + 2 * d
        if self.layout == "hybrid":
            di = self.ssm_expand * d
            per = (2 * d * di + 2 * d * self.ssm_state
                   + d * (di // self.ssm_headdim) + di * d + 2 * d)
            shared = attn + 3 * d * self.d_ff + 2 * d
            return n + L * per + shared
        return n + L * per

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dh = self.head_dim
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        eff = self.expert_d_ff or self.d_ff
        ffn = self.top_k * 3 * d * eff + d * self.n_experts
        return n + L * (attn + ffn + 2 * d)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}

ARCH_IDS = [
    "olmoe_1b_7b", "mixtral_8x7b", "qwen2_vl_72b", "qwen2_5_14b",
    "phi3_mini_3_8b", "qwen3_4b", "gemma3_4b", "zamba2_7b",
    "mamba2_1_3b", "musicgen_medium",
]

# archs whose long_500k cell is skipped: pure full-attention, O(S) KV at 512k
# with no sub-quadratic mechanism (DESIGN.md Sec. 4).
LONG_CONTEXT_SKIP = {
    "olmoe_1b_7b", "qwen2_vl_72b", "qwen2_5_14b", "phi3_mini_3_8b",
    "qwen3_4b", "musicgen_medium",
}


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def registry() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells honoring long-context skips."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES.values():
            if (not include_skipped and s.kind == "long_decode"
                    and a in LONG_CONTEXT_SKIP):
                continue
            out.append((a, s.name))
    return out


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        n_layers=min(cfg.n_layers, 4 if cfg.layout == "hybrid" else 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256,
        vocab=256,
        n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        expert_d_ff=64 if cfg.is_moe else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        local_global=(cfg.local_global[0], 16) if cfg.local_global else None,
        shared_attn_every=3 if cfg.shared_attn_every else 0,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else None,
        chunk=8,
    )
