"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.
The EnCodec frontend is stubbed: input_specs provides precomputed frame
embeddings (assignment rule for [audio] entries).  Positional encoding is
RoPE here (the published model uses sinusoidal embeddings — noted in
DESIGN.md as a TPU-stack adaptation; the backbone dims are exact)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    mlp_kind="gelu",
    input_mode="embeds",
)
