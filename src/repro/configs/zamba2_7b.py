"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention block
applied every `shared_attn_every` layers (weights reused at each site)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,                 # shared block FFN
    vocab=32000,
    layout="hybrid",
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    shared_attn_every=7,        # 81 layers -> 11 shared-attn applications
    rope_theta=1e4,
)
