"""Gemma3-4B [hf:google/gemma-3 family] — 5:1 local:global attention,
local window 1024, dual rope theta, gemma-style norms, tied embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    local_global=(5, 1024),       # 5 local (window 1024) : 1 global
    rope_theta=1e4,               # local layers
    rope_theta_global=1e6,        # global layers
    gemma_norm=True,
    scale_embed=True,
    tie_embeddings=True,
)
