"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,                 # unused (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    layout="mamba",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
)
