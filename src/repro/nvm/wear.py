"""Per-slot NVM wear telemetry (paper Sec. 7.1, Table 1 endurance).

The slow tier is the NVM-channel analogue: every write that lands there
consumes cell endurance.  This module keeps the online record of that
consumption:

  * ``WearState`` — a device pytree of per-*physical*-slot write counters
    plus the logical->physical remap table that the Start-Gap leveler
    (``nvm/leveling.py``) rotates underneath the page store;
  * ``record_writes`` — the counter update, a ``kernels/wear_update``
    Pallas scatter-add (XLA fallback off-TPU);
  * ``NvmWear`` — the host-side tracker owned by ``TierStore``: it maps
    logical slow-pool slots through the remap, charges the counters on
    every slow-tier write (single-page and batched paths), and exposes
    the wear distribution to the energy model and the placement policy.

Wear granularity: the paper models 64 B wear blocks; a page write touches
each of its blocks exactly once, so per-slot write counts equal per-block
write counts within that slot — one counter per slot suffices for max/mean
wear and the lifetime projection.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.wear_update import wear_update


class WearState(NamedTuple):
    """Device-resident wear telemetry (a jax pytree).

    wear  : int32 [n_slots] — writes absorbed by each *physical* slot
    remap : int32 [n_slots] — logical slot -> physical slot (a permutation;
            identity until the leveler starts rotating the pool)
    """

    wear: jnp.ndarray
    remap: jnp.ndarray

    @property
    def n_slots(self) -> int:
        return self.wear.shape[0]


def init_wear(n_slots: int) -> WearState:
    return WearState(
        wear=jnp.zeros((n_slots,), jnp.int32),
        remap=jnp.arange(n_slots, dtype=jnp.int32),
    )


def record_writes(state: WearState, phys_slots, amount=None,
                  valid=None) -> WearState:
    """Charge write events onto physical slots (scatter-add kernel)."""
    return state._replace(
        wear=wear_update(state.wear, jnp.asarray(phys_slots, jnp.int32),
                         amount, valid=valid))


class NvmWear:
    """Host-side wear tracker for one slow pool.

    Keeps the ``WearState`` pytree plus numpy mirrors of the remap (and
    its inverse) so the TierStore's host read/write paths can translate
    logical slots without a device round-trip.  Write events accumulate
    in a host-side pending buffer (the TierStore write path must not pay
    a device dispatch per page) and are flushed into the device counters
    through the ``wear_update`` scatter-add whenever the telemetry is
    read — one kernel call per pass instead of one per write.
    """

    def __init__(self, n_slots: int):
        self.state = init_wear(n_slots)
        self._remap = np.arange(n_slots, dtype=np.int64)   # logical -> phys
        self._inv = np.arange(n_slots, dtype=np.int64)     # phys -> logical
        self._pending = np.zeros(n_slots, np.int64)        # unflushed events
        self.writes_total = 0        # app + migration writes (not leveling)
        self.leveling_writes = 0     # extra writes spent rotating the pool

    @property
    def n_slots(self) -> int:
        return self.state.n_slots

    # -- logical -> physical translation --------------------------------------
    def phys(self, slots) -> np.ndarray:
        return self._remap[np.asarray(slots, np.int64)]

    def phys_one(self, slot: int) -> int:
        return int(self._remap[slot])

    # -- counter updates -------------------------------------------------------
    def record_phys(self, phys_slots, *, leveling: bool = False) -> None:
        p = np.asarray(phys_slots, np.int64)
        np.add.at(self._pending, p, 1)
        if leveling:
            self.leveling_writes += int(p.size)
        else:
            self.writes_total += int(p.size)

    def flush(self) -> WearState:
        """Push pending host-side events into the device counters (one
        ``wear_update`` scatter-add) and return the up-to-date state."""
        ids = np.nonzero(self._pending)[0]
        if ids.size:
            self.state = record_writes(self.state, ids,
                                       amount=self._pending[ids])
            self._pending[ids] = 0
        return self.state

    def adopt_scan_writes(self, new_wear, n_app_writes: int,
                          leveling_writes: int = 0) -> None:
        """Adopt counters updated *inside* a fused device dispatch.

        The pinned-host serving path carries this tracker's ``wear``
        array through the decode ``lax.scan`` and scatter-adds each
        slow-tier KV append on device (zero-round-trip telemetry); at the
        dispatch boundary the engine hands the updated array back here
        and credits the app-write total.  ``leveling_writes`` credits the
        extra row rewrites spent by in-dispatch Start-Gap advances (two
        per advance), which the dispatch also charged into the array.
        Host-side pending events are a separate buffer and are
        unaffected."""
        self.state = self.state._replace(wear=jnp.asarray(new_wear,
                                                          jnp.int32))
        self.writes_total += int(n_app_writes)
        self.leveling_writes += int(leveling_writes)

    def adopt_scan_remap(self, new_remap) -> None:
        """Adopt the logical->physical remap as rotated by in-dispatch
        Start-Gap advances: the fused dispatch swaps remap entries as it
        swaps pool rows (the post-scan advance loop); the boundary hands
        the final permutation back here so the host mirrors (and every
        host-side read/write path) stay in sync."""
        r = np.asarray(new_remap, np.int64)
        self._remap = r
        inv = np.empty_like(r)
        inv[r] = np.arange(r.size, dtype=np.int64)
        self._inv = inv
        self.state = self.state._replace(remap=jnp.asarray(r, jnp.int32))

    # -- leveler hook -----------------------------------------------------------
    def swap_phys(self, a: int, b: int) -> None:
        """Swap which logical slots map to physical ``a`` and ``b`` (the
        leveler swaps the pool rows; this keeps the remap in sync)."""
        la, lb = int(self._inv[a]), int(self._inv[b])
        self._remap[la], self._remap[lb] = b, a
        self._inv[a], self._inv[b] = lb, la
        self.state = self.state._replace(
            remap=jnp.asarray(self._remap, jnp.int32))

    # -- telemetry readout -------------------------------------------------------
    def wear_counts(self) -> np.ndarray:
        """int64 [n_slots] per-physical-slot write counts (host copy)."""
        return np.asarray(self.flush().wear, np.int64)

    def max_wear(self) -> int:
        return int(self.wear_counts().max(initial=0))

    def mean_wear(self) -> float:
        w = self.wear_counts()
        return float(w.mean()) if w.size else 0.0

    def check(self) -> None:
        """Invariants: remap is a permutation and matches its inverse and
        the device copy."""
        self.flush()
        n = self.n_slots
        assert sorted(self._remap.tolist()) == list(range(n)), \
            "remap is not a permutation"
        assert (self._inv[self._remap] == np.arange(n)).all(), \
            "remap inverse out of sync"
        np.testing.assert_array_equal(
            np.asarray(self.state.remap, np.int64), self._remap,
            err_msg="device remap out of sync with host mirror")
