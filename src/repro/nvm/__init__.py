"""NVM wear & energy telemetry subsystem (paper Sec. 7.1, Table 1).

Closes the loop from slow-tier writes to placement policy:

  wear      — WearState pytree (per-physical-slot counters + remap) and
              the NvmWear host tracker, fed by the kernels/wear_update
              scatter-add on every slow-tier write
  leveling  — Start-Gap-style gap rotation over the slow pool (remap
              rewrite; the rest of the system keeps logical slot ids)
  energy    — per-pass energy/lifetime accounting (EnergyMeter ->
              NvmReport) on the Table-1 MediumParams constants

``MemosManager`` consumes the wear-rate signal: when the projected
lifetime drops below the configured horizon, WD pages pick up a
wear-penalty term in placement ranking and are steered to the fast tier
— the paper's 40X lifetime mechanism.
"""
from .wear import NvmWear, WearState, init_wear, record_writes
from .leveling import LevelingStats, StartGapLeveler
from .energy import EnergyMeter, NvmReport

__all__ = [
    "NvmWear", "WearState", "init_wear", "record_writes",
    "LevelingStats", "StartGapLeveler",
    "EnergyMeter", "NvmReport",
]
