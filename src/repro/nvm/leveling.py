"""Start-Gap-style wear leveling over the slow pool (paper Sec. 7.1).

The paper assumes Start-Gap leveling at 95% of ideal cell lifetime for
its NVM projections; this module makes that mechanism real for the repro:
a gap pointer sweeps the physical slot space, and every ``gap_write_interval``
slow-tier writes it advances one position by swapping two adjacent
physical rows and updating the logical->physical remap in ``NvmWear``.
After a full sweep every row has shifted by one — a rotation, so a
write-hot *logical* slot spreads its wear across every *physical* slot
over time while the page table, allocator, and migration engines keep
using stable logical slot ids (they never notice the rotation).

The classic Start-Gap keeps one spare row and moves the gap with a single
copy; we have no spare row in the pool, so an advance is an adjacent-row
swap (two writes instead of one — charged to the wear counters as
leveling overhead).  The default advance interval derives from the cost
model's pinned 95%-of-ideal leveling efficiency.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.costmodel import startgap_interval

from .wear import NvmWear


@dataclass
class LevelingStats:
    advances: int = 0       # gap moves executed
    rotations: int = 0      # completed full sweeps of the pool
    gap: int = 0            # current gap position (physical slot)


class StartGapLeveler:
    """Rotates the physical slow pool underneath the logical slot space.

    ``note_writes(store, n)`` is called by the TierStore after every
    slow-tier write; once the pending count crosses the interval the gap
    advances.  ``advance(store)`` swaps physical rows ``gap`` and
    ``gap+1`` (data, quantization scales, remap, wear charge).
    """

    def __init__(self, wear: NvmWear, gap_write_interval: int | None = None):
        self.wear = wear
        self.interval = (startgap_interval() if gap_write_interval is None
                         else max(1, int(gap_write_interval)))
        self.stats = LevelingStats()
        self._pending = 0

    def note_writes(self, store, n: int) -> int:
        """Account ``n`` demand writes; advance the gap as many steps as
        the interval allows.  Returns the number of advances performed."""
        if self.wear.n_slots < 2:
            return 0
        self._pending += int(n)
        done = 0
        while self._pending >= self.interval:
            self._pending -= self.interval
            self.advance(store)
            done += 1
        return done

    def advance(self, store) -> None:
        """One gap move: swap physical rows (gap, gap+1) of the slow pool.
        ``store`` may expose ``swap_rows`` (host *and* pinned-host jax
        pools route through it); the legacy numpy fancy-index swap is kept
        for bare-pool callers."""
        a = self.stats.gap
        b = a + 1
        if hasattr(store, "swap_rows"):
            store.swap_rows(a, b)
        else:
            pool = store.slow_pool
            pool[[a, b]] = pool[[b, a]]
            if store.slow_scale is not None:
                store.slow_scale[[a, b]] = store.slow_scale[[b, a]]
        self.wear.swap_phys(a, b)
        # the swap physically rewrites both rows
        self.wear.record_phys([a, b], leveling=True)
        self.stats.advances += 1
        self.stats.gap = b
        if self.stats.gap >= self.wear.n_slots - 1:
            self.stats.gap = 0
            self.stats.rotations += 1

    def adopt_scan_advances(self, n_advances: int, pending: int) -> None:
        """Fold in advances executed *inside* a fused serving dispatch:
        the scan carries (remap, gap, pending) and performs the
        row-swap + remap-update itself (see ``serving/engine.py``), so
        the boundary only replays the counter arithmetic — gap position
        (same wrap at ``n_slots - 1`` as :meth:`advance`), rotation
        count, and the leftover pending-write credit."""
        n = int(n_advances)
        if n == 0:
            self._pending = int(pending)
            return
        self.stats.advances += n
        period = max(self.wear.n_slots - 1, 1)
        g = self.stats.gap + n
        self.stats.rotations += g // period
        self.stats.gap = g % period
        self._pending = int(pending)
