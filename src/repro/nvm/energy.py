"""Per-pass NVM energy / lifetime accounting (paper Sec. 7.1, Table 1).

Builds on the ``MediumParams`` constants in ``core/costmodel.py``: every
memos pass the ``EnergyMeter`` snapshots the TierStore's slow-tier
counters (app + migration writes from the wear tracker, reads from the
store's counters), converts them to dynamic energy via the Table-1
per-access energies, adds the standby floor, and projects NVM lifetime
from the *measured* wear distribution — the max-wear slot sets the actual
lifetime, the mean-wear slot the ideal (perfectly leveled) bound, and
their ratio is the wear imbalance the Start-Gap leveler exists to close.

Accumulated reports feed ``MemosReport.nvm`` (the policy's wear-pressure
signal) and ``benchmarks/fig_wear_energy.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.costmodel import (LEVELING_EFFICIENCY, NVM, MediumParams,
                                  lifetime_years_from_wear,
                                  page_access_energy_nj, standby_power_w)


@dataclass
class NvmReport:
    """One pass worth of NVM-side telemetry."""

    passes: int                    # completed passes including this one
    window_s: float                # notional wall-clock span of one pass
    slow_reads: int                # page reads served by the slow tier
    slow_writes: int               # page writes absorbed (app + migration)
    leveling_writes: int           # extra writes spent rotating the pool
    read_energy_mj: float
    write_energy_mj: float
    dynamic_power_mw: float        # over this pass's window
    standby_w: float
    capacity_gb: float
    wear_max: int                  # writes on the worst physical slot (total)
    wear_mean: float
    wear_imbalance: float          # max / mean (1.0 = perfectly leveled)
    lifetime_years_actual: float   # endurance / max-wear rate
    lifetime_years_ideal: float    # endurance * 95% / mean-wear rate

    def to_dict(self) -> dict:
        return {k: (float(v) if isinstance(v, float) else v)
                for k, v in self.__dict__.items()}

    def publish(self, reg, prefix: str = "nvm.") -> None:
        """Publish this pass into an ``obs.MetricsRegistry``: energy as
        counters (per-pass mJ accumulates across passes), wear / power /
        lifetime as gauges."""
        reg.counter(f"{prefix}read_energy_mj",
                    "dynamic read energy (mJ)").inc(self.read_energy_mj)
        reg.counter(f"{prefix}write_energy_mj",
                    "dynamic write energy (mJ)").inc(self.write_energy_mj)
        reg.counter(f"{prefix}slow_writes",
                    "page writes absorbed this tier").inc(self.slow_writes)
        reg.counter(f"{prefix}leveling_writes",
                    "Start-Gap rotation writes").inc(self.leveling_writes)
        reg.gauge(f"{prefix}wear_max",
                  "writes on the worst physical slot").set(self.wear_max)
        reg.gauge(f"{prefix}wear_imbalance",
                  "max/mean wear ratio").set(self.wear_imbalance)
        reg.gauge(f"{prefix}dynamic_power_mw",
                  "dynamic power over the pass window").set(
                      self.dynamic_power_mw)
        lt = self.lifetime_years_actual
        if lt != float("inf"):
            reg.gauge(f"{prefix}lifetime_years",
                      "projected endurance lifetime").set(lt)


class EnergyMeter:
    """Accumulates one tier's access counts pass by pass.

    One meter attaches to one wear-tracked (or at least host-resident)
    tier — ``tier`` defaults to the store's deepest wear-tracked tier
    (falling back to the deepest tier), and ``medium`` defaults to that
    tier's ``MediumSpec`` medium, so a plain ``EnergyMeter(store)`` on a
    two-tier hierarchy behaves exactly as before.

    ``end_pass()`` closes the current window and returns its ``NvmReport``;
    ``project_lifetime()`` reads the live wear counters mid-pass (the
    placement policy's wear-rate signal) without closing the window.
    """

    def __init__(self, store, medium: MediumParams | None = None,
                 window_s: float = 1.0, *, tier: int | None = None):
        self.store = store
        if tier is None:
            wt = store.hierarchy.wear_tiers()
            tier = wt[-1] if wt else store.hierarchy.deepest
        self.tier = int(tier)
        self.medium = medium or store.hierarchy[self.tier].medium
        self.window_s = float(window_s)   # default span of one pass
        self.passes = 0
        self.elapsed = 0.0                # accumulated closed-window seconds
        self.reports: list[NvmReport] = []
        self._snap = self._counters()

    @property
    def _wear(self):
        return self.store.wear_by_tier.get(self.tier)

    def _counters(self) -> dict:
        w = self._wear
        return {
            "slow_writes": (w.writes_total if w is not None
                            else self.store.writes_to[self.tier]),
            "slow_reads": self.store.reads_from[self.tier],
            "leveling_writes": (w.leveling_writes if w is not None else 0),
        }

    @property
    def capacity_bytes(self) -> int:
        return self.store.hierarchy[self.tier].slots * self.store.page_nbytes

    def elapsed_s(self) -> float:
        return self.elapsed

    def project_lifetime(self) -> float:
        """Years until the worst physical slot exhausts endurance, from the
        live wear counters and elapsed (notional) time.  inf before any
        wear has accumulated or when wear is untracked."""
        w = self._wear
        if w is None:
            return float("inf")
        return lifetime_years_from_wear(w.max_wear(), self.elapsed_s(),
                                        self.medium)

    def end_pass(self, window_s: float | None = None) -> NvmReport:
        """Close the current accounting window.  ``window_s`` overrides the
        default span — the memos manager passes the pass's *actual* step
        span so adaptive interval growth doesn't inflate the wear rate."""
        window_s = self.window_s if window_s is None else float(window_s)
        self.passes += 1
        self.elapsed += window_s
        cur = self._counters()
        d = {k: cur[k] - self._snap[k] for k in cur}
        self._snap = cur
        m = self.medium
        page_b = self.store.page_nbytes
        # leveling swaps are real NVM writes: charge their energy too
        writes = d["slow_writes"] + d["leveling_writes"]
        read_nj = d["slow_reads"] * page_access_energy_nj(m, page_b, False)
        write_nj = writes * page_access_energy_nj(m, page_b, True)
        w = self._wear
        wear_max = w.max_wear() if w is not None else 0
        wear_mean = w.mean_wear() if w is not None else 0.0
        elapsed = self.elapsed_s()
        report = NvmReport(
            passes=self.passes,
            window_s=window_s,
            slow_reads=d["slow_reads"],
            slow_writes=d["slow_writes"],
            leveling_writes=d["leveling_writes"],
            read_energy_mj=read_nj * 1e-6,
            write_energy_mj=write_nj * 1e-6,
            dynamic_power_mw=(read_nj + write_nj) * 1e-9
            / max(window_s, 1e-12) * 1e3,
            standby_w=standby_power_w(self.capacity_bytes / 2**30, m),
            capacity_gb=self.capacity_bytes / 2**30,
            wear_max=wear_max,
            wear_mean=wear_mean,
            wear_imbalance=wear_max / max(wear_mean, 1e-12),
            lifetime_years_actual=lifetime_years_from_wear(
                wear_max, elapsed, m),
            lifetime_years_ideal=lifetime_years_from_wear(
                wear_mean, elapsed, m, efficiency=LEVELING_EFFICIENCY),
        )
        self.reports.append(report)
        return report
