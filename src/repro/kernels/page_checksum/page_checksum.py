"""Per-page integrity checksum Pallas TPU kernel.

Companion to the ``page_gather`` data mover: where gather packs pages
for a tier move, this kernel folds each page's stored bits into one
uint32 position-weighted checksum (definition + detection proof in
ref.py).  Same scalar-prefetch DMA pipeline — the checksum of page i
computes while page i+1's block streams in — so a scrub or a
promotion pre-flight verify costs one dispatch over the slot list
instead of a host round-trip per page.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import _UINT_JNP


def _checksum_kernel(idx_ref, src_ref, out_ref, *, uint_dtype):
    u = jax.lax.bitcast_convert_type(src_ref[...], uint_dtype)
    u = u.astype(jnp.uint32)
    # linear element index via per-dim broadcasted iotas (TPU forbids 1D
    # iota); the leading block dim is 1 so its iota contributes nothing
    lin = jnp.zeros(u.shape, jnp.uint32)
    stride = 1
    for d in range(u.ndim - 1, -1, -1):
        lin = lin + jax.lax.broadcasted_iota(jnp.uint32, u.shape, d) \
            * jnp.uint32(stride)
        stride *= u.shape[d]
    s = jnp.sum(u * (2 * lin + 1))
    out_ref[...] = jnp.full(out_ref.shape, s, jnp.uint32)


def page_checksum_pallas(pool: jnp.ndarray, idx: jnp.ndarray,
                         *, interpret: bool = False) -> jnp.ndarray:
    """pool: [n_slots, *page_shape]; idx: int32 [k] -> uint32 [k]."""
    from functools import partial

    k = idx.shape[0]
    page_shape = pool.shape[1:]
    blk = (1, *page_shape)
    zeros = (0,) * len(page_shape)
    itemsize = jnp.dtype(pool.dtype).itemsize
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec(blk, lambda i, idx: (idx[i], *zeros))],
        out_specs=pl.BlockSpec((1,), lambda i, idx: (i,)),
    )
    return pl.pallas_call(
        partial(_checksum_kernel, uint_dtype=_UINT_JNP[itemsize]),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k,), jnp.uint32),
        interpret=interpret,
    )(idx.astype(jnp.int32), pool)
