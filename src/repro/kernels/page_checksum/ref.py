"""Reference page-checksum implementations (pure jnp + numpy).

The checksum is a position-weighted sum over the page's *stored bit
pattern*: view the page as unsigned integers u[0..N) of the element
width, then

    checksum(page) = sum_i u[i] * (2*i + 1)   (mod 2**32)

Every weight 2*i+1 is odd, so flipping bit b of element i changes the
sum by +-2**b * (2*i+1) — a value whose 2-adic valuation is exactly b.
For element widths <= 32 bits that is never 0 mod 2**32, so **any
single-bit flip is guaranteed detected** (the property test in
tests/test_faults.py exercises this exhaustively).  Arithmetic is done
in uint32 with natural wraparound, which IS the mod-2**32 reduction —
numpy, XLA, and the Pallas kernel all agree bit for bit.

Checksums are computed over the raw stored representation (uint16 for
bf16 host pages, int8 for quantized pools, uint32 for f32), never over
decoded floats: integrity tracks media bits, not values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_UINT_NP = {1: np.uint8, 2: np.uint16, 4: np.uint32}
_UINT_JNP = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def checksum_np(pages: np.ndarray) -> np.ndarray:
    """pages: [k, *page_shape] (any <=4-byte dtype) -> uint32 [k]."""
    itemsize = pages.dtype.itemsize
    if itemsize not in _UINT_NP:
        raise TypeError(f"unsupported element width {itemsize} bytes")
    u = np.ascontiguousarray(pages).view(_UINT_NP[itemsize])
    u = u.reshape(pages.shape[0], -1).astype(np.uint32)
    w = (2 * np.arange(u.shape[1], dtype=np.uint32) + 1)
    return (u * w[None, :]).sum(axis=1, dtype=np.uint32)


def page_checksum_ref(pages: jnp.ndarray) -> jnp.ndarray:
    """pages: [k, *page_shape] -> uint32 [k] (pure jnp, jit-safe)."""
    itemsize = jnp.dtype(pages.dtype).itemsize
    if itemsize not in _UINT_JNP:
        raise TypeError(f"unsupported element width {itemsize} bytes")
    u = jax.lax.bitcast_convert_type(pages, _UINT_JNP[itemsize])
    u = u.reshape(pages.shape[0], -1).astype(jnp.uint32)
    w = (2 * jnp.arange(u.shape[1], dtype=jnp.uint32) + 1)
    return jnp.sum(u * w[None, :], axis=1)
