"""Dispatching wrapper for the page-checksum kernel.

Same three-path dispatch as ``page_gather``: Pallas compiled on TPU,
``interpret=True`` for kernel-parity tests, and a jitted XLA
gather+reference fallback everywhere else (interpreter-mode Pallas
loops the grid in Python — far too slow to sit on the scrub path of a
CPU host).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .page_checksum import page_checksum_pallas
from .ref import page_checksum_ref


@partial(jax.jit, static_argnames=("interpret",))
def _checksum_pallas(pool, idx, *, interpret: bool):
    return page_checksum_pallas(pool, idx, interpret=interpret)


@jax.jit
def _checksum_xla(pool, idx):
    return page_checksum_ref(jnp.take(pool, idx, axis=0))


def page_checksum(pool, idx, *, interpret: bool | None = None):
    """checksums[i] = checksum(pool[idx[i]]).  idx: int [k] -> uint32 [k]."""
    idx = idx.astype(jnp.int32)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _checksum_xla(pool, idx)
        interpret = False
    return _checksum_pallas(pool, idx, interpret=interpret)
