from .ops import page_checksum
from .ref import checksum_np, page_checksum_ref

__all__ = ["page_checksum", "page_checksum_ref", "checksum_np"]
