"""Oracle: the models/ssm.py chunked SSD (itself validated against the
O(1)-state sequential decode recurrence in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk):
    """Same signature as ssd_scan_pallas (Bm/Cm: [B, L, N], G=1)."""
    y, h = ssd_chunked(x, dt, A, Bm[:, :, None, :], Cm[:, :, None, :], chunk)
    return y, h


def ssd_sequential_ref(x, dt, A, Bm, Cm):
    """Slow O(L) sequential recurrence — the ground-truth definition."""
    import jax
    Bsz, L, H, P = x.shape

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt * A)                       # [B,H]
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, Bm.shape[-1], P), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
