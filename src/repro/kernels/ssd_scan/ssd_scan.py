"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid: (B, n_chunks) — chunks are innermost and TPU grids run sequentially,
so the inter-chunk recurrent state h [H, N, P] lives in VMEM scratch and
carries across chunk iterations (reset at chunk 0 of each batch).  This
fuses the three phases of SSD (intra-chunk attention-form, chunk-state
accumulation, inter-chunk recurrence) into one pass over HBM: x/dt/B/C are
each read exactly once, vs. 3+ reads for the unfused jnp composition.

VMEM residency per chunk (Q=128, H<=128, P=64, N<=128):
  x block Q*H*P (~4 MB f32), B/C blocks Q*N (tiny), state H*N*P (~4 MB),
  decay tables Q*H — comfortably inside the 16 MB v5e VMEM with double
  buffering on the streamed (Thrashing-class) x/B/C blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, hout_ref, h_scr, *, chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _reset():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)     # [Q, H, P]
    dt = dt_ref[0, 0].astype(jnp.float32)   # [Q, H]
    A = a_ref[...].astype(jnp.float32)      # [H]
    Bm = b_ref[0, 0].astype(jnp.float32)    # [Q, N]   (G=1)
    Cm = c_ref[0, 0].astype(jnp.float32)    # [Q, N]

    Q = chunk
    dA = dt * A                              # [Q, H]
    dA_cs = jnp.cumsum(dA, axis=0)           # [Q, H]

    # intra-chunk: att[h,i,j] = (C_i.B_j) exp(dAcs_i - dAcs_j) dt_j, j<=i
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [Q, Q]
    seg = dA_cs[:, None, :] - dA_cs[None, :, :]                  # [Q, Q, H]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = iota_j <= iota_i
    att = jnp.where(tri[:, :, None], CB[:, :, None] * jnp.exp(seg), 0.0)
    att = att * dt[None, :, :]                                   # [Q, Q, H]
    # y_diag[i,h,p] = sum_j att[i,j,h] x[j,h,p]
    y_diag = jnp.einsum("ijh,jhp->ihp", att, x)

    # inter-chunk output using incoming state
    h_prev = h_scr[...]                                          # [H, N, P]
    y_off = jnp.einsum("qn,hnp->qhp", Cm, h_prev) * jnp.exp(dA_cs)[..., None]
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h = h*exp(sum dA) + sum_j exp(dA_sum - dAcs_j) dt_j B_j x_j
    dA_sum = dA_cs[-1, :]                                        # [H]
    w = jnp.exp(dA_sum[None, :] - dA_cs) * dt                    # [Q, H]
    states = jnp.einsum("qh,qn,qhp->hnp", w, Bm, x)
    h_new = h_prev * jnp.exp(dA_sum)[:, None, None] + states
    h_scr[...] = h_new

    @pl.when(ic == nc - 1)
    def _emit_state():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                    *, interpret: bool = False):
    """x: [B, L, H, P]; dt: [B, L, H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B, L, N] (G=1).  L % chunk == 0.
    Returns (y [B, L, H, P] f32, h_final [B, H, N, P] f32)."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert L % chunk == 0

    xq = x.reshape(Bsz, nc, chunk, H, P)
    dtq = dt.reshape(Bsz, nc, chunk, H)
    Bq = Bm.reshape(Bsz, nc, chunk, N)
    Cq = Cm.reshape(Bsz, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(Bsz, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, H), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, H, P), lambda b, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, H, N, P), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, chunk, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(xq, dtq, A, Bq, Cq)
    return y.reshape(Bsz, L, H, P), h_fin
