"""jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool | None = None):
    """x: [B, L, H, P]; dt: [B, L, H]; A: [H]; Bm/Cm: [B, L, N].
    Pads L to a chunk multiple (identity steps: dt=0)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L0 = x.shape[1]
    pad = (-L0) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk, interpret=interpret)
    return y[:, :L0], h
