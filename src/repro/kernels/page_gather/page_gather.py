"""Page pack/unpack Pallas TPU kernel — the migration engine's data mover.

The optimistic (unlocked-DMA) migration path (core/migration.py) stages a
batch of discontiguous pages into one contiguous buffer before the
host<->device transfer — the TPU analogue of the paper's scatter-gather DMA
mode (Sec. 6.3): ``dma_memcpy_pg_to_pg`` over a page list.  The page
indices come in through scalar prefetch so the DMA engine can start
fetching page i+1's HBM block while page i streams out (double-buffered
automatically by the Pallas pipeline).

gather:  staging[i] = pool[idx[i]]   (pack for eviction / host copy-out)
scatter: pool[idx[i]] = staging[i]   (unpack after promotion / copy-in)

``page_gather_quant_pallas`` fuses the demotion gather with per-page
int8 quantization for ``quantize_int8`` pinned-host tiers: one kernel
packs pool pages into (int8 staging, per-page scale) instead of
gather -> host copy -> numpy quantize — the page never round-trips
through host float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def page_gather_pallas(pool: jnp.ndarray, idx: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """pool: [n_slots, *page_shape]; idx: int32 [k] -> [k, *page_shape]."""
    k = idx.shape[0]
    page_shape = pool.shape[1:]
    blk = (1, *page_shape)
    zeros = (0,) * len(page_shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec(blk, lambda i, idx: (idx[i], *zeros))],
        out_specs=pl.BlockSpec(blk, lambda i, idx: (i, *zeros)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, *page_shape), pool.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), pool)


def page_scatter_pallas(pool: jnp.ndarray, idx: jnp.ndarray,
                        pages: jnp.ndarray, *,
                        interpret: bool = False) -> jnp.ndarray:
    """pool[idx[i]] = pages[i]; returns the updated pool (donated input).

    Slots not referenced by idx are passed through untouched via
    input_output_aliasing.
    """
    k = idx.shape[0]
    page_shape = pool.shape[1:]
    blk = (1, *page_shape)
    zeros = (0,) * len(page_shape)

    def scatter_kernel(idx_ref, pages_ref, pool_ref, out_ref):
        out_ref[...] = pages_ref[...]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec(blk, lambda i, idx: (i, *zeros)),         # pages
            pl.BlockSpec(blk, lambda i, idx: (idx[i], *zeros)),    # pool (aliased)
        ],
        out_specs=pl.BlockSpec(blk, lambda i, idx: (idx[i], *zeros)),
    )
    return pl.pallas_call(
        scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # pool -> out (operand idx incl. prefetch)
        interpret=interpret,
    )(idx.astype(jnp.int32), pages, pool)


def _gather_quant_kernel(idx_ref, src_ref, q_ref, scale_ref):
    page = src_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(page)), 1e-8) / 127.0
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)
    q_ref[...] = jnp.clip(jnp.round(page / scale), -127, 127).astype(jnp.int8)


def page_gather_quant_pallas(pool: jnp.ndarray, idx: jnp.ndarray, *,
                             interpret: bool = False):
    """Fused pack + int8 quantize: pool [n_slots, *page_shape]; idx int32
    [k] -> (int8 [k, *page_shape], f32 scale [k]).  Same scalar-prefetch
    DMA pipeline as ``page_gather_pallas`` with the per-page absmax /
    round / clip folded into the copy — the staging buffer leaves the
    kernel already quantized (scale = max(absmax, 1e-8)/127, matching
    the host-pool quantizer bit for bit)."""
    k = idx.shape[0]
    page_shape = pool.shape[1:]
    blk = (1, *page_shape)
    zeros = (0,) * len(page_shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec(blk, lambda i, idx: (idx[i], *zeros))],
        out_specs=[
            pl.BlockSpec(blk, lambda i, idx: (i, *zeros)),
            pl.BlockSpec((1,), lambda i, idx: (i,)),
        ],
    )
    return pl.pallas_call(
        _gather_quant_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((k, *page_shape), jnp.int8),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), pool)
