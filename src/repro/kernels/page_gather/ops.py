"""Dispatching wrappers for the migration data mover.

Three execution paths per primitive:

  * TPU            — the Pallas scatter-gather kernel, compiled (the
                     double-buffered DMA pipeline described in
                     page_gather.py);
  * explicit       — ``interpret=True`` runs the same Pallas kernel in
                     interpreter mode (kernel-parity tests);
  * other backends — a jitted XLA gather/scatter with identical
                     semantics.  Interpreter-mode Pallas loops the grid
                     in Python and is orders of magnitude too slow to be
                     the batched migration engine's fast path on CPU/GPU
                     hosts, so auto-dispatch (``interpret=None``) only
                     picks Pallas on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .page_gather import (page_gather_pallas, page_gather_quant_pallas,
                          page_scatter_pallas)
from .ref import page_gather_dequant_ref, page_gather_quant_ref


@partial(jax.jit, static_argnames=("interpret",))
def _gather_pallas(pool, idx, *, interpret: bool):
    return page_gather_pallas(pool, idx, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def _scatter_pallas(pool, idx, pages, *, interpret: bool):
    return page_scatter_pallas(pool, idx, pages, interpret=interpret)


@jax.jit
def _gather_xla(pool, idx):
    return jnp.take(pool, idx, axis=0)


@partial(jax.jit, donate_argnums=(0,))
def _scatter_xla(pool, idx, pages):
    return pool.at[idx].set(pages)


def page_gather(pool, idx, *, interpret: bool | None = None):
    """staging[i] = pool[idx[i]].  idx: int [k] -> [k, *page_shape]."""
    idx = idx.astype(jnp.int32)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _gather_xla(pool, idx)
        interpret = False
    return _gather_pallas(pool, idx, interpret=interpret)


def page_scatter(pool, idx, pages, *, interpret: bool | None = None):
    """pool[idx[i]] = pages[i]; returns the updated pool (pool donated)."""
    idx = idx.astype(jnp.int32)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _scatter_xla(pool, idx, pages)
        interpret = False
    return _scatter_pallas(pool, idx, pages, interpret=interpret)


# -- fused int8 paths (quantize_int8 pinned-host tiers) -----------------------

@partial(jax.jit, static_argnames=("interpret",))
def _gather_quant_pallas(pool, idx, *, interpret: bool):
    return page_gather_quant_pallas(pool, idx, interpret=interpret)


_gather_quant_xla = jax.jit(page_gather_quant_ref)
_gather_dequant_xla = jax.jit(page_gather_dequant_ref)


def page_gather_quant(pool, idx, *, interpret: bool | None = None):
    """Fused pack + per-page int8 quantize: (int8 [k, *page], f32 [k]).

    One dispatch instead of gather -> host copy -> numpy quantize; the
    demotion path into an int8 pinned-host tier uses this directly."""
    idx = idx.astype(jnp.int32)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _gather_quant_xla(pool, idx)
        interpret = False
    return _gather_quant_pallas(pool, idx, interpret=interpret)


def page_gather_dequant(pool_q, pool_scale, idx):
    """Fused unpack + dequantize out of an int8 pool -> f32 [k, *page]."""
    return _gather_dequant_xla(pool_q, pool_scale, idx.astype(jnp.int32))


@partial(jax.jit, donate_argnums=(0, 1))
def page_scatter_quant(pool_q, pool_scale, idx, pages):
    """Fused per-page int8 quantize + scatter into a donated int8 pool:
    (pool_q, pool_scale) with pages[i] quantized into slot idx[i].  The
    demotion commit into a ``quantize_int8`` pinned-host tier is this one
    dispatch — no host staging copy, pool buffers donated in place."""
    from .ref import quantize_pages_ref
    q, scale = quantize_pages_ref(pages)
    idx = idx.astype(jnp.int32)
    return pool_q.at[idx].set(q), pool_scale.at[idx].set(scale)
