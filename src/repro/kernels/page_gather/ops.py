"""jit'd wrappers for the migration data mover."""
from __future__ import annotations

from functools import partial

import jax

from .page_gather import page_gather_pallas, page_scatter_pallas


@partial(jax.jit, static_argnames=("interpret",))
def page_gather(pool, idx, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return page_gather_pallas(pool, idx, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def page_scatter(pool, idx, pages, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return page_scatter_pallas(pool, idx, pages, interpret=interpret)
