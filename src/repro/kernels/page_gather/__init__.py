from .ops import page_gather, page_scatter
from .ref import page_gather_ref, page_scatter_ref

__all__ = ["page_gather", "page_scatter", "page_gather_ref", "page_scatter_ref"]
