from .ops import (page_gather, page_gather_dequant, page_gather_quant,
                  page_scatter, page_scatter_quant)
from .ref import (page_gather_dequant_ref, page_gather_quant_ref,
                  page_gather_ref, page_scatter_ref, quantize_pages_ref)

__all__ = [
    "page_gather", "page_scatter", "page_gather_quant", "page_gather_dequant",
    "page_scatter_quant", "page_gather_ref", "page_scatter_ref",
    "page_gather_quant_ref", "page_gather_dequant_ref", "quantize_pages_ref",
]
