"""Pure-jnp oracle for page gather/scatter."""
import jax.numpy as jnp


def page_gather_ref(pool, idx):
    return pool[idx]


def page_scatter_ref(pool, idx, pages):
    return pool.at[idx].set(pages)
