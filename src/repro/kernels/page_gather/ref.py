"""Pure-jnp oracles for page gather/scatter and the fused int8 variants."""
import jax.numpy as jnp


def page_gather_ref(pool, idx):
    return pool[idx]


def page_scatter_ref(pool, idx, pages):
    return pool.at[idx].set(pages)


def _page_scale(pages):
    """Per-page int8 scale: max(absmax, 1e-8)/127, matching the host-pool
    quantizer (``core.tiers.HostPool.write_batch``) bit for bit."""
    axes = tuple(range(1, pages.ndim))
    return jnp.maximum(jnp.max(jnp.abs(pages), axis=axes), 1e-8) / 127.0


def _bcast(scale, ndim):
    return scale.reshape((-1,) + (1,) * (ndim - 1))


def quantize_pages_ref(pages):
    """float pages [k, *page] -> (int8 [k, *page], f32 scale [k])."""
    pages = pages.astype(jnp.float32)
    scale = _page_scale(pages)
    q = jnp.clip(jnp.round(pages / _bcast(scale, pages.ndim)), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def page_gather_quant_ref(pool, idx):
    """Fused gather + per-page int8 quantize (demotion staging)."""
    return quantize_pages_ref(pool[idx])


def page_gather_dequant_ref(pool_q, pool_scale, idx):
    """Fused gather + dequantize out of an int8 pool -> f32 pages."""
    q = pool_q[idx].astype(jnp.float32)
    return q * _bcast(pool_scale[idx].astype(jnp.float32), q.ndim)
