"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pooled


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [B, Hq, D] decode queries; k/v_pool: [n_slots, page, Hkv, D];
    block_table: [B, n_pages]; lengths: [B].  Returns [B, Hq, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Hkv, G, D)
    out = paged_attention_pooled(qg, k_pool, v_pool,
                                 block_table.astype(jnp.int32),
                                 lengths.astype(jnp.int32),
                                 interpret=interpret)
    return out.reshape(B, Hq, D)
