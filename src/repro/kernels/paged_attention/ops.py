"""jit'd public wrapper for paged decode attention.

Same three execution paths as ``kernels/page_gather`` / ``wear_update``:

  * TPU            — the scalar-prefetch Pallas kernel, compiled;
  * explicit       — ``interpret=True`` runs the Pallas kernel in
                     interpreter mode (kernel-parity tests only);
  * other backends — the pure-jnp gather+softmax reference, jit-compiled
                     by XLA.  Interpreter-mode Pallas unrolls the whole
                     (B, Hkv, n_pages) grid into emulation HLO, which
                     dominated the serving decode step on CPU hosts —
                     the XLA path keeps the fused multi-token dispatch
                     compute-bound instead of emulation-bound.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pooled
from .ref import paged_attention_pages_ref, paged_attention_ref


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [B, Hq, D] decode queries; k/v_pool: [n_slots, page, Hkv, D];
    block_table: [B, n_pages]; lengths: [B].  Returns [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Hkv, G, D)
    if interpret is None and jax.default_backend() != "tpu":
        out = paged_attention_ref(qg, k_pool, v_pool,
                                  block_table.astype(jnp.int32),
                                  lengths.astype(jnp.int32))
    else:
        out = paged_attention_pooled(qg, k_pool, v_pool,
                                     block_table.astype(jnp.int32),
                                     lengths.astype(jnp.int32),
                                     interpret=bool(interpret))
    return out.reshape(B, Hq, D)


def paged_attention_prefill(q_all: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                            lengths: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence prefill attention over freshly written KV pages.

    Treats every packed position as its own decode-style query row:
    ``q_all`` [L, Hq, D] attends through per-row block tables
    [L, n_pages] (each row lists only its *own segment's* pages, padded
    with slot 0) masked to ``lengths`` [L] = causal prefix length.  The
    mask drives every out-of-prefix score to -1e30 exactly as the decode
    path does, so position p of a packed segment produces bitwise the
    same output as a decode step at position p over the same pool —
    segments can never attend across packing boundaries because foreign
    pages simply aren't in the row's table.  Seam for a future Pallas
    flash-prefill variant; today it reuses ``paged_attention`` verbatim.
    """
    return paged_attention(q_all, k_pool, v_pool, block_tables, lengths)


def paged_attention_prefill_pages(q_all: jnp.ndarray, k_pages: jnp.ndarray,
                                  v_pages: jnp.ndarray,
                                  lengths: jnp.ndarray) -> jnp.ndarray:
    """Dual-pool prefill attention over pre-gathered per-row pages
    (pinned-tier variant of ``paged_attention_prefill``)."""
    return paged_attention_pages(q_all, k_pages, v_pages, lengths)


@jax.jit
def paged_attention_pages(q: jnp.ndarray, k_pages: jnp.ndarray,
                          v_pages: jnp.ndarray,
                          lengths: jnp.ndarray) -> jnp.ndarray:
    """Decode attention over pre-gathered pages (the dual-pool serving
    path: the caller selects each page from the tier-0 pool or the
    pinned-host pool before attending).  q: [B, Hq, D]; k/v_pages:
    [B, n_pages, page, Hkv, D]; lengths: [B].  XLA everywhere — the
    Pallas pooled kernel reads straight from a single pool and does not
    apply; identical math to ``paged_attention`` on the same pages."""
    B, Hq, D = q.shape
    Hkv = k_pages.shape[3]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Hkv, G, D)
    out = paged_attention_pages_ref(qg, k_pages, v_pages,
                                    lengths.astype(jnp.int32))
    return out.reshape(B, Hq, D)
