"""jit'd public wrapper for paged decode attention.

Same three execution paths as ``kernels/page_gather`` / ``wear_update``:

  * TPU            — the scalar-prefetch Pallas kernel, compiled;
  * explicit       — ``interpret=True`` runs the Pallas kernel in
                     interpreter mode (kernel-parity tests only);
  * other backends — the pure-jnp gather+softmax reference, jit-compiled
                     by XLA.  Interpreter-mode Pallas unrolls the whole
                     (B, Hkv, n_pages) grid into emulation HLO, which
                     dominated the serving decode step on CPU hosts —
                     the XLA path keeps the fused multi-token dispatch
                     compute-bound instead of emulation-bound.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pooled
from .ref import paged_attention_ref


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [B, Hq, D] decode queries; k/v_pool: [n_slots, page, Hkv, D];
    block_table: [B, n_pages]; lengths: [B].  Returns [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Hkv, G, D)
    if interpret is None and jax.default_backend() != "tpu":
        out = paged_attention_ref(qg, k_pool, v_pool,
                                  block_table.astype(jnp.int32),
                                  lengths.astype(jnp.int32))
    else:
        out = paged_attention_pooled(qg, k_pool, v_pool,
                                     block_table.astype(jnp.int32),
                                     lengths.astype(jnp.int32),
                                     interpret=bool(interpret))
    return out.reshape(B, Hq, D)
