"""Paged decode attention Pallas TPU kernel — block-table indirection over
the memos-managed KV page pool.

This is the kernel-level half of the paper's page machinery: the serving
engine hands the kernel a *block table* (logical page -> physical slot in
the HBM pool, maintained by the sub-buddy allocator + migration engine),
and the kernel streams exactly the pages that are resident, in page-size
granules.  SysMon's per-page read counters are charged from the same block
table by the engine — so the access stream the predictor sees is exact.

Grid: (B, Hkv, n_pages).  The page axis is innermost; the running softmax
state for the G grouped q-heads persists in VMEM scratch.  Pages are
fetched through a *scalar-prefetched* block table (PrefetchScalarGridSpec),
i.e. the page index feeds the DMA engine ahead of compute — the TPU-native
analogue of the paper's DMA scatter-gather migration reads.

VMEM policy (DESIGN.md Sec. 3.2): K/V page blocks are Thrashing-class
(streamed once, minimal double-buffer); q & accumulator are resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_table, lengths,          # scalar-prefetch operands
                  q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [page, D]
    v = v_ref[0, :, 0].astype(jnp.float32)           # [page, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page]
    pos = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    valid = pos < lengths[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ip == np_ - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention_pooled(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hkv, G, D] one decode token per sequence;
    k/v_pool: [n_slots, page, Hkv, D] memos HBM page pool;
    block_table: int32 [B, n_pages] (logical page i of seq b -> pool slot);
    lengths: int32 [B] current context lengths.
    Returns [B, Hkv, G, D]."""
    B, Hkv, G, D = q.shape
    n_slots, page, _, _ = k_pool.shape
    n_pages = block_table.shape[1]
    scale = 1.0  # caller pre-scales q

    kernel = functools.partial(_paged_kernel, page_size=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, bt, ln: (bt[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, bt, ln: (bt[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ip, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
