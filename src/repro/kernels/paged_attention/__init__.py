from .ops import (paged_attention, paged_attention_pages,
                  paged_attention_prefill, paged_attention_prefill_pages)
from .ref import paged_attention_pages_ref, paged_attention_ref

__all__ = ["paged_attention", "paged_attention_pages",
           "paged_attention_prefill", "paged_attention_prefill_pages",
           "paged_attention_ref", "paged_attention_pages_ref"]
