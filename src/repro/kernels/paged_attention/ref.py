"""Pure-jnp oracle for the paged decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_table: jnp.ndarray,
                        lengths: jnp.ndarray) -> jnp.ndarray:
    """Same signature as paged_attention_pooled (q pre-scaled)."""
    B, Hkv, G, D = q.shape
    n_pages = block_table.shape[1]
    page = k_pool.shape[1]
    # gather pages -> dense [B, n_pages*page, Hkv, D]
    k = k_pool[block_table].reshape(B, n_pages * page, Hkv, D)
    v = v_pool[block_table].reshape(B, n_pages * page, Hkv, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    pos = jnp.arange(n_pages * page)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
