"""Pure-jnp oracle for the paged decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_pages_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray,
                              lengths: jnp.ndarray) -> jnp.ndarray:
    """Attention over pre-gathered pages (q pre-scaled).

    k_pages/v_pages: [B, n_pages, page, Hkv, D] — the caller already
    resolved the block table, e.g. by selecting between the tier-0 pool
    and a pinned-host pool per page (the dual-pool serving path)."""
    B, Hkv, G, D = q.shape
    n_pages, page = k_pages.shape[1:3]
    k = k_pages.reshape(B, n_pages * page, Hkv, D)
    v = v_pages.reshape(B, n_pages * page, Hkv, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    pos = jnp.arange(n_pages * page)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_table: jnp.ndarray,
                        lengths: jnp.ndarray) -> jnp.ndarray:
    """Same signature as paged_attention_pooled (q pre-scaled)."""
    # gather pages -> dense, then attend (shared with the dual-pool path)
    return paged_attention_pages_ref(q, k_pool[block_table],
                                     v_pool[block_table], lengths)
