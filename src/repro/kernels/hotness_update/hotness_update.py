"""SysMon Pallas TPU kernels — the paper's "page shadow array ... raw
byte and bit manipulation" (Sec. 4.2), fused.

Two kernels share this module:

``sysmon_pass_pallas`` — the pass-boundary sweep.  One elementwise pass
over the page-counter arrays computes, per page:
  * WD/RD/COLD classification (weight-2 writes, Sec. 3.1),
  * history-byte shift  hist' = (hist << 1 | wd) & 0xFF,
  * SWAR popcount of the window,
  * the WD_FREQ_H / WD_FREQ_L / UN_WD prediction with the K_Len Reverse
    override (Sec. 3.2, Fig. 4).

``touch_update_pallas`` — the per-sampling scatter-add behind
``core.sysmon.record``.  A decode step hands SysMon a padded list of
touched page ids (block-table prefix reads + the tail-page write); this
kernel turns the event list into dense per-page increment vectors
(d_reads, d_writes, touched) in one blocked sweep, same ownership
discipline as ``kernels/wear_update``: each grid step owns one [block]
span of the page axis and reduces the full event list against it, so the
scatter is race-free across grid steps and bit-exact vs. the numpy
oracle.  This is the piece the serving engine's fused multi-token decode
carries inside ``lax.scan`` — monitoring without leaving the device.

Blocked [bp] pages per grid step; everything stays in int32 vregs (VPU
lanes), zero HBM re-reads — the fused version reads each counter array
once vs. 4 passes for the unfused jnp composition in core/sysmon.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import patterns, predictor


def _pass_kernel(reads_ref, writes_ref, hist_ref,
                 wd_ref, newhist_ref, future_ref, *,
                 window_len: int, k_len: int, hi: int, lo: int):
    r = reads_ref[...].astype(jnp.int32)
    w = writes_ref[...].astype(jnp.int32)
    hist = hist_ref[...].astype(jnp.int32)

    touched = (r + w) > 0
    is_wd = (patterns.WRITE_WEIGHT * w) >= r
    wd_code = jnp.where(touched,
                        jnp.where(is_wd, patterns.WD, patterns.RD),
                        patterns.COLD).astype(jnp.int32)
    wd_bit = (wd_code == patterns.WD).astype(jnp.int32)

    mask = (1 << window_len) - 1
    hist = ((hist << 1) | wd_bit) & mask

    # SWAR popcount (8-bit window inside an int32 lane)
    x = hist
    x = x - ((x >> 1) & 0x55)
    x = (x & 0x33) + ((x >> 2) & 0x33)
    ones = (x + (x >> 4)) & 0x0F

    base = jnp.where(ones >= hi, predictor.WD_FREQ_H,
                     jnp.where(ones >= lo, predictor.WD_FREQ_L,
                               predictor.UN_WD))
    kmask = (1 << k_len) - 1
    suffix = hist & kmask
    fut = jnp.where(suffix == kmask, predictor.WD_FREQ_H, base)
    fut = jnp.where(suffix == 0, predictor.UN_WD, fut)

    wd_ref[...] = wd_code
    newhist_ref[...] = hist
    future_ref[...] = fut


def sysmon_pass_pallas(reads: jnp.ndarray, writes: jnp.ndarray,
                       hist: jnp.ndarray, *, window_len: int = 8,
                       k_len: int = 3, hi: int = 6, lo: int = 2,
                       block: int = 1024, interpret: bool = False):
    """reads/writes: int32 [n]; hist: int32 [n] (low window_len bits).
    Returns (wd_code, new_hist, future) int32 [n]."""
    n = reads.shape[0]
    pad = (-n) % block
    if pad:
        reads = jnp.pad(reads, (0, pad))
        writes = jnp.pad(writes, (0, pad))
        hist = jnp.pad(hist, (0, pad))
    np_ = reads.shape[0] // block
    kernel = functools.partial(_pass_kernel, window_len=window_len,
                               k_len=k_len, hi=hi, lo=lo)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        kernel,
        grid=(np_,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((reads.shape[0],), jnp.int32)] * 3,
        interpret=interpret,
    )(reads.astype(jnp.int32), writes.astype(jnp.int32),
      hist.astype(jnp.int32))
    return tuple(o[:n] for o in out)


def _touch_kernel(ids_ref, r_ref, w_ref,
                  dr_ref, dw_ref, touched_ref, *, block: int):
    i = pl.program_id(0)
    # pages owned by this grid step, as a [block, 1] column
    pages = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    ids = ids_ref[...].astype(jnp.int32).reshape(1, -1)     # [1, k]
    r = r_ref[...].astype(jnp.int32).reshape(1, -1)
    w = w_ref[...].astype(jnp.int32).reshape(1, -1)
    hit = pages == ids                                      # [block, k]
    dr_ref[...] = jnp.sum(jnp.where(hit, r, 0), axis=1)
    dw_ref[...] = jnp.sum(jnp.where(hit, w, 0), axis=1)
    touched_ref[...] = jnp.max(jnp.where(hit, r + w, 0), axis=1)


def touch_update_pallas(n_pages: int, page_ids: jnp.ndarray,
                        reads: jnp.ndarray, writes: jnp.ndarray, *,
                        block: int = 512, interpret: bool = False):
    """page_ids: int32 [k] (in-bounds; padded events carry zero weights);
    reads/writes: int32 [k] per-event increments (0 or 1).  Returns dense
    int32 [n_pages] (d_reads, d_writes, touched) where touched is 1 for
    any page with at least one non-zero event (duplicates accumulate in
    the count vectors, dedupe in touched)."""
    k = page_ids.shape[0]
    kpad = (-k) % 128
    if kpad:
        page_ids = jnp.pad(page_ids, (0, kpad))
        reads = jnp.pad(reads, (0, kpad))
        writes = jnp.pad(writes, (0, kpad))
    npad = (-n_pages) % block
    n_full = n_pages + npad
    kernel = functools.partial(_touch_kernel, block=block)
    kfull = page_ids.shape[0]
    espec = pl.BlockSpec((kfull,), lambda i: (0,))   # every step sees all ids
    pspec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        kernel,
        grid=(n_full // block,),
        in_specs=[espec, espec, espec],
        out_specs=[pspec, pspec, pspec],
        out_shape=[jax.ShapeDtypeStruct((n_full,), jnp.int32)] * 3,
        interpret=interpret,
    )(page_ids.astype(jnp.int32), reads.astype(jnp.int32),
      writes.astype(jnp.int32))
    return tuple(o[:n_pages] for o in out)
