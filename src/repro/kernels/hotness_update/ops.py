"""jit'd wrapper for the fused SysMon pass kernel."""
from __future__ import annotations

from functools import partial

import jax

from .hotness_update import sysmon_pass_pallas


@partial(jax.jit, static_argnames=("window_len", "k_len", "hi", "lo",
                                   "block", "interpret"))
def sysmon_pass(reads, writes, hist, *, window_len: int = 8, k_len: int = 3,
                hi: int = 6, lo: int = 2, block: int = 1024,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sysmon_pass_pallas(reads, writes, hist, window_len=window_len,
                              k_len=k_len, hi=hi, lo=lo, block=block,
                              interpret=interpret)
