"""jit'd wrappers for the fused SysMon kernels.

``touch_update`` follows the ``kernels/wear_update`` dispatch discipline:

  * TPU            — the blocked Pallas histogram kernel, compiled;
  * explicit       — ``interpret=True`` runs the Pallas kernel in
                     interpreter mode (kernel-parity tests);
  * other backends — jitted XLA scatter-adds with identical integer
                     semantics (bit-exact: integer adds are associative).

Both paths are traceable, so the serving engine can call ``touch_update``
from inside its ``lax.scan``-fused decode dispatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .hotness_update import sysmon_pass_pallas, touch_update_pallas


@partial(jax.jit, static_argnames=("window_len", "k_len", "hi", "lo",
                                   "block", "interpret"))
def sysmon_pass(reads, writes, hist, *, window_len: int = 8, k_len: int = 3,
                hi: int = 6, lo: int = 2, block: int = 1024,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sysmon_pass_pallas(reads, writes, hist, window_len=window_len,
                              k_len=k_len, hi=hi, lo=lo, block=block,
                              interpret=interpret)


@partial(jax.jit, static_argnums=(0,))
def _touch_xla(n_pages: int, ids, r, w):
    d_reads = jnp.zeros((n_pages,), jnp.int32).at[ids].add(r)
    d_writes = jnp.zeros((n_pages,), jnp.int32).at[ids].add(w)
    touched = jnp.zeros((n_pages,), jnp.int32).at[ids].max(
        jnp.minimum(r + w, 1))
    return d_reads, d_writes, touched


def touch_update(n_pages: int, page_ids, is_write, valid=None, *,
                 block: int = 512, interpret: bool | None = None):
    """Dense per-page increments for one SysMon sampling.

    page_ids: int [k] touched pages (may repeat; clipped in-bounds);
    is_write: bool or bool [k]; valid: optional bool [k] mask for padded
    id lists.  Returns int32 [n_pages] (d_reads, d_writes, touched) —
    counts accumulate duplicates, touched dedupes to {0, 1}.
    """
    ids = jnp.clip(jnp.asarray(page_ids, jnp.int32).reshape(-1), 0,
                   n_pages - 1)
    k = ids.shape[0]
    if isinstance(is_write, bool):
        is_write = jnp.full((k,), is_write)
    is_write = jnp.broadcast_to(jnp.asarray(is_write).reshape(-1), (k,))
    if valid is None:
        valid = jnp.ones((k,), bool)
    valid = jnp.broadcast_to(jnp.asarray(valid).reshape(-1), (k,))
    r = (valid & ~is_write).astype(jnp.int32)
    w = (valid & is_write).astype(jnp.int32)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _touch_xla(n_pages, ids, r, w)
        interpret = False
    block = min(block, -(-n_pages // 128) * 128)
    return touch_update_pallas(n_pages, ids, r, w, block=block,
                               interpret=interpret)
