"""Oracles: pure-jnp / numpy compositions of the fused kernels."""
import jax.numpy as jnp
import numpy as np

from repro.core import patterns, predictor


def touch_update_ref(n_pages, page_ids, is_write, valid=None):
    """Numpy oracle for the per-sampling touch scatter-add."""
    ids = np.clip(np.asarray(page_ids, np.int64).reshape(-1), 0, n_pages - 1)
    k = ids.shape[0]
    is_write = np.broadcast_to(np.asarray(is_write).reshape(-1)
                               if not isinstance(is_write, bool)
                               else np.full((k,), is_write), (k,))
    valid = (np.ones((k,), bool) if valid is None
             else np.broadcast_to(np.asarray(valid).reshape(-1), (k,)))
    d_reads = np.zeros((n_pages,), np.int32)
    d_writes = np.zeros((n_pages,), np.int32)
    touched = np.zeros((n_pages,), np.int32)
    np.add.at(d_reads, ids, (valid & ~is_write).astype(np.int32))
    np.add.at(d_writes, ids, (valid & is_write).astype(np.int32))
    np.maximum.at(touched, ids, valid.astype(np.int32))
    return d_reads, d_writes, touched


def sysmon_pass_ref(reads, writes, hist, *, window_len=8, k_len=3,
                    hi=6, lo=2):
    wd_code = patterns.classify_wd(reads, writes).astype(jnp.int32)
    wd_bit = (wd_code == patterns.WD).astype(jnp.uint8)
    new_hist = predictor.push_history(hist.astype(jnp.uint8), wd_bit,
                                      window_len)
    fut = predictor.predict_future(new_hist, window_len=window_len,
                                   k_len=k_len, hi_thresh=hi, lo_thresh=lo)
    return wd_code, new_hist.astype(jnp.int32), fut.astype(jnp.int32)
