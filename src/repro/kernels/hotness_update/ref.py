"""Oracle: compose the core library's pure-jnp pieces."""
import jax.numpy as jnp

from repro.core import patterns, predictor


def sysmon_pass_ref(reads, writes, hist, *, window_len=8, k_len=3,
                    hi=6, lo=2):
    wd_code = patterns.classify_wd(reads, writes).astype(jnp.int32)
    wd_bit = (wd_code == patterns.WD).astype(jnp.uint8)
    new_hist = predictor.push_history(hist.astype(jnp.uint8), wd_bit,
                                      window_len)
    fut = predictor.predict_future(new_hist, window_len=window_len,
                                   k_len=k_len, hi_thresh=hi, lo_thresh=lo)
    return wd_code, new_hist.astype(jnp.int32), fut.astype(jnp.int32)
