from .ops import sysmon_pass, touch_update
from .ref import sysmon_pass_ref, touch_update_ref

__all__ = ["sysmon_pass", "sysmon_pass_ref", "touch_update",
           "touch_update_ref"]
