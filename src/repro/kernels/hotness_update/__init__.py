from .ops import sysmon_pass
from .ref import sysmon_pass_ref

__all__ = ["sysmon_pass", "sysmon_pass_ref"]
