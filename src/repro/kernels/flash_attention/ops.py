"""jit'd public wrapper for the flash attention kernel.

Handles: GQA layout flattening, qk scaling, head_dim padding to a 128
multiple (MXU lane width), and seq padding to block multiples.  On
non-TPU backends it falls back to interpret mode (CPU validation) —
production serving/training on TPU lowers the real kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    scale = D ** -0.5

    qf = (q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D) * scale)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)

    bq_eff = min(bq, max(8, Sq))
    bk_eff = min(bk, max(8, Sk))
    qf = _pad_to(_pad_to(qf, 1, bq_eff), 2, 128)
    kf = _pad_to(_pad_to(kf, 1, bk_eff), 2, 128)
    vf = _pad_to(_pad_to(vf, 1, bk_eff), 2, 128)

    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               bq=bq_eff, bk=bk_eff, seq_len=Sk,
                               interpret=interpret)
    out = out[:, :Sq, :D].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out
