"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        seq_len: int | None = None) -> jnp.ndarray:
    """q: [BHq, Sq, D]; k/v: [BHkv, Sk, D]; BHq = BHkv * G (GQA)."""
    BHq, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    G = BHq // BHkv
    if seq_len is None:
        seq_len = Sk
    k = jnp.repeat(k, G, axis=0)
    v = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = k_pos < seq_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & ((q_pos - k_pos) < window)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", w, v.astype(jnp.float32)).astype(q.dtype)
