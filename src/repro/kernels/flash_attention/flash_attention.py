"""Flash attention (training/prefill) Pallas TPU kernel.

Grid: (B*Hq, n_q_blocks, n_k_blocks) — the k-block axis is innermost, so
the online-softmax running state (m, l, acc) lives in VMEM scratch that
persists across k iterations (TPU grids execute sequentially).

BlockSpec tiling (the paper's "cache slab" policy at the VMEM level,
DESIGN.md Sec. 3.2):
  * q block  [bq, D]  — *Freq-touched*: resident for the whole k sweep;
  * k/v blocks [bk, D] — *Thrashing* (streamed once per q block): minimal
    double-buffered tiles, never re-read within a sweep;
  * acc scratch [bq, D] f32 — resident accumulator.

bq/bk default to 128/256 to align with the 128-lane MXU; D (head_dim) is
the contraction and must be a multiple of 128 for peak MXU utilization
(320-dim heads pad to 384 in ops.py).

Supports causal masking, sliding windows (SWA / gemma3 local layers) and
GQA via a q-head -> kv-head index map (no KV expansion in memory).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, seq_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0].astype(jnp.float32)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 256, seq_len: int | None = None,
                         interpret: bool = False) -> jnp.ndarray:
    """q: [BHq, Sq, D]; k/v: [BHkv, Sk, D] (pre-flattened, padded).
    BHq = BHkv * G; q head i uses kv head i // G."""
    BHq, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    G = BHq // BHkv
    scale = 1.0  # caller pre-scales (keeps D-padding exact)
    if seq_len is None:
        seq_len = Sk
    grid = (BHq, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, seq_len=seq_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
