from .ops import wear_update
from .ref import wear_update_ref

__all__ = ["wear_update", "wear_update_ref"]
