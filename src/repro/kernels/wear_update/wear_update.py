"""Wear-counter scatter-add Pallas TPU kernel (NVM telemetry, Sec. 7.1).

Every write that lands on the slow (NVM-analogue) tier must bump that
physical slot's wear counter — the online signal behind the paper's
lifetime projection and wear-leveling feedback.  The update is a
scatter-add over a histogram array:

    wear[slot_ids[i]] += amount[i]        for every write event i

Same layout discipline as ``kernels/hotness_update``: a 1-D grid over
blocked spans of the counter array, everything in int32 VPU lanes.  A
scatter is race-prone across grid steps, so each step instead *owns* one
counter block and reduces the full event list against it — a [block, k]
compare/select/sum that reads the event arrays once per block and writes
each counter exactly once (deterministic, bit-exact vs. the numpy
oracle).  Event lists are short (one entry per page write in a pass), so
k stays in the hundreds while the block dimension rides the lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wear_kernel(ids_ref, amt_ref, wear_ref, out_ref, *, block: int):
    i = pl.program_id(0)
    base = i * block
    # counters owned by this grid step, as a [block, 1] column
    slots = base + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    ids = ids_ref[...].astype(jnp.int32).reshape(1, -1)    # [1, k]
    amt = amt_ref[...].astype(jnp.int32).reshape(1, -1)
    hits = jnp.where(slots == ids, amt, 0)                 # [block, k]
    out_ref[...] = wear_ref[...] + jnp.sum(hits, axis=1)


def wear_update_pallas(wear: jnp.ndarray, slot_ids: jnp.ndarray,
                       amount: jnp.ndarray, *, block: int = 512,
                       interpret: bool = False) -> jnp.ndarray:
    """wear: int32 [n]; slot_ids/amount: int32 [k].  Returns wear with
    ``amount[i]`` added at ``slot_ids[i]`` (duplicates accumulate).
    Out-of-range ids must be masked by the caller via ``amount == 0``."""
    n = wear.shape[0]
    pad = (-n) % block
    if pad:
        wear = jnp.pad(wear, (0, pad))
    k = slot_ids.shape[0]
    kpad = (-k) % 128
    if kpad:
        # padded events point at a real slot but carry zero amount
        slot_ids = jnp.pad(slot_ids, (0, kpad))
        amount = jnp.pad(amount, (0, kpad))
    nblocks = wear.shape[0] // block
    kernel = functools.partial(_wear_kernel, block=block)
    kfull = slot_ids.shape[0]
    out = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((kfull,), lambda i: (0,)),   # every step sees all ids
            pl.BlockSpec((kfull,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((wear.shape[0],), jnp.int32),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), amount.astype(jnp.int32),
      wear.astype(jnp.int32))
    return out[:n]
