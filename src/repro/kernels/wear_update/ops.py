"""Dispatching wrapper for the wear-counter scatter-add.

Same three execution paths as ``kernels/page_gather``:

  * TPU            — the blocked Pallas histogram kernel, compiled;
  * explicit       — ``interpret=True`` runs the Pallas kernel in
                     interpreter mode (kernel-parity tests);
  * other backends — a jitted XLA ``at[].add`` scatter with identical
                     integer semantics (bit-exact: integer adds are
                     associative), since interpreter-mode Pallas loops
                     the grid in Python and is too slow for the
                     TierStore write path on CPU/GPU hosts.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .wear_update import wear_update_pallas


@partial(jax.jit, static_argnames=("block", "interpret"))
def _wear_pallas(wear, ids, amount, *, block: int, interpret: bool):
    return wear_update_pallas(wear, ids, amount, block=block,
                              interpret=interpret)


@jax.jit
def _wear_xla(wear, ids, amount):
    return wear.at[ids].add(amount)


def wear_update(wear, slot_ids, amount=None, *, valid=None, block: int = 512,
                interpret: bool | None = None):
    """wear[slot_ids[i]] += amount[i]; returns the updated int32 counters.

    slot_ids are clipped in-bounds; ``valid`` (bool [k]) zeroes masked
    events so padded id lists stay jit-friendly.  ``amount`` defaults to
    one write per event.
    """
    import numpy as np
    from jax.core import Tracer
    wear = jnp.asarray(wear, jnp.int32)
    eager = not any(isinstance(x, Tracer) for x in (slot_ids, amount, valid))
    if eager:
        # eager callers (the TierStore flush path) hand in data-dependent
        # event-list sizes almost every pass: normalize + bucket the
        # length to multiples of 128 **in numpy** (zero-amount padding
        # pointed at slot 0), so neither the clip/where ops nor the
        # scatter itself mint a fresh executable per size
        ids_np = np.clip(np.asarray(slot_ids, np.int64).reshape(-1), 0,
                         wear.shape[0] - 1)
        if ids_np.size == 0:
            return wear
        amt_np = (np.ones(ids_np.shape, np.int64) if amount is None
                  else np.broadcast_to(
                      np.asarray(amount, np.int64).reshape(-1),
                      ids_np.shape).copy())
        if valid is not None:
            amt_np[~np.asarray(valid).reshape(-1)] = 0
        kpad = (-ids_np.size) % 128
        if kpad:
            ids_np = np.concatenate([ids_np, np.zeros(kpad, np.int64)])
            amt_np = np.concatenate([amt_np, np.zeros(kpad, np.int64)])
        ids = jnp.asarray(ids_np, jnp.int32)
        amount = jnp.asarray(amt_np, jnp.int32)
    else:
        ids = jnp.clip(jnp.asarray(slot_ids, jnp.int32).reshape(-1), 0,
                       wear.shape[0] - 1)
        if amount is None:
            amount = jnp.ones(ids.shape, jnp.int32)
        amount = jnp.broadcast_to(jnp.asarray(amount, jnp.int32).reshape(-1),
                                  ids.shape)
        if valid is not None:
            amount = jnp.where(jnp.asarray(valid).reshape(-1), amount, 0)
        if ids.shape[0] == 0:
            return wear
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _wear_xla(wear, ids, amount)
        interpret = False
    # shrink the block for small pools, but keep it lane-aligned (128)
    block = min(block, -(-wear.shape[0] // 128) * 128)
    return _wear_pallas(wear, ids, amount, block=block, interpret=interpret)
