"""Numpy oracle for the wear-counter scatter-add."""
import numpy as np


def wear_update_ref(wear, slot_ids, amount=None):
    """wear[slot_ids[i]] += amount[i] (duplicates accumulate); returns a new
    int32 array.  ``amount`` defaults to all-ones."""
    wear = np.asarray(wear, np.int32).copy()
    slot_ids = np.asarray(slot_ids, np.int64)
    if amount is None:
        amount = np.ones_like(slot_ids, np.int32)
    np.add.at(wear, slot_ids, np.asarray(amount, np.int32))
    return wear
