"""Pallas TPU kernels for the compute hot-spots (each with ops.py jit
wrapper and ref.py pure-jnp oracle; validated in interpret mode on CPU):

  flash_attention — train/prefill attention (causal/SWA/local-global, GQA)
  paged_attention — decode attention over the memos block-table page pool
  ssd_scan        — Mamba-2 SSD chunked scan with fused inter-chunk state
  page_gather     — migration-engine page pack/unpack (scatter-gather DMA)
  hotness_update  — fused SysMon pass (WD classify + history + predictor)
  wear_update     — NVM wear-counter scatter-add (telemetry subsystem)
"""
from . import (flash_attention, hotness_update, page_gather,
               paged_attention, ssd_scan, wear_update)

__all__ = ["flash_attention", "hotness_update", "page_gather",
           "paged_attention", "ssd_scan", "wear_update"]
