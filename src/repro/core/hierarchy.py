"""MemoryHierarchy — the N-tier, medium-described memory hierarchy API.

The paper schedules "the entire memory hierarchy ... simultaneously";
this module is the first-class description of that hierarchy: an ordered
list of tiers (fastest first), each a :class:`MediumSpec` naming its
capacity, its Table-1 cost-model medium (latency / energy / endurance),
its residency (device jax pool vs. host numpy pool), and its telemetry
flags (wear tracking, Start-Gap leveling, int8 soft-NVM storage).

Everything above this module is generic over tier *indices*: the
placement policy scores pages against per-tier ``MediumSpec`` costs, the
sub-buddy allocator and Algorithm-2 slot targeting run per tier, the
migration engines move pages between arbitrary tier pairs, and the
wear/energy telemetry attaches to every tier whose spec sets
``wear_tracked`` — nothing outside the compatibility shim below names a
"fast" or "slow" tier.

Conventions:

  * tier 0 is the fastest tier and is the tier compute reads from (the
    serving engine's block tables only ever point at tier-0 slots);
  * tiers are ordered fastest -> slowest; "promotion" moves a page to a
    lower tier index, "demotion" to a higher one;
  * device tiers hold one jax array pool each (HBM, or an HBM-resident
    DRAM-channel simulation); host tiers hold numpy pools (the NVM/CXL
    analogue) and are the only tiers that support wear tracking,
    Start-Gap leveling, and int8 quantization.

Compatibility shim
------------------
The pre-redesign API hardcoded exactly two tiers through module-level
``FAST = 0`` / ``SLOW = 1`` constants.  Those constants now live *only*
here, next to :meth:`MemoryHierarchy.two_tier` — the constructor that
reproduces the old fast/slow behavior bit for bit (pinned by
``tests/test_hierarchy.py::test_two_tier_parity_vs_golden``).  New code
should carry tier indices instead of importing them.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from . import costmodel as cm

# --- two-tier compatibility shim ---------------------------------------------
# The only surviving FAST/SLOW constants.  They are exactly the tier
# indices of a ``MemoryHierarchy.two_tier(...)`` hierarchy; in an N-tier
# hierarchy "fast" is tier 0 and "slow" is the deepest tier.
FAST = 0  # fastest tier of a two_tier() hierarchy (DRAM / HBM analogue)
SLOW = 1  # deepest tier of a two_tier() hierarchy (NVM / host analogue)

DEVICE = "device"   # jax array pool (HBM-resident)
HOST = "host"       # numpy pool (host DRAM; the NVM-channel analogue)
PINNED_HOST = "pinned_host"  # jax pool in pinned host memory: host-class
                             # capacity, addressable from device code


@dataclass(frozen=True)
class MediumSpec:
    """One tier of the hierarchy, described by its physical medium.

    ``medium`` supplies the Table-1 cost model (read/write latency and
    energy, standby power, endurance); ``slots`` is the pool capacity in
    pages; ``bandwidth_gbps`` is the channel's peak bandwidth for the
    bandwidth balancer (0 = unmodeled).  ``wear_tracked`` attaches the
    per-physical-slot write counters of ``repro.nvm`` to this tier;
    ``wear_leveling`` adds Start-Gap rotation on top.  ``quantize_int8``
    stores pages as int8 + per-page scale (the soft-NVM read-cheap /
    write-lossy analogue).  Wear, leveling, and quantization are
    host-class features: they require ``residency == "host"`` or
    ``residency == "pinned_host"``.

    ``pinned_host`` is the NVM/CXL analogue with device addressability:
    the pool is one jax buffer placed in pinned host memory (plain host
    placement where the backend has no memory kinds), so migrations in
    and out of it stay inside the jax runtime (donated scatters instead
    of numpy staging copies), the fused serving dispatch can append KV
    into it and charge its wear counters on device, and int8
    quantization fuses into the demotion gather as one kernel.
    """

    name: str
    slots: int
    medium: cm.MediumParams
    residency: str = HOST
    bandwidth_gbps: float = 0.0
    wear_tracked: bool = False
    wear_leveling: bool = False
    gap_write_interval: int | None = None   # None -> costmodel 95% target
    quantize_int8: bool = False

    def __post_init__(self):
        if self.residency not in (DEVICE, HOST, PINNED_HOST):
            raise ValueError(f"residency must be '{DEVICE}', '{HOST}' or "
                             f"'{PINNED_HOST}', got {self.residency!r}")
        if self.slots < 1:
            raise ValueError(f"tier {self.name!r} needs at least 1 slot")
        if self.residency == DEVICE and (self.wear_tracked
                                         or self.wear_leveling
                                         or self.quantize_int8):
            raise ValueError(
                f"tier {self.name!r}: wear tracking / leveling / int8 "
                "quantization are host-class features (the device pool is "
                "touched inside jitted steps with no accounting hook; "
                "pinned_host tiers support them)")
        if self.wear_leveling and not self.wear_tracked:
            raise ValueError(f"tier {self.name!r}: wear_leveling requires "
                             "wear_tracked")

    @property
    def is_device(self) -> bool:
        return self.residency == DEVICE

    @property
    def is_pinned(self) -> bool:
        return self.residency == PINNED_HOST

    @property
    def is_device_addressable(self) -> bool:
        """Whether jitted device code can gather/scatter this tier's pool
        directly (device tiers and pinned-host tiers)."""
        return self.residency in (DEVICE, PINNED_HOST)

    def read_cost_ns(self) -> float:
        return cm.access_latency_ns(self.medium, is_write=False)

    def write_cost_ns(self) -> float:
        return cm.access_latency_ns(self.medium, is_write=True)


@dataclass(frozen=True)
class MemoryHierarchy:
    """An ordered (fastest -> slowest) list of :class:`MediumSpec` tiers."""

    tiers: tuple[MediumSpec, ...]

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError("a MemoryHierarchy needs at least 2 tiers")
        object.__setattr__(self, "tiers", tuple(self.tiers))

    # -- container protocol ---------------------------------------------------
    def __len__(self) -> int:
        return len(self.tiers)

    def __iter__(self):
        return iter(self.tiers)

    def __getitem__(self, i: int) -> MediumSpec:
        return self.tiers[i]

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def deepest(self) -> int:
        """Index of the slowest tier (the default residence of new pages)."""
        return len(self.tiers) - 1

    # -- tier subsets ---------------------------------------------------------
    def device_tiers(self) -> list[int]:
        return [i for i, t in enumerate(self.tiers) if t.is_device]

    def host_tiers(self) -> list[int]:
        return [i for i, t in enumerate(self.tiers) if not t.is_device]

    def pinned_tiers(self) -> list[int]:
        return [i for i, t in enumerate(self.tiers) if t.is_pinned]

    def wear_tiers(self) -> list[int]:
        return [i for i, t in enumerate(self.tiers) if t.wear_tracked]

    def total_slots(self) -> int:
        return sum(t.slots for t in self.tiers)

    def describe(self) -> str:
        return " -> ".join(f"{t.name}[{t.slots}{'*' if t.is_device else ''}]"
                           for t in self.tiers)

    # -- canonical constructors ----------------------------------------------
    @classmethod
    def two_tier(cls, fast_slots: int, slow_slots: int, *,
                 quantize_slow: bool = False, track_wear: bool = True,
                 wear_leveling: bool = True,
                 gap_write_interval: int | None = None,
                 pinned_slow: bool = False) -> "MemoryHierarchy":
        """The pre-redesign FAST/SLOW pair: a device HBM tier over a host
        NVM-analogue tier.  Behaviorally bit-identical to the old
        hardcoded ``TierStore`` (parity-pinned against a golden trace).
        ``pinned_slow`` backs the NVM tier with a pinned-host jax buffer
        instead of a numpy pool — same telemetry, device-addressable."""
        return cls(tiers=(
            MediumSpec("HBM", fast_slots, cm.HBM, residency=DEVICE),
            MediumSpec("NVM", slow_slots, cm.NVM,
                       residency=PINNED_HOST if pinned_slow else HOST,
                       wear_tracked=track_wear,
                       wear_leveling=track_wear and wear_leveling,
                       gap_write_interval=gap_write_interval,
                       quantize_int8=quantize_slow),
        ))

    @classmethod
    def three_tier(cls, hbm_slots: int, dram_slots: int, nvm_slots: int, *,
                   quantize_nvm: bool = False, track_wear: bool = True,
                   wear_leveling: bool = True,
                   gap_write_interval: int | None = None,
                   pinned_nvm: bool = False) -> "MemoryHierarchy":
        """The HBM -> DRAM -> NVM demo hierarchy: a second device-resident
        pool simulates the DRAM channel (device<->device migration stays
        on-accelerator), backed by the host NVM-analogue tier with wear
        telemetry.  ``pinned_nvm`` makes the NVM tier a pinned-host jax
        pool (device-addressable, donated demotion commits)."""
        return cls(tiers=(
            MediumSpec("HBM", hbm_slots, cm.HBM, residency=DEVICE),
            MediumSpec("DRAM", dram_slots, cm.DRAM, residency=DEVICE),
            MediumSpec("NVM", nvm_slots, cm.NVM,
                       residency=PINNED_HOST if pinned_nvm else HOST,
                       wear_tracked=track_wear,
                       wear_leveling=track_wear and wear_leveling,
                       gap_write_interval=gap_write_interval,
                       quantize_int8=quantize_nvm),
        ))

    def with_tier(self, i: int, **changes) -> "MemoryHierarchy":
        """A copy with tier ``i`` replaced (dataclasses.replace semantics)."""
        tiers = list(self.tiers)
        tiers[i] = replace(tiers[i], **changes)
        return MemoryHierarchy(tiers=tuple(tiers))
