"""Data-migration engine (paper Sec. 6.3, Fig. 10 step 4).

Two migration paths, matching the paper:

  * ``locked``     — CPU-style synchronous per-page copy under a lock
                     (serving writes to the batch are fenced).  Preferred
                     for small batches of hot/WD pages moving slow->fast.
  * ``optimistic`` — unlocked DMA-style bulk copy: snapshot per-page
                     version counters, copy the whole batch without
                     blocking writers, then commit only pages whose version
                     did not advance during the copy (the paper's post-hoc
                     dirty-bit check); dirtied pages are retried on the
                     next iteration ("the migration engine works
                     iteratively").  Preferred for bulk cold/RD fast->slow
                     moves, which are rarely dirtied mid-copy.

Two scheduling modes: ``lazy`` (default, move when the memos loop fires)
and ``eager`` (callers move pages immediately on request).

Placement of the destination slot follows Algorithm 2: coldest bank, then
coldest non-reserved slab with free rows (per the frequency tables of the
current pass), so migrations simultaneously rebalance bank and slab load.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable

import numpy as np

from . import placement
from .placement import FAST, SLOW
from .tiers import TierStore, NO_SLOT


@dataclass
class MigrationStats:
    migrated: int = 0
    dirty_discards: int = 0
    retries: int = 0
    bytes_moved: int = 0
    to_fast: int = 0
    to_slow: int = 0

    def merge(self, other: "MigrationStats") -> None:
        self.migrated += other.migrated
        self.dirty_discards += other.dirty_discards
        self.retries += other.retries
        self.bytes_moved += other.bytes_moved
        self.to_fast += other.to_fast
        self.to_slow += other.to_slow


class MigrationEngine:
    def __init__(self, store: TierStore, *, max_retries: int = 3):
        self.store = store
        self.max_retries = max_retries
        self.stats = MigrationStats()

    # -- slot targeting (Algorithm 2) ----------------------------------------
    def _target_color(self, dst_tier: int, bank_freq: np.ndarray | None,
                      slab_freq: np.ndarray | None,
                      reuse_class: int | None = None) -> tuple[int | None, int | None]:
        """color = bank*n_slabs + slab, per Algorithm 2 + reserved-slab rules."""
        cfg = self.store.alloc[dst_tier].cfg
        if bank_freq is None or slab_freq is None:
            return None, None
        forced_slab = (placement.slab_for_reuse_class(reuse_class)
                       if reuse_class is not None else None)

        # fold the monitor's bank/slab frequency space onto the allocator's
        # (the monitor tracks logical banks = device shards, which may be a
        # different cardinality from the slot pool's color geometry)
        def fold(freq: np.ndarray, n: int) -> np.ndarray:
            out = np.zeros(n, dtype=np.float64)
            for i, v in enumerate(np.asarray(freq)):
                out[i % n] += v
            return out

        bfreq = fold(bank_freq, cfg.n_banks)
        sfreq = fold(slab_freq, cfg.n_slabs)

        def rows_free(bank: int, slab: int) -> bool:
            # optimistic probe; the allocator falls back to any color when
            # the exact color is exhausted (see TierStore.move_page)
            return True

        if forced_slab is not None:
            bank = int(np.argmin(bfreq))
            slab = forced_slab % cfg.n_slabs
            return bank * cfg.n_slabs + slab, cfg.n_colors - 1
        reserved = tuple(r for r in (placement.RESERVED_THRASH_SLAB,
                                     placement.RESERVED_RARE_SLAB)
                         if r < cfg.n_slabs) if cfg.n_slabs > 2 else ()
        got = placement.coldest_bank_and_slab(bfreq, sfreq, rows_free,
                                              reserved=reserved)
        if got is None:
            return None, None
        bank, slab = got
        return bank * cfg.n_slabs + slab, cfg.n_colors - 1

    # -- locked path -----------------------------------------------------------
    def migrate_locked(self, pages: Iterable[int], dst_tier: int,
                       bank_freq: np.ndarray | None = None,
                       slab_freq: np.ndarray | None = None,
                       reuse_class: np.ndarray | None = None) -> MigrationStats:
        st = MigrationStats()
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for p in pages:
            rc = None if reuse_class is None else int(reuse_class[p])
            color, mask = self._target_color(dst_tier, bank_freq, slab_freq, rc)
            ok = self.store.move_page(int(p), dst_tier, color, mask)
            if ok:
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                if dst_tier == FAST:
                    st.to_fast += 1
                else:
                    st.to_slow += 1
                if bank_freq is not None:
                    # account the move so subsequent picks spread across banks
                    cfg = self.store.alloc[dst_tier].cfg
                    b = cfg.bank_of(int(self.store.slot[p])) % len(bank_freq)
                    bank_freq[b] += 1
        self.stats.merge(st)
        return st

    # -- optimistic (unlocked DMA) path ---------------------------------------
    def migrate_optimistic(
        self, pages: Iterable[int], dst_tier: int,
        bank_freq: np.ndarray | None = None,
        slab_freq: np.ndarray | None = None,
        reuse_class: np.ndarray | None = None,
        concurrent_writer: Callable[[], None] | None = None,
    ) -> MigrationStats:
        """Bulk copy without locking; commit only pages not dirtied mid-copy.

        ``concurrent_writer`` is a test/simulation hook invoked between the
        bulk copy and the version re-check, standing in for writes that land
        while the DMA is in flight.
        """
        st = MigrationStats()
        pending = [int(p) for p in pages
                   if int(self.store.tier[p]) != dst_tier
                   and int(self.store.slot[p]) != NO_SLOT]
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for attempt in range(self.max_retries + 1):
            if not pending:
                break
            if attempt > 0:
                st.retries += 1
            # 1) snapshot versions, 2) unlocked bulk copy to staging
            vsnap = {p: int(self.store.version[p]) for p in pending}
            staged = {p: self.store.read_page(p) for p in pending}
            if concurrent_writer is not None:
                concurrent_writer()
                concurrent_writer = None  # writer fires once
            # 3) dirty check + commit clean pages
            dirty: list[int] = []
            for p in pending:
                if int(self.store.version[p]) != vsnap[p]:
                    dirty.append(p)      # discard: will retry next iteration
                    st.dirty_discards += 1
                    continue
                rc = None if reuse_class is None else int(reuse_class[p])
                color, mask = self._target_color(dst_tier, bank_freq,
                                                 slab_freq, rc)
                new_slot = self.store.alloc[dst_tier].alloc(0, color, mask)
                if new_slot is None and color is not None:
                    new_slot = self.store.alloc[dst_tier].alloc(0, None)
                if new_slot is None:
                    continue
                old_tier, old_slot = int(self.store.tier[p]), int(self.store.slot[p])
                if dst_tier == FAST:
                    import jax.numpy as jnp
                    self.store.fast_pool = self.store.fast_pool.at[new_slot].set(
                        jnp.asarray(staged[p], self.store.cfg.dtype))
                else:
                    self.store._slow_write(new_slot, staged[p])
                self.store.alloc[old_tier].free(old_slot, 0)
                self.store.tier[p] = dst_tier
                self.store.slot[p] = new_slot
                self.store.traffic[(old_tier, dst_tier)] += self.store.page_nbytes
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                if dst_tier == FAST:
                    st.to_fast += 1
                else:
                    st.to_slow += 1
            pending = dirty
        self.stats.merge(st)
        return st

    # -- policy-selected execution (Sec. 6.3 observed asymmetry) ---------------
    def execute(self, decision: placement.PlacementDecision,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationStats:
        """Run a planned migration: slow->fast hot/WD pages take the locked
        path (small, must be consistent *now*); fast->slow bulk cold/RD
        pages take the optimistic DMA path."""
        st = MigrationStats()
        hl = decision.hotness_list
        to_fast = [p for p in hl if decision.target_tier[p] == FAST]
        to_slow = [p for p in hl if decision.target_tier[p] == SLOW]
        st.merge(self.migrate_locked(to_fast, FAST, bank_freq, slab_freq,
                                     reuse_class))
        st.merge(self.migrate_optimistic(to_slow, SLOW, bank_freq, slab_freq,
                                         reuse_class))
        return st
