"""Migration engines (paper Sec. 6.3, Fig. 10 step 4): plan/execute split.

Migration is two phases with a narrow interface between them:

  * **plan** (host) — the memos pass walks the hotness list, picks each
    page's destination slot per Algorithm 2 (coldest bank, then coldest
    non-reserved slab; reserved-slab routing for Thrashing/Rarely-touched
    pages), and reserves the slots in the sub-buddy allocator.  The output
    is a ``MigrationPlan``: parallel arrays of (page, src slot, dst slot)
    plus a per-page version snapshot for the dirty check.
  * **execute** (device) — the plan is applied as bulk data movement.

Two engines implement execute:

  * ``MigrationEngine`` — the numpy **reference** implementation: a
    host-side per-page copy loop.  Retained as the parity oracle
    (tests/test_batched_migration.py) and as the slow baseline in
    benchmarks/migration_bw.py.
  * ``BatchedMigrationEngine`` — the **device-resident** fast path.  One
    bulk move per direction: evicted fast-pool pages are packed into a
    contiguous staging buffer by the ``kernels/page_gather`` Pallas kernel
    (XLA gather off-TPU) and streamed to the host slow tier through
    chunked, double-buffered async device→host copies; promoted pages are
    staged host→device the same way and scattered into their planned
    slots with a donated pool buffer, so the whole batch costs one
    compiled dispatch per chunk instead of one per page.

Both engines expose the same two paths, matching the paper:

  * ``locked``     — synchronous copy, commit unconditionally; used for
                     small batches of hot/WD pages moving slow->fast.
  * ``optimistic`` — unlocked DMA-style copy: snapshot per-page version
                     counters, copy without blocking writers, commit only
                     pages whose version did not advance mid-copy (the
                     paper's post-hoc dirty-bit check), retry dirtied
                     pages iteratively.  Used for bulk cold/RD fast->slow
                     moves, which are rarely dirtied mid-copy.

The engines make identical allocator calls in identical order, so for the
same inputs they produce identical tier/slot tables and pool contents —
that equivalence is what the parity suite pins down.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable

import jax
import numpy as np

from . import placement
from .placement import FAST, SLOW
from .tiers import TierStore, NO_SLOT

# Bump when engine semantics / data layout change; recorded in benchmark
# result JSONs so trajectory comparisons across machines and revisions
# aren't apples-to-oranges.
ENGINE_VERSION = "2.0"  # 1.x: per-page reference loop; 2.x: batched bulk
                        # mover + NVM wear accounting on the slow path


def bench_env() -> dict:
    """Execution-environment record shared by every benchmark result JSON."""
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "engine_version": ENGINE_VERSION,
    }


@dataclass
class MigrationStats:
    migrated: int = 0
    dirty_discards: int = 0
    retries: int = 0
    bytes_moved: int = 0
    to_fast: int = 0
    to_slow: int = 0

    def merge(self, other: "MigrationStats") -> None:
        self.migrated += other.migrated
        self.dirty_discards += other.dirty_discards
        self.retries += other.retries
        self.bytes_moved += other.bytes_moved
        self.to_fast += other.to_fast
        self.to_slow += other.to_slow


# =============================================================================
# slot targeting (Algorithm 2) — shared by both engines
# =============================================================================

def target_color(store: TierStore, dst_tier: int,
                 bank_freq: np.ndarray | None,
                 slab_freq: np.ndarray | None,
                 reuse_class: int | None = None) -> tuple[int | None, int | None]:
    """color = bank*n_slabs + slab, per Algorithm 2 + reserved-slab rules."""
    cfg = store.alloc[dst_tier].cfg
    if bank_freq is None or slab_freq is None:
        return None, None
    forced_slab = (placement.slab_for_reuse_class(reuse_class)
                   if reuse_class is not None else None)

    # fold the monitor's bank/slab frequency space onto the allocator's
    # (the monitor tracks logical banks = device shards, which may be a
    # different cardinality from the slot pool's color geometry)
    def fold(freq: np.ndarray, n: int) -> np.ndarray:
        out = np.zeros(n, dtype=np.float64)
        for i, v in enumerate(np.asarray(freq)):
            out[i % n] += v
        return out

    bfreq = fold(bank_freq, cfg.n_banks)
    sfreq = fold(slab_freq, cfg.n_slabs)

    def rows_free(bank: int, slab: int) -> bool:
        # optimistic probe; the allocator falls back to any color when
        # the exact color is exhausted (see TierStore.move_page)
        return True

    if forced_slab is not None:
        bank = int(np.argmin(bfreq))
        slab = forced_slab % cfg.n_slabs
        return bank * cfg.n_slabs + slab, cfg.n_colors - 1
    reserved = tuple(r for r in (placement.RESERVED_THRASH_SLAB,
                                 placement.RESERVED_RARE_SLAB)
                     if r < cfg.n_slabs) if cfg.n_slabs > 2 else ()
    got = placement.coldest_bank_and_slab(bfreq, sfreq, rows_free,
                                          reserved=reserved)
    if got is None:
        return None, None
    bank, slab = got
    return bank * cfg.n_slabs + slab, cfg.n_colors - 1


def _alloc_target_slot(store: TierStore, dst_tier: int,
                       bank_freq: np.ndarray | None,
                       slab_freq: np.ndarray | None,
                       reuse_class: int | None) -> int | None:
    """Reserve one destination slot per Algorithm 2, falling back to any
    color when the targeted slab walk is exhausted (capacity is the real
    bound, not color)."""
    color, mask = target_color(store, dst_tier, bank_freq, slab_freq,
                               reuse_class)
    slot = store.alloc[dst_tier].alloc(0, color, mask)
    if slot is None and color is not None:
        slot = store.alloc[dst_tier].alloc(0, None)
    return slot


# =============================================================================
# plans
# =============================================================================

@dataclass
class MigrationPlan:
    """A reserved, executable bulk move in one direction.

    ``pages[i]`` moves ``src_slots[i]`` (in the source tier) ->
    ``dst_slots[i]`` (reserved in ``dst_tier``).  ``trivial`` counts pages
    that were requested but already sit in ``dst_tier`` (the locked path
    reports them as migrated without moving data, like the reference).
    """
    dst_tier: int
    pages: np.ndarray       # int64 [k]
    src_slots: np.ndarray   # int64 [k]
    dst_slots: np.ndarray   # int64 [k]
    trivial: int = 0

    @property
    def src_tier(self) -> int:
        return FAST if self.dst_tier == SLOW else SLOW

    def __len__(self) -> int:
        return int(self.pages.size)


def plan_locked(store: TierStore, pages: Iterable[int], dst_tier: int,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationPlan:
    """Phase 1 for the locked path: reserve destination slots for every
    movable page, in hotness-list order (allocator call sequence identical
    to the reference engine's, so both engines land pages in the same
    slots)."""
    bank_freq = None if bank_freq is None else np.array(bank_freq)
    mv_pages: list[int] = []
    src_slots: list[int] = []
    dst_slots: list[int] = []
    planned: dict[int, int] = {}            # page -> reserved dst slot
    trivial = 0

    def account(slot: int) -> None:
        # account the move so subsequent picks spread across banks
        if bank_freq is not None:
            cfg = store.alloc[dst_tier].cfg
            bank_freq[cfg.bank_of(slot) % len(bank_freq)] += 1

    for p in pages:
        p = int(p)
        cur_slot = planned.get(p, int(store.slot[p]))
        if int(store.tier[p]) == dst_tier or p in planned:
            # already there (or already planned this batch): the reference
            # reports these as migrated without moving data
            trivial += 1
            account(cur_slot)
            continue
        if cur_slot == NO_SLOT:
            continue                        # released page: nothing to move
        rc = None if reuse_class is None else int(reuse_class[p])
        new_slot = _alloc_target_slot(store, dst_tier, bank_freq, slab_freq, rc)
        if new_slot is None:
            continue
        mv_pages.append(p)
        src_slots.append(cur_slot)
        dst_slots.append(new_slot)
        planned[p] = new_slot
        account(new_slot)
    return MigrationPlan(
        dst_tier=dst_tier,
        pages=np.asarray(mv_pages, np.int64),
        src_slots=np.asarray(src_slots, np.int64),
        dst_slots=np.asarray(dst_slots, np.int64),
        trivial=trivial,
    )


def execute_decision(engine, decision: placement.PlacementDecision,
                     bank_freq: np.ndarray | None = None,
                     slab_freq: np.ndarray | None = None,
                     reuse_class: np.ndarray | None = None) -> MigrationStats:
    """Direction routing shared by both engines (Sec. 6.3 observed
    asymmetry): slow->fast hot/WD pages take the locked path (small, must
    be consistent *now*); fast->slow bulk cold/RD pages take the
    optimistic DMA path."""
    st = MigrationStats()
    hl = decision.hotness_list
    to_fast = [p for p in hl if decision.target_tier[p] == FAST]
    to_slow = [p for p in hl if decision.target_tier[p] == SLOW]
    st.merge(engine.migrate_locked(to_fast, FAST, bank_freq, slab_freq,
                                   reuse_class))
    st.merge(engine.migrate_optimistic(to_slow, SLOW, bank_freq, slab_freq,
                                       reuse_class))
    return st


# =============================================================================
# reference engine (numpy per-page loop) — the parity oracle
# =============================================================================

class MigrationEngine:
    def __init__(self, store: TierStore, *, max_retries: int = 3):
        self.store = store
        self.max_retries = max_retries
        self.stats = MigrationStats()

    def _target_color(self, dst_tier: int, bank_freq: np.ndarray | None,
                      slab_freq: np.ndarray | None,
                      reuse_class: int | None = None) -> tuple[int | None, int | None]:
        return target_color(self.store, dst_tier, bank_freq, slab_freq,
                            reuse_class)

    # -- locked path -----------------------------------------------------------
    def migrate_locked(self, pages: Iterable[int], dst_tier: int,
                       bank_freq: np.ndarray | None = None,
                       slab_freq: np.ndarray | None = None,
                       reuse_class: np.ndarray | None = None) -> MigrationStats:
        st = MigrationStats()
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for p in pages:
            rc = None if reuse_class is None else int(reuse_class[p])
            color, mask = self._target_color(dst_tier, bank_freq, slab_freq, rc)
            ok = self.store.move_page(int(p), dst_tier, color, mask)
            if ok:
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                if dst_tier == FAST:
                    st.to_fast += 1
                else:
                    st.to_slow += 1
                if bank_freq is not None:
                    # account the move so subsequent picks spread across banks
                    cfg = self.store.alloc[dst_tier].cfg
                    b = cfg.bank_of(int(self.store.slot[p])) % len(bank_freq)
                    bank_freq[b] += 1
        self.stats.merge(st)
        return st

    # -- optimistic (unlocked DMA) path ---------------------------------------
    def migrate_optimistic(
        self, pages: Iterable[int], dst_tier: int,
        bank_freq: np.ndarray | None = None,
        slab_freq: np.ndarray | None = None,
        reuse_class: np.ndarray | None = None,
        concurrent_writer: Callable[[], None] | None = None,
    ) -> MigrationStats:
        """Bulk copy without locking; commit only pages not dirtied mid-copy.

        ``concurrent_writer`` is a test/simulation hook invoked between the
        bulk copy and the version re-check, standing in for writes that land
        while the DMA is in flight.
        """
        st = MigrationStats()
        pending = [int(p) for p in dict.fromkeys(int(p) for p in pages)
                   if int(self.store.tier[p]) != dst_tier
                   and int(self.store.slot[p]) != NO_SLOT]
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for attempt in range(self.max_retries + 1):
            if not pending:
                break
            if attempt > 0:
                st.retries += 1
            # 1) snapshot versions, 2) unlocked bulk copy to staging
            vsnap = {p: int(self.store.version[p]) for p in pending}
            staged = {p: self.store.read_page(p) for p in pending}
            if concurrent_writer is not None:
                concurrent_writer()
                concurrent_writer = None  # writer fires once
            # 3) dirty check + commit clean pages
            dirty: list[int] = []
            for p in pending:
                if int(self.store.version[p]) != vsnap[p]:
                    dirty.append(p)      # discard: will retry next iteration
                    st.dirty_discards += 1
                    continue
                rc = None if reuse_class is None else int(reuse_class[p])
                new_slot = _alloc_target_slot(self.store, dst_tier, bank_freq,
                                              slab_freq, rc)
                if new_slot is None:
                    continue
                old_tier, old_slot = int(self.store.tier[p]), int(self.store.slot[p])
                if dst_tier == FAST:
                    import jax.numpy as jnp
                    self.store.fast_pool = self.store.fast_pool.at[new_slot].set(
                        jnp.asarray(staged[p], self.store.cfg.dtype))
                else:
                    self.store._slow_write(new_slot, staged[p])
                self.store.alloc[old_tier].free(old_slot, 0)
                self.store.tier[p] = dst_tier
                self.store.slot[p] = new_slot
                self.store.traffic[(old_tier, dst_tier)] += self.store.page_nbytes
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                if dst_tier == FAST:
                    st.to_fast += 1
                else:
                    st.to_slow += 1
            pending = dirty
        self.stats.merge(st)
        return st

    # -- policy-selected execution (Sec. 6.3 observed asymmetry) ---------------
    def execute(self, decision: placement.PlacementDecision,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationStats:
        return execute_decision(self, decision, bank_freq, slab_freq,
                                reuse_class)


# =============================================================================
# batched device-resident engine — the fast path
# =============================================================================

class BatchedMigrationEngine:
    """Executes migration plans as bulk device ops (see module docstring).

    Drop-in for ``MigrationEngine``: same constructor, same
    ``migrate_locked`` / ``migrate_optimistic`` / ``execute`` signatures,
    same resulting tier/slot/pool state.  ``chunk_pages`` bounds the
    staging working set and is the unit of the double-buffered host↔device
    pipeline: while chunk *i* is converting on the host, chunk *i+1*'s
    gather/transfer is already in flight (JAX async dispatch +
    ``copy_to_host_async``).
    """

    def __init__(self, store: TierStore, *, max_retries: int = 3,
                 chunk_pages: int = 64):
        self.store = store
        self.max_retries = max_retries
        self.chunk_pages = max(1, int(chunk_pages))
        self.stats = MigrationStats()

    # -- bulk staging ----------------------------------------------------------
    def _stage_fast_to_host(self, slots: np.ndarray) -> np.ndarray:
        """Gather fast-pool slots into contiguous device staging (Pallas
        page_gather), then stream chunks to the host.  Each chunk's
        device→host copy is started asynchronously before the next chunk's
        gather is dispatched, so transfer overlaps packing."""
        slots = np.asarray(slots, np.int64)
        if slots.size == 0:
            return np.zeros((0, *self.store.cfg.page_shape), np.float32)
        bufs = []
        for i in range(0, slots.size, self.chunk_pages):
            g = self.store.gather_fast(slots[i:i + self.chunk_pages])
            try:
                g.copy_to_host_async()
            except AttributeError:      # older jax array types
                pass
            bufs.append(g)
        return np.concatenate([np.asarray(b, np.float32) for b in bufs])

    def _stage_host_to_fast(self, dst_slots: np.ndarray,
                            values: np.ndarray) -> None:
        """Scatter host pages into their planned fast-pool slots (Pallas
        page_scatter, pool donated).  Chunk *i+1*'s host→device transfer is
        issued before chunk *i*'s scatter blocks, double-buffering the
        upload."""
        dst_slots = np.asarray(dst_slots, np.int64)
        k = dst_slots.size
        if k == 0:
            return
        c = self.chunk_pages
        nxt = jax.device_put(values[:c])
        for i in range(0, k, c):
            cur = nxt
            if i + c < k:
                nxt = jax.device_put(values[i + c:i + 2 * c])
            self.store.scatter_fast(dst_slots[i:i + c], cur)

    # -- plan execution --------------------------------------------------------
    def execute_plan(self, plan: MigrationPlan) -> MigrationStats:
        """Apply a reserved plan as one bulk move (locked semantics: commit
        unconditionally)."""
        st = MigrationStats()
        k = len(plan)
        store = self.store
        if k:
            if plan.dst_tier == FAST:
                staged = store.slow_read_batch(plan.src_slots)
                self._stage_host_to_fast(plan.dst_slots, staged)
            else:
                staged = self._stage_fast_to_host(plan.src_slots)
                store.slow_write_batch(plan.dst_slots, staged)
            store.reads_from[plan.src_tier] += k
            store.commit_moves(plan.pages, plan.dst_tier, plan.dst_slots)
        st.migrated = k + plan.trivial
        st.bytes_moved = (k + plan.trivial) * store.page_nbytes
        if plan.dst_tier == FAST:
            st.to_fast = st.migrated
        else:
            st.to_slow = st.migrated
        self.stats.merge(st)
        return st

    # -- locked path -----------------------------------------------------------
    def migrate_locked(self, pages: Iterable[int], dst_tier: int,
                       bank_freq: np.ndarray | None = None,
                       slab_freq: np.ndarray | None = None,
                       reuse_class: np.ndarray | None = None) -> MigrationStats:
        plan = plan_locked(self.store, pages, dst_tier, bank_freq, slab_freq,
                           reuse_class)
        return self.execute_plan(plan)

    # -- optimistic (unlocked DMA) path ---------------------------------------
    def migrate_optimistic(
        self, pages: Iterable[int], dst_tier: int,
        bank_freq: np.ndarray | None = None,
        slab_freq: np.ndarray | None = None,
        reuse_class: np.ndarray | None = None,
        concurrent_writer: Callable[[], None] | None = None,
    ) -> MigrationStats:
        """Bulk unlocked copy: stage the whole batch, then commit only pages
        whose version counter did not advance mid-copy; dirtied pages retry
        on the next iteration (destination slots are only reserved after
        the dirty check, so aborted pages reserve nothing)."""
        st = MigrationStats()
        store = self.store
        pending = np.asarray(
            [int(p) for p in dict.fromkeys(int(p) for p in pages)
             if int(store.tier[p]) != dst_tier
             and int(store.slot[p]) != NO_SLOT], np.int64)
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for attempt in range(self.max_retries + 1):
            if pending.size == 0:
                break
            if attempt > 0:
                st.retries += 1
            # 1) snapshot versions, 2) unlocked bulk copy to staging
            vsnap = store.version[pending].copy()
            src_slots = store.slot[pending].copy()
            if dst_tier == SLOW:
                staged = self._stage_fast_to_host(src_slots)
            else:
                staged = store.slow_read_batch(src_slots)
            store.reads_from[FAST if dst_tier == SLOW else SLOW] += pending.size
            if concurrent_writer is not None:
                concurrent_writer()
                concurrent_writer = None  # writer fires once
            # 3) dirty check + bulk-commit clean pages
            dirty_mask = store.version[pending] != vsnap
            st.dirty_discards += int(dirty_mask.sum())
            clean = np.nonzero(~dirty_mask)[0]
            commit_idx: list[int] = []
            dst_slots: list[int] = []
            for i in clean:
                rc = (None if reuse_class is None
                      else int(reuse_class[pending[i]]))
                s = _alloc_target_slot(store, dst_tier, bank_freq, slab_freq,
                                       rc)
                if s is None:
                    continue          # capacity exhausted: drop, like the ref
                commit_idx.append(int(i))
                dst_slots.append(s)
            if commit_idx:
                idx = np.asarray(commit_idx, np.int64)
                slots = np.asarray(dst_slots, np.int64)
                if dst_tier == SLOW:
                    store.slow_write_batch(slots, staged[idx])
                else:
                    self._stage_host_to_fast(slots, staged[idx])
                store.commit_moves(pending[idx], dst_tier, slots)
                st.migrated += idx.size
                st.bytes_moved += idx.size * store.page_nbytes
                if dst_tier == FAST:
                    st.to_fast += idx.size
                else:
                    st.to_slow += idx.size
            pending = pending[dirty_mask]
        self.stats.merge(st)
        return st

    # -- policy-selected execution ---------------------------------------------
    def execute(self, decision: placement.PlacementDecision,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationStats:
        return execute_decision(self, decision, bank_freq, slab_freq,
                                reuse_class)


def make_engine(store: TierStore, kind: str = "batched", **kw):
    """Engine factory: ``"batched"`` (device-resident bulk mover, default)
    or ``"reference"`` (numpy per-page oracle)."""
    if kind == "batched":
        return BatchedMigrationEngine(store, **kw)
    if kind == "reference":
        return MigrationEngine(store, **kw)
    raise ValueError(f"unknown migration engine {kind!r}")
