"""Migration engines (paper Sec. 6.3, Fig. 10 step 4): plan/execute split,
generic over the tiers of a :class:`~repro.core.hierarchy.MemoryHierarchy`.

Migration is two phases with a narrow interface between them:

  * **plan** (host) — the memos pass walks the hotness list, picks each
    page's destination slot per Algorithm 2 (coldest bank, then coldest
    non-reserved slab; reserved-slab routing for Thrashing/Rarely-touched
    pages), and reserves the slots in the destination tier's sub-buddy
    allocator.  The output is a ``MigrationPlan``: parallel arrays of
    (page, src tier, src slot, dst slot) plus a destination tier and a
    per-page version snapshot for the dirty check.  One plan moves pages
    from *any* mix of source tiers into one destination tier.
  * **execute** (device) — the plan is applied as bulk data movement per
    (source, destination) residency pair:

      - device -> device: Pallas ``page_gather`` out of the source pool,
        ``page_scatter`` into the destination pool — the whole move stays
        on-accelerator (the HBM -> DRAM-sim path);
      - device -> host: gather into contiguous device staging, then
        chunked double-buffered async device->host copies;
      - host -> device: staged host->device uploads + donated-pool scatter;
      - host -> host: one vectorized numpy copy.

Two engines implement execute:

  * ``MigrationEngine`` — the numpy **reference** implementation: a
    host-side per-page copy loop.  Retained as the parity oracle
    (tests/test_batched_migration.py) and as the slow baseline in
    benchmarks/migration_bw.py.
  * ``BatchedMigrationEngine`` — the **device-resident** fast path
    described above; one compiled dispatch per chunk instead of one per
    page.

Both engines expose the same two paths, matching the paper:

  * ``locked``     — synchronous copy, commit unconditionally; used for
                     small batches of hot/WD pages moving toward tier 0.
  * ``optimistic`` — unlocked DMA-style copy: snapshot per-page version
                     counters, copy without blocking writers, commit only
                     pages whose version did not advance mid-copy (the
                     paper's post-hoc dirty-bit check), retry dirtied
                     pages iteratively.  Used for bulk cold/RD demotions,
                     which are rarely dirtied mid-copy.

The engines make identical allocator calls in identical order, so for the
same inputs they produce identical tier/slot tables and pool contents —
that equivalence is what the parity suite pins down.  (When one plan
mixes several *source* tiers the batched engine moves them grouped by
source tier; logical state stays identical, only the physical write order
onto wear-leveled pools may differ from the reference's interleaving.)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.faults.errors import TransientMigrationFault
from repro.faults.injector import get_injector, note_recovered

from . import placement
from .tiers import NO_SLOT, TierStore, _pad_idx_np, _pad_pages, _pow2

# Bump when engine semantics / data layout change; recorded in benchmark
# result JSONs so trajectory comparisons across machines and revisions
# aren't apples-to-oranges.
ENGINE_VERSION = "4.1"  # 1.x: per-page reference loop; 2.x: batched bulk
                        # mover + NVM wear accounting on the slow path;
                        # 3.x: N-tier plans (per-page src tier, device<->
                        # device moves); 4.x: replayable reservations
                        # (async plan/commit) + pinned-host tier routing;
                        # 4.1: page-granular async commits (clean subset
                        # executes, only dirtied pages degrade) + O(1)
                        # allocator adoption on quiet tiers


def bench_env() -> dict:
    """Execution-environment record shared by every benchmark result JSON."""
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "engine_version": ENGINE_VERSION,
    }


@dataclass
class MigrationStats:
    migrated: int = 0
    dirty_discards: int = 0
    retries: int = 0
    retries_exhausted: int = 0    # pages still dirty at the retry cap
    failed: int = 0               # pages dropped by exhausted move faults
    bytes_moved: int = 0
    to_fast: int = 0              # moves into tier 0
    to_slow: int = 0              # moves into any slower tier
    by_pair: dict = field(default_factory=dict)   # (src, dst) -> pages moved

    def note_move(self, src_tier: int, dst_tier: int, n: int = 1) -> None:
        if n:
            key = (int(src_tier), int(dst_tier))
            self.by_pair[key] = self.by_pair.get(key, 0) + n

    def merge(self, other: "MigrationStats") -> None:
        self.migrated += other.migrated
        self.dirty_discards += other.dirty_discards
        self.retries += other.retries
        self.retries_exhausted += other.retries_exhausted
        self.failed += other.failed
        self.bytes_moved += other.bytes_moved
        self.to_fast += other.to_fast
        self.to_slow += other.to_slow
        for k, v in other.by_pair.items():
            self.by_pair[k] = self.by_pair.get(k, 0) + v

    def to_dict(self) -> dict:
        """JSON-safe form: the (src, dst) tuple keys of ``by_pair``
        serialize as ``"src->dst"`` strings."""
        return {
            "migrated": self.migrated,
            "dirty_discards": self.dirty_discards,
            "retries": self.retries,
            "retries_exhausted": self.retries_exhausted,
            "failed": self.failed,
            "bytes_moved": self.bytes_moved,
            "to_fast": self.to_fast,
            "to_slow": self.to_slow,
            "by_pair": {f"{s}->{d}": n
                        for (s, d), n in sorted(self.by_pair.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MigrationStats":
        by_pair = {}
        for k, n in d.get("by_pair", {}).items():
            s, _, dst = k.partition("->")
            by_pair[(int(s), int(dst))] = int(n)
        return cls(
            migrated=int(d.get("migrated", 0)),
            dirty_discards=int(d.get("dirty_discards", 0)),
            retries=int(d.get("retries", 0)),
            retries_exhausted=int(d.get("retries_exhausted", 0)),
            failed=int(d.get("failed", 0)),
            bytes_moved=int(d.get("bytes_moved", 0)),
            to_fast=int(d.get("to_fast", 0)),
            to_slow=int(d.get("to_slow", 0)),
            by_pair=by_pair,
        )


# =============================================================================
# slot targeting (Algorithm 2) — shared by both engines
# =============================================================================

def target_color(store: TierStore, dst_tier: int,
                 bank_freq: np.ndarray | None,
                 slab_freq: np.ndarray | None,
                 reuse_class: int | None = None) -> tuple[int | None, int | None]:
    """color = bank*n_slabs + slab, per Algorithm 2 + reserved-slab rules."""
    cfg = store.alloc[dst_tier].cfg
    if bank_freq is None or slab_freq is None:
        return None, None
    forced_slab = (placement.slab_for_reuse_class(reuse_class)
                   if reuse_class is not None else None)

    # fold the monitor's bank/slab frequency space onto the allocator's
    # (the monitor tracks logical banks = device shards, which may be a
    # different cardinality from the slot pool's color geometry)
    def fold(freq: np.ndarray, n: int) -> np.ndarray:
        out = np.zeros(n, dtype=np.float64)
        for i, v in enumerate(np.asarray(freq)):
            out[i % n] += v
        return out

    bfreq = fold(bank_freq, cfg.n_banks)
    sfreq = fold(slab_freq, cfg.n_slabs)

    def rows_free(bank: int, slab: int) -> bool:
        # optimistic probe; the allocator falls back to any color when
        # the exact color is exhausted (see TierStore.move_page)
        return True

    if forced_slab is not None:
        bank = int(np.argmin(bfreq))
        slab = forced_slab % cfg.n_slabs
        return bank * cfg.n_slabs + slab, cfg.n_colors - 1
    reserved = tuple(r for r in (placement.RESERVED_THRASH_SLAB,
                                 placement.RESERVED_RARE_SLAB)
                     if r < cfg.n_slabs) if cfg.n_slabs > 2 else ()
    got = placement.coldest_bank_and_slab(bfreq, sfreq, rows_free,
                                          reserved=reserved)
    if got is None:
        return None, None
    bank, slab = got
    return bank * cfg.n_slabs + slab, cfg.n_colors - 1


def _alloc_target_slot_rec(store, dst_tier: int,
                           bank_freq: np.ndarray | None,
                           slab_freq: np.ndarray | None,
                           reuse_class: int | None
                           ) -> tuple[int | None, int, int]:
    """Reserve one destination slot per Algorithm 2, falling back to any
    color when the targeted slab walk is exhausted (capacity is the real
    bound, not color).  Returns (slot, color, mask) where color/mask
    record the allocator call that actually produced the slot (-1 = any
    color) — the asynchronous commit replays exactly these calls against
    the live allocator and treats any divergence as a plan conflict."""
    color, mask = target_color(store, dst_tier, bank_freq, slab_freq,
                               reuse_class)
    slot = store.alloc[dst_tier].alloc(0, color, mask)
    if slot is not None:
        return slot, (-1 if color is None else int(color)), \
            (-1 if mask is None else int(mask))
    if color is not None:
        slot = store.alloc[dst_tier].alloc(0, None)
    return slot, -1, -1


def _alloc_target_slot(store, dst_tier: int,
                       bank_freq: np.ndarray | None,
                       slab_freq: np.ndarray | None,
                       reuse_class: int | None) -> int | None:
    return _alloc_target_slot_rec(store, dst_tier, bank_freq, slab_freq,
                                  reuse_class)[0]


# =============================================================================
# plans
# =============================================================================

@dataclass
class MigrationPlan:
    """A reserved, executable bulk move into one destination tier.

    ``pages[i]`` moves from ``src_tiers[i]`` / ``src_slots[i]`` ->
    ``dst_slots[i]`` (reserved in ``dst_tier``).  Source tiers may be
    mixed within one plan.  ``trivial`` counts pages that were requested
    but already sit in ``dst_tier`` (the locked path reports them as
    migrated without moving data, like the reference).

    ``colors``/``masks`` record the Algorithm-2 allocator call that
    reserved each slot (-1 = any color): a plan produced against a
    :class:`StoreView` snapshot has its reservations *simulated* on
    cloned allocators, and ``commit_reservations`` lands them on the
    live store at commit time (clone adoption when the allocator saw no
    interleaved call, per-call slot-patching replay otherwise).  ``reads_by_tier``
    carries the staging read charge for optimistic plans (the unlocked
    copy stages every pending page, including ones later dropped for
    capacity, so the async commit charges the same reads the synchronous
    path would).
    """
    dst_tier: int
    pages: np.ndarray       # int64 [k]
    src_tiers: np.ndarray   # int8  [k]
    src_slots: np.ndarray   # int64 [k]
    dst_slots: np.ndarray   # int64 [k]
    trivial: int = 0
    colors: np.ndarray | None = None   # int64 [k], -1 = any
    masks: np.ndarray | None = None    # int64 [k], -1 = full mask
    reads_by_tier: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return int(self.pages.size)


def plan_locked(store: TierStore, pages: Iterable[int], dst_tier: int,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationPlan:
    """Phase 1 for the locked path: reserve destination slots for every
    movable page, in hotness-list order (allocator call sequence identical
    to the reference engine's, so both engines land pages in the same
    slots)."""
    bank_freq = None if bank_freq is None else np.array(bank_freq)
    mv_pages: list[int] = []
    src_tiers: list[int] = []
    src_slots: list[int] = []
    dst_slots: list[int] = []
    colors: list[int] = []
    masks: list[int] = []
    planned: dict[int, int] = {}            # page -> reserved dst slot
    trivial = 0

    def account(slot: int) -> None:
        # account the move so subsequent picks spread across banks
        if bank_freq is not None:
            cfg = store.alloc[dst_tier].cfg
            bank_freq[cfg.bank_of(slot) % len(bank_freq)] += 1

    for p in pages:
        p = int(p)
        cur_slot = planned.get(p, int(store.slot[p]))
        if int(store.tier[p]) == dst_tier or p in planned:
            # already there (or already planned this batch): the reference
            # reports these as migrated without moving data
            trivial += 1
            account(cur_slot)
            continue
        if cur_slot == NO_SLOT:
            continue                        # released page: nothing to move
        rc = None if reuse_class is None else int(reuse_class[p])
        new_slot, color, mask = _alloc_target_slot_rec(
            store, dst_tier, bank_freq, slab_freq, rc)
        if new_slot is None:
            continue
        mv_pages.append(p)
        src_tiers.append(int(store.tier[p]))
        src_slots.append(cur_slot)
        dst_slots.append(new_slot)
        colors.append(color)
        masks.append(mask)
        planned[p] = new_slot
        account(new_slot)
    return MigrationPlan(
        dst_tier=dst_tier,
        pages=np.asarray(mv_pages, np.int64),
        src_tiers=np.asarray(src_tiers, np.int8),
        src_slots=np.asarray(src_slots, np.int64),
        dst_slots=np.asarray(dst_slots, np.int64),
        trivial=trivial,
        colors=np.asarray(colors, np.int64),
        masks=np.asarray(masks, np.int64),
    )


def plan_optimistic(store, pages: Iterable[int], dst_tier: int,
                    bank_freq: np.ndarray | None = None,
                    slab_freq: np.ndarray | None = None,
                    reuse_class: np.ndarray | None = None) -> MigrationPlan:
    """Phase 1 for the optimistic path: the reservation sequence of one
    clean ``migrate_optimistic`` attempt (dedupe, skip already-there /
    released pages, one Algorithm-2 allocator call per page in list
    order, *no* bank-frequency accounting between picks) without touching
    any data.  Run against a :class:`StoreView` this simulates the whole
    demotion commit on the plan worker; the version check that the
    synchronous path does after staging becomes the commit-time snapshot
    validation."""
    pending = [int(p) for p in dict.fromkeys(int(p) for p in pages)
               if int(store.tier[p]) != dst_tier
               and int(store.slot[p]) != NO_SLOT]
    bank_freq = None if bank_freq is None else np.array(bank_freq)
    mv_pages: list[int] = []
    src_tiers: list[int] = []
    src_slots: list[int] = []
    dst_slots: list[int] = []
    colors: list[int] = []
    masks: list[int] = []
    reads_by_tier: dict[int, int] = {}
    for p in pending:
        # the unlocked copy stages every pending page before the dirty
        # check — mirror its read charge even for pages dropped below
        t = int(store.tier[p])
        reads_by_tier[t] = reads_by_tier.get(t, 0) + 1
    for p in pending:
        rc = None if reuse_class is None else int(reuse_class[p])
        new_slot, color, mask = _alloc_target_slot_rec(
            store, dst_tier, bank_freq, slab_freq, rc)
        if new_slot is None:
            continue          # capacity exhausted: drop, like the engines
        mv_pages.append(p)
        src_tiers.append(int(store.tier[p]))
        src_slots.append(int(store.slot[p]))
        dst_slots.append(new_slot)
        colors.append(color)
        masks.append(mask)
    return MigrationPlan(
        dst_tier=dst_tier,
        pages=np.asarray(mv_pages, np.int64),
        src_tiers=np.asarray(src_tiers, np.int8),
        src_slots=np.asarray(src_slots, np.int64),
        dst_slots=np.asarray(dst_slots, np.int64),
        trivial=0,
        colors=np.asarray(colors, np.int64),
        masks=np.asarray(masks, np.int64),
        reads_by_tier=reads_by_tier,
    )


class StoreView:
    """Immutable-world facade for the asynchronous plan phase.

    Snapshots the placement-visible store state (page table, version
    counters, cloned per-tier allocators) at a dispatch boundary; the
    plan worker runs ``plan_locked`` / ``plan_optimistic`` against it —
    they only touch ``tier``/``slot``/``alloc`` — so Algorithm-2 slot
    targeting simulates its reservations off-thread while the next
    dispatch runs.  Creating the view also records each tier allocator's
    generation counter and opens the store's dirty-page epoch: the commit
    validates per page against the epoch's dirty set (O(dirtied pages))
    and adopts any clone whose tier saw no interleaved allocator call
    (O(1)) instead of replaying every reservation."""

    def __init__(self, store: TierStore):
        self.tier = store.tier.copy()
        self.slot = store.slot.copy()
        self.version = store.version.copy()
        self.alloc = [a.clone() for a in store.alloc]
        self.alloc_gen = [a.gen for a in store.alloc]
        self.hierarchy = store.hierarchy
        self.n_tiers = store.n_tiers
        store.begin_dirty_epoch()


def _group_decision(store, decision: placement.PlacementDecision
                    ) -> tuple[dict, dict]:
    """(promotions, demotions) per destination tier, in hotness-list
    order — THE grouping both ``execute_decision`` and ``plan_decision``
    must share: the async commit's every-page-lands-in-the-same-slot
    guarantee holds only while their allocator call order is identical."""
    cur = store.tier
    tgt = decision.target_tier
    promos = {t: [] for t in range(store.n_tiers)}
    demos = {t: [] for t in range(store.n_tiers)}
    for p in decision.hotness_list:
        src, dst = int(cur[p]), int(tgt[p])
        if dst == src:
            continue
        (promos if dst < src else demos)[dst].append(int(p))
    return promos, demos


def plan_decision(store, decision: placement.PlacementDecision,
                  bank_freq: np.ndarray | None = None,
                  slab_freq: np.ndarray | None = None,
                  reuse_class: np.ndarray | None = None) -> list[MigrationPlan]:
    """Reserve every migration of a ``PlacementDecision`` without moving
    data: the same destination grouping and allocator call order as
    ``execute_decision`` (promotions per dst tier shallowest-first via
    the locked sequence, then demotions via the optimistic sequence), so
    a conflict-free commit lands every page in exactly the slot the
    synchronous pass would have picked.  ``store`` may be a live
    ``TierStore`` or a :class:`StoreView` snapshot."""
    n_tiers = store.n_tiers
    promos, demos = _group_decision(store, decision)
    plans: list[MigrationPlan] = []
    for dst in range(n_tiers):
        if promos[dst]:
            plans.append(plan_locked(store, promos[dst], dst, bank_freq,
                                     slab_freq, reuse_class))
    for dst in range(n_tiers):
        if demos[dst]:
            plans.append(plan_optimistic(store, demos[dst], dst, bank_freq,
                                         slab_freq, reuse_class))
    return plans


def _replay_calls(store: TierStore, plan: MigrationPlan) -> np.ndarray:
    """Re-issue one plan's recorded allocator calls on the live store, in
    order.  Interleaved allocator activity (tail-page provisioning,
    promotion frees) means the live free lists no longer match the
    snapshot clones, so a call may land on a *different* slot than the
    plan simulated — that is not a conflict: the page itself is still
    clean, and the slot actually obtained is exactly what a synchronous
    pass planning at this boundary would have taken, so the plan is
    patched to it in place.  Only a capacity failure (the tier is full
    even after the any-color fallback, mirroring the planners) drops a
    reservation.  Returns the bool landed-mask."""
    assert plan.colors is not None and plan.masks is not None, \
        "replay needs a plan with recorded allocator calls"
    ok = np.zeros(len(plan), bool)
    for i in range(len(plan)):
        c, m = int(plan.colors[i]), int(plan.masks[i])
        s = store.alloc[plan.dst_tier].alloc(
            0, None if c < 0 else c, None if m < 0 else m)
        if s is None and c >= 0:
            s = store.alloc[plan.dst_tier].alloc(0, None)
        if s is None:
            continue
        plan.dst_slots[i] = s
        ok[i] = True
    return ok


def commit_reservations(store: TierStore, view: StoreView,
                        plans: list[MigrationPlan]) -> list[np.ndarray]:
    """Make the live allocators hold each plan's reservations; returns
    one bool landed-mask per plan (False = no capacity left for that
    page at commit time).

    Fast path: a destination tier whose live generation counter still
    equals the snapshot's saw *no* allocator call during the dispatch, so
    the view's clone — which already holds every simulated reservation —
    simply becomes the live allocator (O(1), no per-call replay, slots
    land exactly as simulated).  Tiers with interleaved activity (e.g.
    tier 0 tail-page provisioning) fall back to per-call replay, which
    patches each reservation to the slot the live allocator actually
    hands out."""
    landed = [np.zeros(len(pl), bool) for pl in plans]
    by_tier: dict[int, list[int]] = {}
    for i, pl in enumerate(plans):
        by_tier.setdefault(pl.dst_tier, []).append(i)
    for t, idxs in by_tier.items():
        if store.alloc[t].gen == view.alloc_gen[t]:
            store.alloc[t] = view.alloc[t]
            for i in idxs:
                landed[i][:] = True
        else:
            for i in idxs:        # plan order == simulation order
                landed[i] = _replay_calls(store, plans[i])
    return landed


def subset_plan(plan: MigrationPlan, keep: np.ndarray) -> MigrationPlan:
    """The sub-plan of ``plan`` restricted to the kept pages (bool mask).
    ``trivial`` and ``reads_by_tier`` carry over whole: trivial pages
    were never moving, and the optimistic staging read charge covers
    every *pending* page — the synchronous unlocked copy stages dirtied
    pages too before discarding them."""
    keep = np.asarray(keep, bool)
    if keep.all():
        return plan
    return MigrationPlan(
        dst_tier=plan.dst_tier,
        pages=plan.pages[keep],
        src_tiers=plan.src_tiers[keep],
        src_slots=plan.src_slots[keep],
        dst_slots=plan.dst_slots[keep],
        trivial=plan.trivial,
        colors=None if plan.colors is None else plan.colors[keep],
        masks=None if plan.masks is None else plan.masks[keep],
        reads_by_tier=plan.reads_by_tier,
    )


def execute_decision(engine, decision: placement.PlacementDecision,
                     bank_freq: np.ndarray | None = None,
                     slab_freq: np.ndarray | None = None,
                     reuse_class: np.ndarray | None = None) -> MigrationStats:
    """Direction routing shared by both engines (Sec. 6.3 observed
    asymmetry): promotions — moves toward a faster tier, hot/WD pages —
    take the locked path (small, must be consistent *now*); demotions —
    bulk cold/RD moves toward slower tiers — take the optimistic DMA
    path.  Pages are grouped per destination tier (shallowest first, in
    hotness-list order within each group) so both engines make identical
    allocator calls in identical order."""
    st = MigrationStats()
    n_tiers = engine.store.n_tiers
    promos, demos = _group_decision(engine.store, decision)
    for dst in range(n_tiers):
        if promos[dst]:
            st.merge(engine.migrate_locked(promos[dst], dst, bank_freq,
                                           slab_freq, reuse_class))
    for dst in range(n_tiers):
        if demos[dst]:
            st.merge(engine.migrate_optimistic(demos[dst], dst, bank_freq,
                                               slab_freq, reuse_class))
    return st


def _classify(st: MigrationStats, dst_tier: int, n: int) -> None:
    """Two-tier compat stat buckets: moves into tier 0 count as to_fast,
    everything else as to_slow."""
    if dst_tier == 0:
        st.to_fast += n
    else:
        st.to_slow += n


def _note_retries_exhausted(st: MigrationStats, n: int) -> None:
    """Pages still dirty when the optimistic retry cap hit: dropped this
    pass (a later pass re-plans them) rather than livelocking the loop."""
    if n:
        st.retries_exhausted += n
        obs.get_registry().counter(
            "migrate.retries_exhausted",
            "pages dropped at the optimistic dirty-retry cap").inc(n)


# =============================================================================
# reference engine (numpy per-page loop) — the parity oracle
# =============================================================================

class MigrationEngine:
    def __init__(self, store: TierStore, *, max_retries: int = 3,
                 retry_backoff_s: float = 1e-3):
        self.store = store
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.stats = MigrationStats()

    def _target_color(self, dst_tier: int, bank_freq: np.ndarray | None,
                      slab_freq: np.ndarray | None,
                      reuse_class: int | None = None) -> tuple[int | None, int | None]:
        return target_color(self.store, dst_tier, bank_freq, slab_freq,
                            reuse_class)

    # -- locked path -----------------------------------------------------------
    def migrate_locked(self, pages: Iterable[int], dst_tier: int,
                       bank_freq: np.ndarray | None = None,
                       slab_freq: np.ndarray | None = None,
                       reuse_class: np.ndarray | None = None) -> MigrationStats:
        st = MigrationStats()
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for p in pages:
            src_tier = int(self.store.tier[p])
            rc = None if reuse_class is None else int(reuse_class[p])
            color, mask = self._target_color(dst_tier, bank_freq, slab_freq, rc)
            ok = self.store.move_page(int(p), dst_tier, color, mask)
            if ok:
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                _classify(st, dst_tier, 1)
                if src_tier != dst_tier:       # trivial moves shift no bytes
                    st.note_move(src_tier, dst_tier)
                if bank_freq is not None:
                    # account the move so subsequent picks spread across banks
                    cfg = self.store.alloc[dst_tier].cfg
                    b = cfg.bank_of(int(self.store.slot[p])) % len(bank_freq)
                    bank_freq[b] += 1
        self.stats.merge(st)
        return st

    # -- optimistic (unlocked DMA) path ---------------------------------------
    def migrate_optimistic(
        self, pages: Iterable[int], dst_tier: int,
        bank_freq: np.ndarray | None = None,
        slab_freq: np.ndarray | None = None,
        reuse_class: np.ndarray | None = None,
        concurrent_writer: Callable[[], None] | None = None,
    ) -> MigrationStats:
        """Bulk copy without locking; commit only pages not dirtied mid-copy.

        ``concurrent_writer`` is a test/simulation hook invoked between the
        bulk copy and the version re-check, standing in for writes that land
        while the DMA is in flight.
        """
        st = MigrationStats()
        pending = [int(p) for p in dict.fromkeys(int(p) for p in pages)
                   if int(self.store.tier[p]) != dst_tier
                   and int(self.store.slot[p]) != NO_SLOT]
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for attempt in range(self.max_retries + 1):
            if not pending:
                break
            if attempt > 0:
                st.retries += 1
                # bounded exponential backoff: give the writer that keeps
                # dirtying these pages a chance to move off them
                time.sleep(self.retry_backoff_s * (1 << (attempt - 1)))
            # 1) snapshot versions, 2) unlocked bulk copy to staging
            vsnap = {p: int(self.store.version[p]) for p in pending}
            staged = {p: self.store.read_page(p) for p in pending}
            if concurrent_writer is not None:
                concurrent_writer()
                concurrent_writer = None  # writer fires once
            # 3) dirty check + commit clean pages
            dirty: list[int] = []
            for p in pending:
                if int(self.store.version[p]) != vsnap[p]:
                    dirty.append(p)      # discard: will retry next iteration
                    st.dirty_discards += 1
                    continue
                rc = None if reuse_class is None else int(reuse_class[p])
                new_slot = _alloc_target_slot(self.store, dst_tier, bank_freq,
                                              slab_freq, rc)
                if new_slot is None:
                    continue
                old_tier, old_slot = int(self.store.tier[p]), int(self.store.slot[p])
                if self.store.is_device_tier(dst_tier):
                    self.store.pools[dst_tier].write_one(new_slot, staged[p])
                else:
                    self.store._host_write(dst_tier, new_slot, staged[p])
                self.store.alloc[old_tier].free(old_slot, 0)
                self.store.tier[p] = dst_tier
                self.store.slot[p] = new_slot
                self.store._mark_dirty_one(p)
                self.store.traffic[(old_tier, dst_tier)] += self.store.page_nbytes
                st.migrated += 1
                st.bytes_moved += self.store.page_nbytes
                _classify(st, dst_tier, 1)
                st.note_move(old_tier, dst_tier)
            pending = dirty
        _note_retries_exhausted(st, len(pending))
        self.stats.merge(st)
        return st

    # -- policy-selected execution (Sec. 6.3 observed asymmetry) ---------------
    def execute(self, decision: placement.PlacementDecision,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationStats:
        return execute_decision(self, decision, bank_freq, slab_freq,
                                reuse_class)


# =============================================================================
# batched device-resident engine — the fast path
# =============================================================================

class BatchedMigrationEngine:
    """Executes migration plans as bulk device ops (see module docstring).

    Drop-in for ``MigrationEngine``: same constructor, same
    ``migrate_locked`` / ``migrate_optimistic`` / ``execute`` signatures,
    same resulting tier/slot/pool state.  ``chunk_pages`` bounds the
    staging working set and is the unit of the double-buffered host↔device
    pipeline: while chunk *i* is converting on the host, chunk *i+1*'s
    gather/transfer is already in flight (JAX async dispatch +
    ``copy_to_host_async``).
    """

    def __init__(self, store: TierStore, *, max_retries: int = 3,
                 chunk_pages: int = 64, retry_backoff_s: float = 1e-3):
        self.store = store
        self.max_retries = max_retries
        self.chunk_pages = max(1, int(chunk_pages))
        self.retry_backoff_s = retry_backoff_s
        self.stats = MigrationStats()

    # -- bulk staging ----------------------------------------------------------
    def _stage_device_to_host(self, src_tier: int,
                              slots: np.ndarray) -> np.ndarray:
        """Gather a device tier's slots into contiguous device staging
        (Pallas page_gather), then stream chunks to the host.  Each chunk's
        device→host copy is started asynchronously before the next chunk's
        gather is dispatched, so transfer overlaps packing."""
        slots = np.asarray(slots, np.int64)
        if slots.size == 0:
            return np.zeros((0, *self.store.cfg.page_shape), np.float32)
        bufs = []
        for i in range(0, slots.size, self.chunk_pages):
            chunk = slots[i:i + self.chunk_pages]
            g = self.store.gather_device(src_tier, chunk)
            try:
                g.copy_to_host_async()
            except AttributeError:      # older jax array types
                pass
            bufs.append((g, chunk.size))
        # gathers come back pow2-padded; slice to true counts in numpy
        return np.concatenate([np.asarray(b, np.float32)[:n]
                               for b, n in bufs])

    def _stage_host_to_device(self, dst_tier: int, dst_slots: np.ndarray,
                              values: np.ndarray) -> None:
        """Scatter host pages into their planned device-pool slots (Pallas
        page_scatter, pool donated).  Chunk *i+1*'s host→device transfer is
        issued before chunk *i*'s scatter blocks, double-buffering the
        upload.  Chunks are pow2-padded on the host pre-transfer so ragged
        tails don't mint fresh executables."""
        dst_slots = np.asarray(dst_slots, np.int64)
        k = dst_slots.size
        if k == 0:
            return
        c = self.chunk_pages

        def staged_chunk(i):
            v = values[i:i + c]
            return jax.device_put(_pad_pages(v, _pow2(v.shape[0])))

        nxt = staged_chunk(0)
        for i in range(0, k, c):
            cur = nxt
            if i + c < k:
                nxt = staged_chunk(i + c)
            self.store.scatter_device(dst_tier, dst_slots[i:i + c], cur)

    def _move_group(self, src_tier: int, dst_tier: int,
                    src_slots: np.ndarray, dst_slots: np.ndarray) -> None:
        """Bulk-move one (src, dst) tier pair's data by residency:
        device-addressable pairs (device and pinned-host tiers) stay
        inside the jax runtime — gather + donated scatter, with int8
        quantization fused into the pinned pool's scatter — the
        device<->numpy-host pairs go through chunked staging, and
        host->host is one vectorized numpy copy.

        Injected transient faults retry with exponential backoff up to
        ``max_retries``; past the cap :class:`TransientMigrationFault`
        escapes and the caller drops the group for this pass.  Injection
        fires *before* any data moves, so a failed attempt never leaves
        a half-written group."""
        store = self.store
        src_dev = store.is_addressable_tier(src_tier)
        dst_dev = store.is_addressable_tier(dst_tier)
        inj = get_injector()
        attempts = (self.max_retries + 1) if inj.enabled else 1
        with obs.span("migrate.move_group", src=src_tier, dst=dst_tier,
                      pages=int(len(src_slots))):
            for a in range(attempts):
                try:
                    inj.maybe_migration_fault(src_tier, dst_tier,
                                              int(len(src_slots)))
                except TransientMigrationFault:
                    if a + 1 >= attempts:
                        raise
                    time.sleep(self.retry_backoff_s * (1 << a))
                    continue
                if a:
                    note_recovered("migrate_retry")
                break
            if src_dev and dst_dev:
                staged = store.gather_device(src_tier, src_slots)
                store.scatter_device(dst_tier, dst_slots, staged)
            elif src_dev:
                staged = self._stage_device_to_host(src_tier, src_slots)
                store.host_write_batch(dst_tier, dst_slots, staged)
            elif dst_dev:
                staged = store.host_read_batch(src_tier, src_slots)
                self._stage_host_to_device(dst_tier, dst_slots, staged)
            else:
                staged = store.host_read_batch(src_tier, src_slots)
                store.host_write_batch(dst_tier, dst_slots, staged)

    # -- integrity pre-flight --------------------------------------------------
    def _preflight_verify(self, plan: MigrationPlan,
                          st: MigrationStats) -> MigrationPlan:
        """Verify checksums of the plan's covered-tier source pages before
        any data moves: a corrupt page's slot is quarantined (owner fails
        cleanly), its reserved destination slot freed, and the plan
        shrunk — corrupted bits are never copied forward into a faster
        tier.  No-op while integrity is disarmed."""
        store = self.store
        if not store.integrity.enabled or len(plan) == 0:
            return plan
        keep = np.ones(len(plan), bool)
        for src_t in np.unique(plan.src_tiers):
            t = int(src_t)
            if store.is_device_tier(t):
                continue
            idx = np.nonzero(plan.src_tiers == src_t)[0]
            bad = set(store.integrity.verify(store, t, plan.src_slots[idx]))
            for i in idx:
                if int(plan.src_slots[i]) in bad:
                    keep[i] = False
                    st.failed += 1
                    store.quarantine_slot(t, int(plan.src_slots[i]),
                                          "promotion-preflight")
                    store.alloc[plan.dst_tier].free(int(plan.dst_slots[i]), 0)
        return plan if keep.all() else subset_plan(plan, keep)

    # -- plan execution --------------------------------------------------------
    def execute_plan(self, plan: MigrationPlan) -> MigrationStats:
        """Apply a reserved plan as one bulk move per source tier (locked
        semantics: commit unconditionally).  Groups whose move faults past
        the retry cap are dropped from the commit — their pages stay in
        the source tier, their reservations are returned."""
        st = MigrationStats()
        store = self.store
        if plan.reads_by_tier:
            # optimistic plans stage every *pending* page before the dirty
            # check — charge the reads the synchronous unlocked copy would
            for t, n in plan.reads_by_tier.items():
                store.reads_from[int(t)] += int(n)
        plan = self._preflight_verify(plan, st)
        k = len(plan)
        if k:
            keep = np.ones(k, bool)
            for src_t in np.unique(plan.src_tiers):
                idx = np.nonzero(plan.src_tiers == src_t)[0]
                try:
                    self._move_group(int(src_t), plan.dst_tier,
                                     plan.src_slots[idx], plan.dst_slots[idx])
                except TransientMigrationFault:
                    keep[idx] = False
                    st.failed += idx.size
                    for i in idx:
                        store.alloc[plan.dst_tier].free(
                            int(plan.dst_slots[i]), 0)
                    continue
                if not plan.reads_by_tier:
                    store.reads_from[int(src_t)] += idx.size
                st.note_move(int(src_t), plan.dst_tier, idx.size)
            if not keep.all():
                plan = subset_plan(plan, keep)
                k = len(plan)
            if k:
                store.commit_moves(plan.pages, plan.dst_tier, plan.dst_slots)
        st.migrated = k + plan.trivial
        st.bytes_moved = (k + plan.trivial) * store.page_nbytes
        _classify(st, plan.dst_tier, st.migrated)
        self.stats.merge(st)
        return st

    # -- locked path -----------------------------------------------------------
    def migrate_locked(self, pages: Iterable[int], dst_tier: int,
                       bank_freq: np.ndarray | None = None,
                       slab_freq: np.ndarray | None = None,
                       reuse_class: np.ndarray | None = None) -> MigrationStats:
        plan = plan_locked(self.store, pages, dst_tier, bank_freq, slab_freq,
                           reuse_class)
        return self.execute_plan(plan)

    # -- optimistic (unlocked DMA) path ---------------------------------------
    def migrate_optimistic(
        self, pages: Iterable[int], dst_tier: int,
        bank_freq: np.ndarray | None = None,
        slab_freq: np.ndarray | None = None,
        reuse_class: np.ndarray | None = None,
        concurrent_writer: Callable[[], None] | None = None,
    ) -> MigrationStats:
        """Bulk unlocked copy: stage the whole batch, then commit only pages
        whose version counter did not advance mid-copy; dirtied pages retry
        on the next iteration (destination slots are only reserved after
        the dirty check, so aborted pages reserve nothing)."""
        st = MigrationStats()
        store = self.store
        pending = np.asarray(
            [int(p) for p in dict.fromkeys(int(p) for p in pages)
             if int(store.tier[p]) != dst_tier
             and int(store.slot[p]) != NO_SLOT], np.int64)
        if store.integrity.enabled and pending.size:
            # promotion pre-flight: quarantine corrupt source pages (their
            # slot drops to NO_SLOT) before anything is staged
            for t in np.unique(store.tier[pending]):
                t = int(t)
                if store.is_device_tier(t):
                    continue
                sel = pending[store.tier[pending] == t]
                for s in store.integrity.verify(store, t, store.slot[sel]):
                    st.failed += 1
                    store.quarantine_slot(t, int(s), "promotion-preflight")
            pending = pending[store.slot[pending] != NO_SLOT]
        bank_freq = None if bank_freq is None else np.array(bank_freq)
        for attempt in range(self.max_retries + 1):
            if pending.size == 0:
                break
            if attempt > 0:
                st.retries += 1
                # bounded exponential backoff: let the writer that keeps
                # dirtying these pages move off them before the re-stage
                time.sleep(self.retry_backoff_s * (1 << (attempt - 1)))
            # 1) snapshot versions, 2) unlocked bulk copy to staging —
            # one gather/read per source tier, all before the dirty check.
            # device->device staging never leaves the accelerator (the
            # dirty check only needs the host-side version array); only
            # device->host moves pay the chunked transfer.
            vsnap = store.version[pending].copy()
            src_tiers = store.tier[pending].copy()
            src_slots = store.slot[pending].copy()
            dst_dev = store.is_addressable_tier(dst_tier)
            staged = {}                      # src tier -> group buffer
            local_of = np.zeros(pending.size, np.int64)  # pos within group
            groups = {int(t): np.nonzero(src_tiers == t)[0]
                      for t in np.unique(src_tiers)}
            for src_t, idx in groups.items():
                local_of[idx] = np.arange(idx.size)
                if not store.is_addressable_tier(src_t):
                    staged[src_t] = store.host_read_batch(src_t,
                                                          src_slots[idx])
                elif dst_dev:
                    # both ends device-addressable: staging never leaves
                    # the jax runtime (pinned tiers included)
                    staged[src_t] = store.gather_device(src_t,
                                                        src_slots[idx])
                elif store.is_device_tier(src_t):
                    staged[src_t] = self._stage_device_to_host(
                        src_t, src_slots[idx])
                else:   # pinned src -> numpy-host dst
                    staged[src_t] = np.asarray(
                        store.gather_device(src_t, src_slots[idx]),
                        np.float32)[:idx.size]     # drop the pow2 padding
                store.reads_from[src_t] += idx.size
            if concurrent_writer is not None:
                concurrent_writer()
                concurrent_writer = None  # writer fires once
            # 3) dirty check + bulk-commit clean pages
            dirty_mask = store.version[pending] != vsnap
            st.dirty_discards += int(dirty_mask.sum())
            clean = np.nonzero(~dirty_mask)[0]
            commit_idx: list[int] = []
            dst_slots: list[int] = []
            for i in clean:
                rc = (None if reuse_class is None
                      else int(reuse_class[pending[i]]))
                s = _alloc_target_slot(store, dst_tier, bank_freq, slab_freq,
                                       rc)
                if s is None:
                    continue          # capacity exhausted: drop, like the ref
                commit_idx.append(int(i))
                dst_slots.append(s)
            if commit_idx:
                idx = np.asarray(commit_idx, np.int64)
                slots = np.asarray(dst_slots, np.int64)
                ok = np.ones(idx.size, bool)
                for src_t, gidx in groups.items():
                    m = src_tiers[idx] == src_t
                    sel = idx[m]                         # pending positions
                    if sel.size == 0:
                        continue
                    li = local_of[sel]
                    buf = staged[src_t]
                    if isinstance(buf, np.ndarray):
                        vals = buf[li]
                    else:
                        # device staging: pow2-pad the sub-gather too, so
                        # the commit's shapes stay bucketed (the matching
                        # scatter pads its slot vector the same way)
                        vals = buf[jnp.asarray(_pad_idx_np(li), jnp.int32)]
                    sslots = slots[m]
                    try:
                        self._commit_group_write(src_t, dst_tier, sslots,
                                                 vals, dst_dev)
                    except TransientMigrationFault:
                        # move faulted past the retry cap: return the
                        # reservations, leave the pages where they are
                        # (a later pass re-plans them)
                        ok[m] = False
                        st.failed += int(sel.size)
                        for s_ in sslots:
                            store.alloc[dst_tier].free(int(s_), 0)
                        continue
                    st.note_move(src_t, dst_tier, int(sel.size))
                if not ok.all():
                    idx, slots = idx[ok], slots[ok]
                if idx.size:
                    store.commit_moves(pending[idx], dst_tier, slots)
                    st.migrated += idx.size
                    st.bytes_moved += idx.size * store.page_nbytes
                    _classify(st, dst_tier, idx.size)
            pending = pending[dirty_mask]
        _note_retries_exhausted(st, int(pending.size))
        self.stats.merge(st)
        return st

    def _commit_group_write(self, src_tier: int, dst_tier: int,
                            dst_slots: np.ndarray, vals,
                            dst_dev: bool) -> None:
        """One optimistic-commit group write, behind the same injected
        fault + retry-with-backoff discipline as :meth:`_move_group`
        (injection fires before the write, so a retried attempt never
        double-writes)."""
        inj = get_injector()
        attempts = (self.max_retries + 1) if inj.enabled else 1
        for a in range(attempts):
            try:
                inj.maybe_migration_fault(src_tier, dst_tier,
                                          int(len(dst_slots)))
            except TransientMigrationFault:
                if a + 1 >= attempts:
                    raise
                time.sleep(self.retry_backoff_s * (1 << a))
                continue
            if a:
                note_recovered("migrate_retry")
            break
        store = self.store
        if not dst_dev:
            store.host_write_batch(dst_tier, dst_slots, vals)
        elif store.is_addressable_tier(src_tier):
            store.scatter_device(dst_tier, dst_slots, vals)
        else:
            self._stage_host_to_device(dst_tier, dst_slots, vals)

    # -- policy-selected execution ---------------------------------------------
    def execute(self, decision: placement.PlacementDecision,
                bank_freq: np.ndarray | None = None,
                slab_freq: np.ndarray | None = None,
                reuse_class: np.ndarray | None = None) -> MigrationStats:
        return execute_decision(self, decision, bank_freq, slab_freq,
                                reuse_class)


def make_engine(store: TierStore, kind: str = "batched", **kw):
    """Engine factory: ``"batched"`` (device-resident bulk mover, default)
    or ``"reference"`` (numpy per-page oracle)."""
    if kind == "batched":
        return BatchedMigrationEngine(store, **kw)
    if kind == "reference":
        return MigrationEngine(store, **kw)
    raise ValueError(f"unknown migration engine {kind!r}")
