"""SysMon — inner-runtime memory-pattern profiling (paper Sec. 4.2).

The OS version samples PTE access/dirty bits; a TPU has neither, so SysMon
becomes a *software counter layer fused into the jitted step function*:
the serving engine's multi-token decode dispatch carries the whole
``SysmonState`` pytree through its ``jax.lax.scan`` — each inner decode
step records the exact pages it touched (block-table prefix reads, the
tail-page KV append write) with the ``kernels/hotness_update``
``touch_update`` scatter-add, entirely on device.  Nothing about a step's
access stream ever crosses to the host: the state lives in the scan
carry, is donated back to the next dispatch, and only pass harvesting
(pattern classification + history push, ``end_pass``) runs at pass
boundaries — mirroring the paper's sampling passes (default 100 samplings
per pass) at zero host round-trips per step.

``record`` is jit-safe and traceable, so it composes both ways: called
eagerly (the retained K=1 reference serving path, training loops) or from
inside a scanned/jitted step function (the fused serving hot path).

Algorithm 1 (cache/bank frequency tables) is implemented verbatim: each
recorded access bumps the page's bank and slab counters, keyed by the
page's color bits.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import patterns, predictor


class SysmonState(NamedTuple):
    """Per-page counters for the current sampling pass + persistent history.

    Shapes: [n_pages] unless noted.  Everything is int32/uint8 so the whole
    state stays tiny relative to the pools it monitors (paper: 'a page
    shadow array, each element is a raw byte').
    """

    reads: jnp.ndarray          # int32 — reads this pass
    writes: jnp.ndarray         # int32 — writes this pass
    access_count: jnp.ndarray   # int32 — samplings in which page was touched
    hist: jnp.ndarray           # uint8 — WD history window bitfield
    last_access: jnp.ndarray    # int32 — sampling idx of last touch (-1 = never)
    intv_cnt: jnp.ndarray       # int32 — observed reuse intervals
    intv_sum: jnp.ndarray       # int32 — sum of interval lengths
    intv_sqsum: jnp.ndarray     # int32 — sum of squared interval lengths
    bank_freq: jnp.ndarray      # int32 [n_banks] — Algorithm 1
    slab_freq: jnp.ndarray      # int32 [n_slabs] — Algorithm 1
    page_bank: jnp.ndarray      # int32 — page -> bank (device shard) map
    page_slab: jnp.ndarray      # int32 — page -> VMEM/cache slab class map
    sample_idx: jnp.ndarray     # int32 scalar — sampling counter within pass

    @property
    def n_pages(self) -> int:
        return self.reads.shape[0]


class PassSummary(NamedTuple):
    """Classification produced at a pass boundary (inputs to placement)."""

    wd_code: jnp.ndarray      # int8 {COLD, RD, WD}
    hot: jnp.ndarray          # bool
    hotness: jnp.ndarray      # float32 ranking key
    reuse_class: jnp.ndarray  # int8 {RARELY, FREQ, THRASHING}
    future: jnp.ndarray       # int8 {UN_WD, WD_FREQ_L, WD_FREQ_H}
    reads: jnp.ndarray        # int32 raw counters (for cost model / figs)
    writes: jnp.ndarray
    bank_freq: jnp.ndarray
    slab_freq: jnp.ndarray


def init(n_pages: int, n_banks: int, n_slabs: int,
         page_bank: jnp.ndarray | None = None,
         page_slab: jnp.ndarray | None = None) -> SysmonState:
    if page_bank is None:
        page_bank = jnp.arange(n_pages, dtype=jnp.int32) % n_banks
    if page_slab is None:
        page_slab = (jnp.arange(n_pages, dtype=jnp.int32) // max(n_banks, 1)) % n_slabs
    z = jnp.zeros(n_pages, dtype=jnp.int32)
    return SysmonState(
        reads=z, writes=z, access_count=z,
        hist=jnp.zeros(n_pages, dtype=jnp.uint8),
        last_access=jnp.full((n_pages,), -1, dtype=jnp.int32),
        intv_cnt=z, intv_sum=z, intv_sqsum=z,
        bank_freq=jnp.zeros(n_banks, dtype=jnp.int32),
        slab_freq=jnp.zeros(n_slabs, dtype=jnp.int32),
        page_bank=page_bank.astype(jnp.int32),
        page_slab=page_slab.astype(jnp.int32),
        sample_idx=jnp.int32(0),
    )


def record(state: SysmonState, page_ids: jnp.ndarray, *,
           is_write: jnp.ndarray | bool = False,
           valid: jnp.ndarray | None = None) -> SysmonState:
    """Record one sampling's worth of page touches (jit-safe, ragged via mask).

    page_ids: int32 [k] page indices touched this sampling (may repeat).
    is_write: bool or bool [k] — write vs read.
    valid:    optional bool [k] mask for padded id lists.
    """
    # the ragged id list becomes dense per-page increment vectors in one
    # fused scatter-add sweep (kernels/hotness_update.touch_update:
    # Pallas on TPU, XLA scatter elsewhere — bit-exact either way).
    # Imported lazily: the kernel package imports core.patterns/predictor,
    # so a module-level import here would be circular under a
    # kernels-first import order.
    from repro.kernels.hotness_update import touch_update
    d_reads, d_writes, touched_i = touch_update(
        state.n_pages, page_ids, is_write, valid)
    return _apply_sampling(state, d_reads, d_writes, touched_i)


def _apply_sampling(state: SysmonState, d_reads: jnp.ndarray,
                    d_writes: jnp.ndarray, touched_i: jnp.ndarray
                    ) -> SysmonState:
    """Fold one sampling's dense per-page increments into the state."""
    touched = touched_i > 0

    reads = state.reads + d_reads
    writes = state.writes + d_writes

    # access_count: count *samplings* where the page was touched (paper's
    # access_bit semantics) — touched dedupes within the sampling.
    access_count = state.access_count + touched_i

    # reuse intervals (paper Sec. 3.3): gap in samplings since last touch.
    now = state.sample_idx
    seen_before = state.last_access >= 0
    gap = now - state.last_access
    upd = touched & seen_before
    intv_cnt = state.intv_cnt + upd.astype(jnp.int32)
    intv_sum = state.intv_sum + jnp.where(upd, gap, 0)
    intv_sqsum = state.intv_sqsum + jnp.where(upd, gap * gap, 0)
    last_access = jnp.where(touched, now, state.last_access)

    # Algorithm 1: bump bank/slab frequency by page touch — the dense
    # per-page event counts fold through the page->color maps.
    events = d_reads + d_writes
    bank_freq = state.bank_freq.at[state.page_bank].add(events)
    slab_freq = state.slab_freq.at[state.page_slab].add(events)

    return state._replace(
        reads=reads, writes=writes, access_count=access_count,
        last_access=last_access, intv_cnt=intv_cnt, intv_sum=intv_sum,
        intv_sqsum=intv_sqsum, bank_freq=bank_freq, slab_freq=slab_freq,
        sample_idx=state.sample_idx + 1,
    )


def record_dense(state: SysmonState, d_reads: jnp.ndarray,
                 d_writes: jnp.ndarray) -> SysmonState:
    """Record a *bulk sequential* access burst as ONE sampling (jit-safe).

    ``d_reads``/``d_writes`` are dense int32 [n_pages] event totals — e.g.
    every page a prefill dispatch streamed through, with exact per-page
    read/write counts.  Unlike replaying the burst as K per-token
    ``record`` samplings, the whole burst lands as a single sampling: the
    raw ``reads``/``writes``/``bank_freq``/``slab_freq`` totals match the
    per-token replay exactly (they are sums either way), but
    ``access_count`` advances by at most 1 and ``sample_idx`` by exactly
    1 — so the *cadence* counters see one streaming touch, not K fake
    decode touches, and the next classification pass ranks these pages as
    sequential/cold rather than hot (paper Sec. 4.2: streaming pages must
    not be promoted on raw touch volume).
    """
    touched_i = ((d_reads + d_writes) > 0).astype(jnp.int32)
    return _apply_sampling(state, d_reads.astype(jnp.int32),
                           d_writes.astype(jnp.int32), touched_i)


@jax.jit
def end_pass(state: SysmonState) -> tuple[SysmonState, PassSummary]:
    """Close a sampling pass: classify, push WD history, reset counters."""
    wd_code = patterns.classify_wd(state.reads, state.writes)
    wd_bit = (wd_code == patterns.WD).astype(jnp.uint8)
    hist = predictor.push_history(state.hist, wd_bit)
    future = predictor.predict_future(hist)
    hot = patterns.classify_hot(state.access_count, state.sample_idx)
    hotness = patterns.hotness_score(state.access_count, state.writes)
    reuse = patterns.classify_reuse(
        state.intv_cnt, state.intv_sum, state.intv_sqsum, state.sample_idx
    )
    summary = PassSummary(
        wd_code=wd_code, hot=hot, hotness=hotness, reuse_class=reuse,
        future=future, reads=state.reads, writes=state.writes,
        bank_freq=state.bank_freq, slab_freq=state.slab_freq,
    )
    z = jnp.zeros_like(state.reads)
    new_state = state._replace(
        reads=z, writes=z, access_count=z,
        hist=hist,
        last_access=jnp.full_like(state.last_access, -1),
        intv_cnt=z, intv_sum=z, intv_sqsum=z,
        bank_freq=jnp.zeros_like(state.bank_freq),
        slab_freq=jnp.zeros_like(state.slab_freq),
        sample_idx=jnp.int32(0),
    )
    return new_state, summary


def summary_metrics(summary: PassSummary) -> dict[str, int]:
    """Pass classification mix as plain-int gauges (for the obs metrics
    registry): page counts per WD class plus the hot set size."""
    import numpy as np
    wd = np.asarray(summary.wd_code)
    return {
        "hot_pages": int(np.asarray(summary.hot).sum()),
        "wd_pages": int((wd == patterns.WD).sum()),
        "rd_pages": int((wd == patterns.RD).sum()),
        "cold_pages": int((wd == patterns.COLD).sum()),
    }


def remap(state: SysmonState, page_ids: jnp.ndarray,
          new_bank: jnp.ndarray, new_slab: jnp.ndarray) -> SysmonState:
    """Update page->bank/slab maps after the migration engine moves pages."""
    return state._replace(
        page_bank=state.page_bank.at[page_ids].set(new_bank.astype(jnp.int32)),
        page_slab=state.page_slab.at[page_ids].set(new_slab.astype(jnp.int32)),
    )
