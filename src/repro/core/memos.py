"""MemosManager — the periodic full-hierarchy management loop (Fig. 10).

Ties SysMon -> predictor -> placement -> migration together:

  every ``interval`` steps (paper: 20 s wall clock):
    1. close the SysMon sampling pass (WD counts over Window_Len history)
    2. predict each page's future state (+ Reverse check over K_Len)
    3. mark will-be-migrated pages, rank the hotness list (WD_FREQ_H first)
    4. migrate: locked slow->fast for hot/WD, optimistic fast->slow bulk;
       destination slots via Algorithm 2 (coldest bank x coldest slab)
    5. bandwidth balancing: spill RD (then coolest WD) pages to the slow
       channel while the fast channel is saturated
    6. NVM telemetry (Sec. 7.1): close the energy/lifetime accounting
       window; when the projected lifetime from the live wear counters
       drops below ``lifetime_horizon_years``, the *next* pass plans with
       a wear penalty — WD pages are pinned/promoted to the fast tier and
       excluded from bandwidth spills until the projection recovers.

Overhead controls from Sec. 7.4 are exposed: sampling subset fraction and
an adaptively growing interval once patterns stabilize.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import sysmon as sysmon_mod
from .migration import MigrationStats, make_engine
from .placement import FAST, SLOW, BandwidthBalancer, plan
from .tiers import TierStore


@dataclass
class MemosConfig:
    interval: int = 16            # steps between memos passes
    max_migrations: int | None = 256
    fast_bw_bound: float = 0.9    # fraction of fast-channel peak
    adaptive_interval: bool = True
    interval_growth: float = 1.5  # grow when patterns are stable (Sec. 7.4)
    interval_max: int = 256
    stability_threshold: float = 0.02  # fraction of pages changing target
    engine: str = "batched"       # "batched" (device bulk) | "reference"
    # NVM wear feedback (Sec. 7.1): act when the projected lifetime from
    # live wear counters drops below the horizon; None disables feedback.
    lifetime_horizon_years: float | None = None
    wear_penalty: float = 4.0     # HL-ranking boost for WD pages under pressure
    pass_window_s: float = 1.0    # notional wall-clock span of one pass


@dataclass
class MemosReport:
    step: int
    migrations: MigrationStats
    n_marked: int
    fast_pages: int
    slow_pages: int
    bank_imbalance: float
    spilled: int = 0
    nvm: object | None = None     # NvmReport for this pass (wear tracked)
    wear_pressure: bool = False   # wear penalty applied to this pass's plan


class MemosManager:
    def __init__(self, store: TierStore, cfg: MemosConfig | None = None):
        self.store = store
        self.cfg = cfg or MemosConfig()
        self.engine = make_engine(store, self.cfg.engine)
        self.balancer = BandwidthBalancer(self.cfg.fast_bw_bound)
        self.meter = None
        if store.wear is not None:
            # lazy import: repro.nvm depends on core.costmodel
            from repro.nvm.energy import EnergyMeter
            self.meter = EnergyMeter(store, window_s=self.cfg.pass_window_s)
        self.interval = self.cfg.interval
        self._last_target: np.ndarray | None = None
        self._steps_since = 0
        self._last_pass_step = 0
        self.reports: list[MemosReport] = []
        self.step_count = 0

    def maybe_step(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0, steps: int = 1):
        """Call once per training/serving step — or once per fused decode
        dispatch with ``steps`` = the number of inner steps it covered, so
        the interval stays token-granular across dispatch sizes; fires the
        memos loop on the configured interval.  Returns (new sysmon state,
        report|None)."""
        self.step_count += steps
        self._steps_since += steps
        if self._steps_since < self.interval:
            return sm_state, None
        # a pass can only fire at a call (dispatch) boundary, so keep the
        # token-granular cadence by carrying the remainder modulo the
        # interval instead of discarding it — overshoot from one large
        # dispatch does not push the next pass a full interval out
        self._steps_since %= self.interval
        return self.run_pass(sm_state, fast_bw_util)

    def run_pass(self, sm_state: sysmon_mod.SysmonState,
                 fast_bw_util: float = 0.0):
        # 1-2) close the pass; classification + prediction happen on device
        sm_state, summary = sysmon_mod.end_pass(sm_state)

        # 3) plan: mark will-be-migrated, rank HL; under NVM wear pressure
        # (projected lifetime below the horizon) WD pages get the penalty
        # term: pinned to fast, ranked first, excluded from spills
        wear_pressure = False
        if self.meter is not None and self.cfg.lifetime_horizon_years:
            wear_pressure = (self.meter.project_lifetime()
                             < self.cfg.lifetime_horizon_years)
        penalty = self.cfg.wear_penalty if wear_pressure else 0.0
        current = self.store.tier.copy()
        decision = plan(summary, current, max_migrations=self.cfg.max_migrations,
                        wear_penalty=penalty)

        bank_freq = np.asarray(summary.bank_freq)
        slab_freq = np.asarray(summary.slab_freq)
        reuse = np.asarray(summary.reuse_class)

        # 4) migrate
        stats = self.engine.execute(decision, bank_freq, slab_freq, reuse)

        # 5) bandwidth balancing (spill while fast channel saturated)
        spilled = 0
        if self.balancer.update(fast_bw_util):
            cands = self.balancer.spill_candidates(
                np.asarray(summary.wd_code), np.asarray(summary.hotness),
                self.store.tier, n=self.cfg.max_migrations or 64,
                exclude_wd=wear_pressure)
            st = self.engine.migrate_optimistic(cands, SLOW, bank_freq,
                                                slab_freq, reuse)
            spilled = st.migrated

        # adaptive interval (Sec. 7.4): grow when the plan barely changes
        tgt = np.asarray(decision.target_tier)
        if self.cfg.adaptive_interval and self._last_target is not None:
            changed = float(np.mean(tgt != self._last_target))
            if changed < self.cfg.stability_threshold:
                self.interval = min(int(self.interval * self.cfg.interval_growth),
                                    self.cfg.interval_max)
            else:
                self.interval = self.cfg.interval
        self._last_target = tgt

        # 6) close the NVM telemetry window (energy + lifetime projection);
        # scale the window by the steps this pass actually covered so
        # adaptive interval growth doesn't inflate the apparent wear rate
        nvm = None
        if self.meter is not None:
            steps = self.step_count - self._last_pass_step
            window = (self.cfg.pass_window_s * steps / self.cfg.interval
                      if steps > 0 else self.cfg.pass_window_s)
            nvm = self.meter.end_pass(window_s=window)
        self._last_pass_step = self.step_count

        report = MemosReport(
            step=self.step_count,
            migrations=stats,
            n_marked=int(decision.migrate.sum()),
            fast_pages=int((self.store.tier == FAST).sum()),
            slow_pages=int((self.store.tier == SLOW).sum()),
            bank_imbalance=float(np.std(bank_freq)),
            spilled=spilled,
            nvm=nvm,
            wear_pressure=wear_pressure,
        )
        self.reports.append(report)
        return sm_state, report
