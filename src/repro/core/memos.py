"""MemosManager — the periodic full-hierarchy management loop (Fig. 10).

Ties SysMon -> predictor -> placement -> migration together:

  every ``interval`` steps (paper: 20 s wall clock):
    1. close the SysMon sampling pass (WD counts over Window_Len history)
    2. predict each page's future state (+ Reverse check over K_Len)
    3. mark will-be-migrated pages, rank the hotness list (WD_FREQ_H first)
    4. migrate: locked slow->fast for hot/WD, optimistic fast->slow bulk;
       destination slots via Algorithm 2 (coldest bank x coldest slab)
    5. bandwidth balancing: spill RD (then coolest WD) pages to the slow
       channel while the fast channel is saturated

Overhead controls from Sec. 7.4 are exposed: sampling subset fraction and
an adaptively growing interval once patterns stabilize.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import sysmon as sysmon_mod
from .migration import MigrationStats, make_engine
from .placement import FAST, SLOW, BandwidthBalancer, plan
from .tiers import TierStore


@dataclass
class MemosConfig:
    interval: int = 16            # steps between memos passes
    max_migrations: int | None = 256
    fast_bw_bound: float = 0.9    # fraction of fast-channel peak
    adaptive_interval: bool = True
    interval_growth: float = 1.5  # grow when patterns are stable (Sec. 7.4)
    interval_max: int = 256
    stability_threshold: float = 0.02  # fraction of pages changing target
    engine: str = "batched"       # "batched" (device bulk) | "reference"


@dataclass
class MemosReport:
    step: int
    migrations: MigrationStats
    n_marked: int
    fast_pages: int
    slow_pages: int
    bank_imbalance: float
    spilled: int = 0


class MemosManager:
    def __init__(self, store: TierStore, cfg: MemosConfig | None = None):
        self.store = store
        self.cfg = cfg or MemosConfig()
        self.engine = make_engine(store, self.cfg.engine)
        self.balancer = BandwidthBalancer(self.cfg.fast_bw_bound)
        self.interval = self.cfg.interval
        self._last_target: np.ndarray | None = None
        self._steps_since = 0
        self.reports: list[MemosReport] = []
        self.step_count = 0

    def maybe_step(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0):
        """Call once per training/serving step; fires the memos loop on the
        configured interval.  Returns (new sysmon state, report|None)."""
        self.step_count += 1
        self._steps_since += 1
        if self._steps_since < self.interval:
            return sm_state, None
        self._steps_since = 0
        return self.run_pass(sm_state, fast_bw_util)

    def run_pass(self, sm_state: sysmon_mod.SysmonState,
                 fast_bw_util: float = 0.0):
        # 1-2) close the pass; classification + prediction happen on device
        sm_state, summary = sysmon_mod.end_pass(sm_state)

        # 3) plan: mark will-be-migrated, rank HL
        current = self.store.tier.copy()
        decision = plan(summary, current, max_migrations=self.cfg.max_migrations)

        bank_freq = np.asarray(summary.bank_freq)
        slab_freq = np.asarray(summary.slab_freq)
        reuse = np.asarray(summary.reuse_class)

        # 4) migrate
        stats = self.engine.execute(decision, bank_freq, slab_freq, reuse)

        # 5) bandwidth balancing (spill while fast channel saturated)
        spilled = 0
        if self.balancer.update(fast_bw_util):
            cands = self.balancer.spill_candidates(
                np.asarray(summary.wd_code), np.asarray(summary.hotness),
                self.store.tier, n=self.cfg.max_migrations or 64)
            st = self.engine.migrate_optimistic(cands, SLOW, bank_freq,
                                                slab_freq, reuse)
            spilled = st.migrated

        # adaptive interval (Sec. 7.4): grow when the plan barely changes
        tgt = np.asarray(decision.target_tier)
        if self.cfg.adaptive_interval and self._last_target is not None:
            changed = float(np.mean(tgt != self._last_target))
            if changed < self.cfg.stability_threshold:
                self.interval = min(int(self.interval * self.cfg.interval_growth),
                                    self.cfg.interval_max)
            else:
                self.interval = self.cfg.interval
        self._last_target = tgt

        report = MemosReport(
            step=self.step_count,
            migrations=stats,
            n_marked=int(decision.migrate.sum()),
            fast_pages=int((self.store.tier == FAST).sum()),
            slow_pages=int((self.store.tier == SLOW).sum()),
            bank_imbalance=float(np.std(bank_freq)),
            spilled=spilled,
        )
        self.reports.append(report)
        return sm_state, report
