"""MemosManager — the periodic full-hierarchy management loop (Fig. 10),
generic over the tiers of a :class:`~repro.core.hierarchy.MemoryHierarchy`.

Ties SysMon -> predictor -> placement -> migration together:

  every ``interval`` steps (paper: 20 s wall clock):
    1. close the SysMon sampling pass (WD counts over Window_Len history)
    2. predict each page's future state (+ Reverse check over K_Len)
    3. mark will-be-migrated pages, rank the hotness list (WD_FREQ_H first)
    4. migrate: locked promotions toward tier 0 for hot/WD pages,
       optimistic bulk demotions toward the slower tiers; destination
       slots via Algorithm 2 (coldest bank x coldest slab) in the
       destination tier's own allocator
    5. bandwidth balancing: spill RD (then coolest WD) pages off the
       fast channel while it is saturated, into the backing tier with
       the most bandwidth headroom
    6. NVM telemetry (Sec. 7.1): close the energy/lifetime accounting
       window of **every wear-tracked tier**; when any tier's projected
       lifetime from the live wear counters drops below
       ``lifetime_horizon_years``, the *next* pass plans with a wear
       penalty — WD pages are pinned/promoted to the fast tier, ranked
       first in the HL, and excluded from bandwidth spills.

Overhead controls from Sec. 7.4 are exposed: sampling subset fraction and
an adaptively growing interval once patterns stabilize.

Asynchronous pipeline (``MemosConfig.async_plan``)
--------------------------------------------------
The paper's monitor and migration engine run *concurrently* with the
application; the synchronous ``run_pass`` instead blocks the serving loop
for the whole pass.  With ``async_plan`` the pass splits into a
snapshot -> plan -> commit pipeline:

  * **snapshot** (dispatch boundary, cheap): close the SysMon pass, pull
    the summary, snapshot the page table / version counters / cloned
    allocators (:class:`~repro.core.migration.StoreView`) and the wear
    projection;
  * **plan** (worker thread, overlapped with the next jitted K-token
    dispatch): pattern classification + placement + Algorithm-2 slot
    targeting simulated on the cloned allocators + spill candidate
    selection — pure numpy against the immutable snapshot;
  * **commit** (next dispatch boundary): **page-granular**.  The
    snapshot opened a dirty-page epoch on the store (every version bump,
    tier change, or slot change mid-dispatch is recorded incrementally),
    so validation is a set lookup per planned page — O(dirtied pages)
    overall, not O(plan).  Reservations land through
    :func:`~repro.core.migration.commit_reservations`: a destination
    tier with no interleaved allocator call adopts the plan's clone
    wholesale (O(1), slots land exactly as simulated); otherwise the
    recorded Algorithm-2 calls replay against the live allocator, each
    reservation patched to the slot actually obtained — the slot a
    synchronous pass planning at this boundary would take.  The *clean
    subset* of every plan then executes as bulk moves — only pages
    dirtied mid-plan (or out of destination capacity at commit time)
    degrade: their reservations are released and they simply wait for
    the next pass, which sees them in its fresh snapshot.  A conflict no
    longer discards the whole plan or forces a synchronous re-plan;
    ``pages_committed`` / ``pages_degraded`` count the split per page.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field

import numpy as np

from repro import obs
from repro.faults.degradation import (RUNG_OFF, RUNG_OVERLAP, RUNG_SYNC,
                                      DegradationLadder)
from repro.faults.injector import get_injector, note_recovered

from . import sysmon as sysmon_mod
from .migration import (MigrationStats, StoreView, commit_reservations,
                        make_engine, plan_decision, plan_optimistic,
                        subset_plan)
from .placement import BandwidthBalancer, plan
from .tiers import NO_SLOT, TierStore


@dataclass
class MemosConfig:
    interval: int = 16            # steps between memos passes
    max_migrations: int | None = 256
    fast_bw_bound: float = 0.9    # fraction of fast-channel peak
    adaptive_interval: bool = True
    interval_growth: float = 1.5  # grow when patterns are stable (Sec. 7.4)
    interval_max: int = 256
    stability_threshold: float = 0.02  # fraction of pages changing target
    engine: str = "batched"       # "batched" (device bulk) | "reference"
    # NVM wear feedback (Sec. 7.1): act when any wear-tracked tier's
    # projected lifetime drops below the horizon; None disables feedback.
    lifetime_horizon_years: float | None = None
    wear_penalty: float = 4.0     # HL-ranking boost for WD pages under pressure
    pass_window_s: float = 1.0    # notional wall-clock span of one pass
    # overlap the plan phase with the next dispatch on a worker thread
    # (snapshot -> plan -> commit; see module docstring)
    async_plan: bool = False
    # -- fault tolerance (repro.faults) -----------------------------------
    # watchdog bound on joining the worker-thread plan at commit time;
    # a timeout (or any worker exception) falls back to a synchronous
    # pass against live state and demotes the degradation ladder.
    # None = wait forever (no watchdog).
    plan_timeout_s: float | None = 30.0
    # consecutive healthy passes before the circuit breaker re-promotes
    # one ladder rung (overlap -> sync -> memos-off and back)
    breaker_recovery_passes: int = 3
    # per-pass budget of recorded page checksums re-verified by the
    # background scrub (0 disables scrubbing)
    scrub_pages: int = 16
    # -- power cap (repro.qos.power) --------------------------------------
    # budget on the summed per-wear-tier ``NvmReport.dynamic_power_mw``;
    # while over it the governor raises a throttle level that shrinks
    # serving-engine batch admission and plans the next pass under power
    # pressure (WD pages pinned fast, energy-ranked intermediate fill,
    # WD excluded from spills).  None disables the governor entirely.
    power_cap_mw: float | None = None
    # consecutive under-budget passes before one throttle level releases
    power_recover_passes: int = 2


@dataclass
class MemosReport:
    step: int
    migrations: MigrationStats
    n_marked: int
    fast_pages: int               # pages resident in tier 0
    slow_pages: int               # pages resident in the deepest tier
    bank_imbalance: float
    spilled: int = 0
    tier_pages: list[int] = field(default_factory=list)  # per-tier residency
    nvm: object | None = None     # deepest wear-tracked tier's NvmReport
    nvm_by_tier: dict = field(default_factory=dict)  # tier -> NvmReport
    wear_pressure: bool = False   # wear penalty applied to this pass's plan
    power_pressure: bool = False  # pass planned under the power governor
    power_throttle: int = 0       # governor throttle level after this pass
    power_mw: float = 0.0         # summed per-wear-tier dynamic power
    committed_async: bool = False  # pass went through the overlapped commit
    plan_conflict: bool = False    # some planned pages were stale (degraded)
    pages_committed: int = 0      # planned pages committed by this pass
    pages_degraded: int = 0       # planned pages left for the next pass
    pages_dropped: int = 0        # planned pages freed mid-plan (not conflicts)
    plan_ms: float = 0.0          # wall time of the (worker-thread) plan phase
    # fraction of the plan phase hidden under the overlapped dispatch
    # (1.0 = fully hidden, 0.0 = the commit waited for the whole plan);
    # None for synchronous passes
    overlap_efficiency: float | None = None
    # non-None when this pass recovered from a plan-phase fault: the
    # failure class ("timeout", "InjectedPlanFault", ...) whose watchdog
    # fallback produced this (synchronous) result
    fault_fallback: str | None = None

    def to_dict(self) -> dict:
        """JSON-ready nested dict: MigrationStats and every per-tier
        NvmReport flatten through their own ``to_dict``; round-trips
        losslessly through :meth:`from_dict` (the serialization contract
        report.py and the benchmark scripts consume instead of plucking
        fields ad hoc)."""
        return {
            "step": self.step,
            "migrations": self.migrations.to_dict(),
            "n_marked": self.n_marked,
            "fast_pages": self.fast_pages,
            "slow_pages": self.slow_pages,
            "bank_imbalance": self.bank_imbalance,
            "spilled": self.spilled,
            "tier_pages": list(self.tier_pages),
            "nvm": self.nvm.to_dict() if self.nvm is not None else None,
            "nvm_by_tier": {str(t): r.to_dict()
                            for t, r in self.nvm_by_tier.items()},
            "wear_pressure": self.wear_pressure,
            "power_pressure": self.power_pressure,
            "power_throttle": self.power_throttle,
            "power_mw": self.power_mw,
            "committed_async": self.committed_async,
            "plan_conflict": self.plan_conflict,
            "pages_committed": self.pages_committed,
            "pages_degraded": self.pages_degraded,
            "pages_dropped": self.pages_dropped,
            "plan_ms": self.plan_ms,
            "overlap_efficiency": self.overlap_efficiency,
            "fault_fallback": self.fault_fallback,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MemosReport":
        from repro.nvm.energy import NvmReport
        nvm_by_tier = {int(t): NvmReport(**r)
                       for t, r in (d.get("nvm_by_tier") or {}).items()}
        nvm = NvmReport(**d["nvm"]) if d.get("nvm") is not None else None
        # the deepest tier's report aliases the by-tier entry, as built
        if nvm is not None:
            for t, r in nvm_by_tier.items():
                if r == nvm:
                    nvm = r
                    break
        return cls(
            step=d["step"],
            migrations=MigrationStats.from_dict(d["migrations"]),
            n_marked=d["n_marked"], fast_pages=d["fast_pages"],
            slow_pages=d["slow_pages"],
            bank_imbalance=d["bank_imbalance"], spilled=d["spilled"],
            tier_pages=list(d["tier_pages"]), nvm=nvm,
            nvm_by_tier=nvm_by_tier, wear_pressure=d["wear_pressure"],
            power_pressure=d.get("power_pressure", False),
            power_throttle=d.get("power_throttle", 0),
            power_mw=d.get("power_mw", 0.0),
            committed_async=d["committed_async"],
            plan_conflict=d["plan_conflict"],
            pages_committed=d["pages_committed"],
            pages_degraded=d["pages_degraded"],
            pages_dropped=d.get("pages_dropped", 0),
            plan_ms=d.get("plan_ms", 0.0),
            overlap_efficiency=d.get("overlap_efficiency"),
            fault_fallback=d.get("fault_fallback"),
        )

    def flat_metrics(self) -> dict:
        """Flattened scalar leaves — the shape the metrics registry and
        ``report.py`` consume (`tier{i}_pages` per tier, migration stats
        inlined, per-wear-tier energy under ``nvm.t{t}.``)."""
        m = self.migrations
        out = {
            "step": self.step, "migrated": m.migrated,
            "to_fast": m.to_fast, "to_slow": m.to_slow,
            "bytes_moved": m.bytes_moved,
            "dirty_discards": m.dirty_discards, "retries": m.retries,
            "n_marked": self.n_marked, "spilled": self.spilled,
            "bank_imbalance": self.bank_imbalance,
            "wear_pressure": int(self.wear_pressure),
            "power_pressure": int(self.power_pressure),
            "power_throttle": self.power_throttle,
            "power_mw": self.power_mw,
            "committed_async": int(self.committed_async),
            "plan_conflict": int(self.plan_conflict),
            "pages_committed": self.pages_committed,
            "pages_degraded": self.pages_degraded,
            "pages_dropped": self.pages_dropped,
            "plan_ms": self.plan_ms,
            "fault_fallback": int(self.fault_fallback is not None),
        }
        if self.overlap_efficiency is not None:
            out["overlap_efficiency"] = self.overlap_efficiency
        for t, n in enumerate(self.tier_pages):
            out[f"tier{t}_pages"] = n
        for t, r in self.nvm_by_tier.items():
            d = r.to_dict()
            for k in ("slow_writes", "wear_max", "read_energy_mj",
                      "write_energy_mj", "dynamic_power_mw",
                      "lifetime_years_actual"):
                out[f"nvm.t{t}.{k}"] = d[k]
        return out


def aggregate_reports(reports: list["MemosReport"]) -> dict:
    """Sum the countable leaves of a report list (migrated, spilled,
    pages committed/degraded, bytes moved) and carry the last pass's
    state leaves — the shared aggregation benchmarks use instead of
    plucking ``r.migrations.<field>`` by hand."""
    agg = {"passes": len(reports), "migrated": 0, "to_fast": 0,
           "to_slow": 0, "bytes_moved": 0, "spilled": 0,
           "pages_committed": 0, "pages_degraded": 0, "pages_dropped": 0}
    effs = []
    for r in reports:
        f = r.flat_metrics()
        for k in ("migrated", "to_fast", "to_slow", "bytes_moved",
                  "spilled", "pages_committed", "pages_degraded",
                  "pages_dropped"):
            agg[k] += f[k]
        if r.overlap_efficiency is not None:
            effs.append(r.overlap_efficiency)
    if effs:
        agg["overlap_efficiency_mean"] = float(np.mean(effs))
    if reports:
        last = reports[-1]
        agg["tier_pages"] = list(last.tier_pages)
        agg["nvm_last"] = (last.to_dict()["nvm"]
                           if last.nvm is not None else None)
    return agg


@dataclass
class _PlanTicket:
    """One in-flight asynchronous pass: the immutable snapshot plus the
    worker future that resolves to (decision, plans, spill_plan)."""
    step: int
    summary: object               # PassSummary with numpy leaves
    view: StoreView
    wear_pressure: bool
    spilling: bool
    spill_dst: int
    power_pressure: bool = False
    page_weight: np.ndarray | None = None   # snapshot of tenant weights
    future: Future | None = None
    # worker-thread plan phase wall-clock bounds (monotonic ns), recorded
    # unconditionally so the overlap-efficiency metric works without
    # tracing enabled
    plan_t0_ns: int = 0
    plan_t1_ns: int = 0


class MemosManager:
    def __init__(self, store: TierStore, cfg: MemosConfig | None = None):
        self.store = store
        self.cfg = cfg or MemosConfig()
        self.engine = make_engine(store, self.cfg.engine)
        self.balancer = BandwidthBalancer(self.cfg.fast_bw_bound)
        # one energy meter per wear-tracked tier (lazy import: repro.nvm
        # depends on core.costmodel)
        self.meters: dict[int, object] = {}
        for t in store.hierarchy.wear_tiers():
            from repro.nvm.energy import EnergyMeter
            self.meters[t] = EnergyMeter(store, tier=t,
                                         window_s=self.cfg.pass_window_s)
        # power-cap governor (repro.qos): fed the summed per-wear-tier
        # dynamic power at the end of every pass; its throttle level
        # shrinks serving-engine admission and puts the next plan under
        # power pressure
        self.governor = None
        if self.cfg.power_cap_mw is not None:
            from repro.qos.power import PowerGovernor
            self.governor = PowerGovernor(
                budget_mw=self.cfg.power_cap_mw,
                recover_passes=self.cfg.power_recover_passes)
        # per-page tenant utility weights (lazy: stays None — and the
        # planner bit-identical to pre-QoS — until a weighted tenant's
        # pages appear)
        self._page_weight: np.ndarray | None = None
        self.interval = self.cfg.interval
        self._last_target: np.ndarray | None = None
        self._steps_since = 0
        self._last_pass_step = 0
        self.reports: list[MemosReport] = []
        self.step_count = 0
        # async pipeline state
        if self.cfg.async_plan and not hasattr(self.engine, "execute_plan"):
            raise ValueError("async_plan requires a plan-executing engine "
                             "(MemosConfig.engine='batched')")
        self._executor: ThreadPoolExecutor | None = None
        self._ticket: _PlanTicket | None = None
        # graceful degradation: overlap -> sync -> memos-off, circuit
        # breaker re-promotes after breaker_recovery_passes healthy passes
        self.ladder = DegradationLadder(
            top=RUNG_OVERLAP if self.cfg.async_plan else RUNG_SYNC,
            recovery_passes=self.cfg.breaker_recovery_passes)
        # page-granular commit accounting: a partially-committed pass
        # contributes to *both* counters, once per page — never
        # double-counted as a whole-pass commit and a whole-pass conflict
        self.pages_committed = 0      # planned pages committed async
        self.pages_degraded = 0       # planned pages dirtied mid-plan
        self.pages_dropped = 0        # planned pages freed mid-plan
        # overlap-efficiency accounting: how much of the worker-thread
        # plan time was hidden under the dispatch that ran between
        # snapshot and commit (the number the async pipeline is buying)
        self.plan_ns_total = 0
        self.plan_hidden_ns_total = 0
        # test hook: called with (manager, decision, plans) between the
        # worker join and validation — simulates writes landing mid-plan
        self._mid_plan_hook = None

    @property
    def meter(self):
        """Deepest wear-tracked tier's meter (two-tier compat alias)."""
        wt = self.store.hierarchy.wear_tiers()
        return self.meters[wt[-1]] if wt else None

    @property
    def overlap_efficiency(self) -> float | None:
        """Lifetime fraction of async plan time hidden under overlapped
        dispatches (None before any async pass commits)."""
        if not self.plan_ns_total:
            return None
        return self.plan_hidden_ns_total / self.plan_ns_total

    def maybe_step(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0, steps: int = 1,
                   on_commit=None):
        """Call once per training/serving step — or once per fused decode
        dispatch with ``steps`` = the number of inner steps it covered, so
        the interval stays token-granular across dispatch sizes; fires the
        memos loop on the configured interval.  Returns (new sysmon state,
        report|None).  In async mode the report belongs to the *previous*
        boundary's pass, committed here after overlapping with the
        dispatch in between; ``on_commit(report)`` runs between that
        commit and the next snapshot, so caller reactions to the pass
        (e.g. the serving engine re-promoting demoted active pages) are
        *inside* the next plan's snapshot instead of dirtying it
        mid-plan."""
        report = self.commit_pending()
        if report is not None and on_commit is not None:
            on_commit(report)
        self.step_count += steps
        self._steps_since += steps
        if self._steps_since < self.interval:
            return sm_state, report
        # a pass can only fire at a call (dispatch) boundary; keep the
        # token-granular cadence exact by carrying the full overshoot —
        # subtracting one interval instead of snapping to the remainder —
        # so a dispatch spanning more than one interval (decode_block >
        # interval, or shrunken dispatches near sequence ends) fires its
        # skipped pass at the next boundary instead of double-counting a
        # whole interval.  The carried credit is capped at one interval:
        # the cadence can never exceed one pass per boundary, so credit
        # beyond that is unspendable and would only grow without bound.
        self._steps_since = min(self._steps_since - self.interval,
                                self.interval)
        # background scrub at the pass boundary: re-verify a budgeted
        # slice of recorded page checksums, quarantining any slot whose
        # stored bits drifted (detection between write and next read)
        self._scrub()
        # degradation ladder: overlap -> sync -> memos-off.  At OFF the
        # pass still closes the SysMon window (state stays bounded) and
        # counts as healthy so the breaker can climb back.
        rung = self.ladder.rung
        if rung == RUNG_OFF:
            sm_state, _ = sysmon_mod.end_pass(sm_state)
            self.store.roll_traffic_window()
            self.ladder.record_healthy()
            return sm_state, report
        if self.cfg.async_plan and rung >= RUNG_OVERLAP:
            sm_state = self.begin_pass(sm_state, fast_bw_util)
            return sm_state, report
        return self.run_pass(sm_state, fast_bw_util)

    def _scrub(self) -> None:
        integ = self.store.integrity
        if not integ.enabled or self.cfg.scrub_pages <= 0:
            return
        for t, s in integ.scrub(self.store, self.cfg.scrub_pages):
            self.store.quarantine_slot(t, s, reason="scrub")

    # =========================================================================
    # synchronous pass
    # =========================================================================

    def run_pass(self, sm_state: sysmon_mod.SysmonState,
                 fast_bw_util: float = 0.0):
        with obs.span("memos.pass_sync", step=self.step_count):
            # 1-2) close the pass; classification + prediction on device
            sm_state, summary = sysmon_mod.end_pass(sm_state)
            wear_pressure = self._wear_pressure()
            spilling = self.balancer.update(fast_bw_util)
            report = self._plan_execute_finish(summary, wear_pressure,
                                               spilling, self._spill_dst())
        return sm_state, report

    def _wear_pressure(self) -> bool:
        """Whether any wear-tracked tier's projected lifetime (from the
        live counters) has dropped below the horizon."""
        if not (self.meters and self.cfg.lifetime_horizon_years):
            return False
        return any(m.project_lifetime() < self.cfg.lifetime_horizon_years
                   for m in self.meters.values())

    def _power_pressure(self) -> bool:
        """Whether the power governor is currently throttling (the last
        pass's dynamic power exceeded the budget and the throttle has not
        fully released)."""
        return self.governor is not None and self.governor.pressure

    def set_page_weight(self, pages, weight: float) -> None:
        """Record the tenant utility weight for a set of logical pages
        (Li et al. page-utility multiplier).  The weight array is created
        lazily on the first non-neutral weight, so unweighted workloads
        keep ``page_weight=None`` — and the planner bit-identical to the
        pre-QoS decision."""
        if self._page_weight is None:
            if weight == 1.0:
                return
            self._page_weight = np.ones(self.store.tier.shape[0],
                                        dtype=np.float64)
        self._page_weight[np.asarray(pages, dtype=np.int64)] = float(weight)

    def _spill_dst(self) -> int:
        """Bandwidth-aware spill destination: the backing tier with the
        most channel headroom over the current traffic window (ties break
        toward the faster tier, which reduces to tier 1 for unmodeled
        bandwidths), skipping capacity-exhausted pools."""
        order = self.store.backing_tier_order()
        for t in order:
            if self.store.alloc[t].n_free > 0:
                return t
        return order[0] if order else self.store.hierarchy.deepest

    def _plan_execute_finish(self, summary, wear_pressure: bool,
                             spilling: bool, spill_dst: int, *,
                             fault_fallback: str | None = None
                             ) -> MemosReport:
        """Steps 3-6 of the pass against *live* state: plan placement,
        execute migrations, spill, close telemetry — the synchronous
        path."""
        # power pressure planning response: WD pages pin fast (via the
        # wear-penalty path — writes stop burning NVM energy), WD is
        # excluded from spills, and intermediate-tier fill ranks media by
        # access energy
        power_pressure = self._power_pressure()
        pressure = wear_pressure or power_pressure
        penalty = self.cfg.wear_penalty if pressure else 0.0
        current = self.store.tier.copy()
        decision = plan(summary, current, max_migrations=self.cfg.max_migrations,
                        wear_penalty=penalty, hierarchy=self.store.hierarchy,
                        page_weight=self._page_weight,
                        energy_aware=power_pressure)

        bank_freq = np.asarray(summary.bank_freq)
        slab_freq = np.asarray(summary.slab_freq)
        reuse = np.asarray(summary.reuse_class)

        # 4) migrate
        stats = self.engine.execute(decision, bank_freq, slab_freq, reuse)

        # 5) bandwidth balancing (spill off the fast channel into the
        # backing tier with the most headroom while it is saturated)
        spilled = 0
        if spilling:
            cands = self.balancer.spill_candidates(
                np.asarray(summary.wd_code), np.asarray(summary.hotness),
                self.store.tier, n=self.cfg.max_migrations or 64,
                exclude_wd=pressure)
            st = self.engine.migrate_optimistic(cands, spill_dst, bank_freq,
                                                slab_freq, reuse)
            spilled = st.migrated

        return self._finish_pass(decision, stats, spilled, summary,
                                 wear_pressure,
                                 power_pressure=power_pressure,
                                 fault_fallback=fault_fallback)

    def _finish_pass(self, decision, stats: MigrationStats, spilled: int,
                     summary, wear_pressure: bool, *,
                     power_pressure: bool = False,
                     committed_async: bool = False,
                     pages_committed: int = 0,
                     pages_degraded: int = 0,
                     pages_dropped: int = 0,
                     plan_ms: float = 0.0,
                     overlap_efficiency: float | None = None,
                     fault_fallback: str | None = None) -> MemosReport:
        """Close the pass: adaptive interval, telemetry windows, report."""
        # adaptive interval (Sec. 7.4): grow when the plan barely changes
        tgt = np.asarray(decision.target_tier)
        if self.cfg.adaptive_interval and self._last_target is not None:
            changed = float(np.mean(tgt != self._last_target))
            if changed < self.cfg.stability_threshold:
                self.interval = min(int(self.interval * self.cfg.interval_growth),
                                    self.cfg.interval_max)
            else:
                self.interval = self.cfg.interval
        self._last_target = tgt

        # 6) close every wear-tracked tier's telemetry window (energy +
        # lifetime projection); scale the window by the steps this pass
        # actually covered so adaptive interval growth doesn't inflate the
        # apparent wear rate
        nvm_by_tier = {}
        if self.meters:
            steps = self.step_count - self._last_pass_step
            window = (self.cfg.pass_window_s * steps / self.cfg.interval
                      if steps > 0 else self.cfg.pass_window_s)
            nvm_by_tier = {t: m.end_pass(window_s=window)
                           for t, m in self.meters.items()}
        self._last_pass_step = self.step_count
        self.store.roll_traffic_window()

        # power-cap control loop: feed the governor the summed dynamic
        # power of every wear-tracked tier; its throttle level shapes the
        # *next* pass's plan and the engine's admission width
        power_mw = float(sum(r.dynamic_power_mw
                             for r in nvm_by_tier.values()))
        if self.governor is not None and nvm_by_tier:
            self.governor.observe(power_mw)

        bank_freq = np.asarray(summary.bank_freq)
        tier_pages = [int((self.store.tier == t).sum())
                      for t in range(self.store.n_tiers)]
        wt = self.store.hierarchy.wear_tiers()
        report = MemosReport(
            step=self.step_count,
            migrations=stats,
            n_marked=int(decision.migrate.sum()),
            fast_pages=tier_pages[0],
            slow_pages=tier_pages[-1],
            bank_imbalance=float(np.std(bank_freq)),
            spilled=spilled,
            tier_pages=tier_pages,
            nvm=nvm_by_tier.get(wt[-1]) if wt else None,
            nvm_by_tier=nvm_by_tier,
            wear_pressure=wear_pressure,
            power_pressure=power_pressure,
            power_throttle=(self.governor.throttle
                            if self.governor is not None else 0),
            power_mw=power_mw,
            committed_async=committed_async,
            plan_conflict=pages_degraded > 0,
            pages_committed=pages_committed,
            pages_degraded=pages_degraded,
            pages_dropped=pages_dropped,
            plan_ms=plan_ms,
            overlap_efficiency=overlap_efficiency,
            fault_fallback=fault_fallback,
        )
        self.reports.append(report)
        # ladder health: a watchdog fallback or any failed migration
        # group demotes one rung; otherwise the pass feeds the breaker's
        # healthy streak
        # (dirty-page retry exhaustion is normal churn, not a fault —
        # stats.failed only moves under injection or integrity failures,
        # so a fault-free run records healthy passes exclusively)
        if fault_fallback is not None:
            self.ladder.record_failure(f"plan:{fault_fallback}")
        elif stats.failed > 0:
            self.ladder.record_failure("migration")
        else:
            self.ladder.record_healthy()
        self._publish_metrics(report, summary)
        return report

    def _publish_metrics(self, report: MemosReport, summary) -> None:
        """Publish this pass into the process metrics registry (looked up
        by name each pass so registry resets between sweep configs take
        effect)."""
        reg = obs.get_registry()
        reg.counter("memos.passes", "memos passes completed").inc()
        reg.counter("memos.pages_migrated",
                    "pages moved across tiers").inc(report.migrations.migrated)
        reg.counter("memos.migration_bytes",
                    "bytes moved across tiers").inc(
                        report.migrations.bytes_moved)
        reg.counter("memos.pages_committed",
                    "async-plan pages committed").inc(report.pages_committed)
        reg.counter("memos.pages_degraded",
                    "async-plan pages degraded to next pass").inc(
                        report.pages_degraded)
        reg.counter("memos.pages_dropped",
                    "async-plan pages voided by mid-plan frees").inc(
                        report.pages_dropped)
        reg.counter("memos.spilled", "bandwidth-balancer spills").inc(
            report.spilled)
        if report.plan_ms > 0:
            reg.histogram("memos.plan_latency_s",
                          "worker-thread plan phase wall time").observe(
                              report.plan_ms / 1e3)
        if report.overlap_efficiency is not None:
            reg.histogram(
                "memos.overlap_efficiency",
                "fraction of plan time hidden under dispatch").observe(
                    report.overlap_efficiency)
        reg.gauge("memos.interval", "current adaptive pass interval").set(
            self.interval)
        reg.gauge("faults.ladder_rung",
                  "degradation rung: 2=overlap 1=sync 0=memos-off").set(
                      self.ladder.rung)
        reg.gauge("memos.bank_imbalance",
                  "stddev of per-bank access frequency").set(
                      report.bank_imbalance)
        if report.nvm_by_tier:
            reg.gauge("power.dynamic_mw",
                      "summed wear-tier dynamic power").set(report.power_mw)
        if self.governor is not None:
            reg.gauge("power.throttle",
                      "power-governor admission shrink level").set(
                          self.governor.throttle)
            reg.gauge("power.budget_mw", "dynamic-power budget").set(
                self.governor.budget_mw)
            reg.gauge("power.over_budget_passes",
                      "passes whose power reading exceeded the budget").set(
                          self.governor.over_budget_passes)
        # SysMon classification mix for the pass
        for k, v in sysmon_mod.summary_metrics(summary).items():
            reg.gauge(f"sysmon.{k}").set(v)
        # per-tier occupancy + per-(src,dst) traffic
        self.store.publish_metrics(reg)
        # per-wear-tier energy / wear / lifetime
        for t, nvm in report.nvm_by_tier.items():
            nvm.publish(reg, prefix=f"nvm.t{t}.")

    # =========================================================================
    # asynchronous pipeline: snapshot -> plan (worker) -> commit
    # =========================================================================

    def begin_pass(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0) -> sysmon_mod.SysmonState:
        """Snapshot phase, at a dispatch boundary: close the SysMon pass,
        freeze the placement-visible store state, and hand the plan to
        the worker thread.  Returns the reset SysMon state immediately so
        the next dispatch launches while the worker plans."""
        assert self._ticket is None, "previous plan not committed"
        with obs.span("memos.snapshot", step=self.step_count):
            sm_state, summary = sysmon_mod.end_pass(sm_state)
            # numpy-ify the summary once (device sync) so the worker is
            # jax-free — classification itself already ran on device
            summary_np = type(summary)(*[np.asarray(f) for f in summary])
            ticket = _PlanTicket(
                step=self.step_count,
                summary=summary_np,
                view=StoreView(self.store),
                wear_pressure=self._wear_pressure(),
                spilling=self.balancer.update(fast_bw_util),
                spill_dst=self._spill_dst(),
                power_pressure=self._power_pressure(),
                page_weight=(None if self._page_weight is None
                             else self._page_weight.copy()),
            )
            ticket.future = self._submit_plan(ticket)
            self._ticket = ticket
        return sm_state

    def _submit_plan(self, ticket: _PlanTicket) -> Future:
        """Hand the plan to the worker pool, respawning the executor once
        if it died (watchdog shutdown, external kill); if the respawn
        also cannot accept work, return a pre-failed future so the next
        commit takes the synchronous fallback instead of deadlocking."""
        for _ in range(2):
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="memos-plan")
            try:
                return self._executor.submit(self._plan_job, ticket)
            except RuntimeError:          # executor already shut down
                self._executor = None
        f: Future = Future()
        f.set_exception(RuntimeError("memos plan executor unavailable"))
        return f

    def _plan_job(self, t: _PlanTicket):
        """Worker-thread plan phase: classification + placement +
        Algorithm-2 slot targeting, all against the immutable snapshot
        (reservations simulated on the cloned allocators).  Pure numpy —
        no jax, no live-store access."""
        # plan-phase wall clock is recorded unconditionally (two
        # monotonic_ns calls) — the overlap-efficiency metric must work
        # with tracing off
        t.plan_t0_ns = time.monotonic_ns()
        with obs.span("memos.plan", step=t.step):
            get_injector().maybe_plan_fault()
            pressure = t.wear_pressure or t.power_pressure
            penalty = self.cfg.wear_penalty if pressure else 0.0
            decision = plan(t.summary, t.view.tier.copy(),
                            max_migrations=self.cfg.max_migrations,
                            wear_penalty=penalty,
                            hierarchy=self.store.hierarchy,
                            page_weight=t.page_weight,
                            energy_aware=t.power_pressure)
            bank_freq = np.asarray(t.summary.bank_freq)
            slab_freq = np.asarray(t.summary.slab_freq)
            reuse = np.asarray(t.summary.reuse_class)
            plans = plan_decision(t.view, decision, bank_freq, slab_freq,
                                  reuse)
            spill_plan = None
            if t.spilling:
                cands = self.balancer.spill_candidates(
                    np.asarray(t.summary.wd_code),
                    np.asarray(t.summary.hotness),
                    t.view.tier, n=self.cfg.max_migrations or 64,
                    exclude_wd=pressure)
                # candidates come from the snapshot's tier table, so exclude
                # pages this pass already plans to move — the synchronous path
                # picks candidates *after* migrating, so a just-demoted page
                # can never be spilled twice
                planned = {int(p) for pl in plans for p in pl.pages}
                cands = np.asarray(
                    [p for p in cands if int(p) not in planned], np.int64)
                spill_plan = plan_optimistic(t.view, cands, t.spill_dst,
                                             bank_freq, slab_freq, reuse)
        t.plan_t1_ns = time.monotonic_ns()
        return decision, plans, spill_plan

    def commit_pending(self) -> MemosReport | None:
        """Commit phase, at the next dispatch boundary — page-granular:
        join the worker, close the dirty-page epoch the snapshot opened,
        land the reservations (O(1) clone adoption per quiet tier,
        prefix replay otherwise), and bulk-execute the *clean subset* of
        every plan.  Only pages dirtied mid-plan (or past a replay
        divergence) degrade: their reservations are released and the
        next pass picks them up from its own fresh snapshot.  No-op when
        no plan is in flight."""
        if self._ticket is None:
            return None
        t, self._ticket = self._ticket, None
        # overlap accounting: plan time elapsed before we *asked* for the
        # result was hidden under the dispatch; time we block in result()
        # is exposed
        t_commit0 = time.monotonic_ns()
        try:
            decision, plans, spill_plan = t.future.result(
                timeout=self.cfg.plan_timeout_s)
        except FutureTimeout:
            return self._plan_fault_fallback(t, "timeout")
        except Exception as e:        # worker raised (injected or real)
            return self._plan_fault_fallback(t, type(e).__name__)
        with obs.span("memos.commit", step=t.step) as sp:
            if self._mid_plan_hook is not None:
                self._mid_plan_hook(self, decision, plans)
            all_plans = plans + ([spill_plan] if spill_plan is not None
                                 else [])

            # pages whose version/tier/slot changed since the snapshot — the
            # incremental epoch diff, recorded by the store as the dispatch
            # ran, replaces any per-plan array re-validation
            dirty = self.store.end_dirty_epoch()
            landed = commit_reservations(self.store, t.view, all_plans)

            stats = MigrationStats()
            spilled = 0
            committed = degraded = dropped = 0
            for pl, ok in zip(all_plans, landed):
                keep = ok.copy()
                if len(pl):
                    if dirty:
                        stale = np.asarray(
                            [int(p) in dirty for p in pl.pages])
                        keep &= ~stale
                        # stale pages that are no longer allocated were
                        # freed mid-plan (a retired sequence): the plan
                        # entry is void, not deferred work — drop it
                        # without charging a conflict
                        freed = np.asarray(
                            [int(self.store.slot[int(p)]) == NO_SLOT
                             for p in pl.pages])
                        dropped += int((stale & freed).sum())
                    # release reservations held for pages that degrade or
                    # drop (a page the replay had no capacity for holds
                    # nothing)
                    for i in np.nonzero(ok & ~keep)[0]:
                        self.store.alloc[pl.dst_tier].free(
                            int(pl.dst_slots[i]), 0)
                committed += int(keep.sum())
                degraded += len(pl) - int(keep.sum())
                st = self.engine.execute_plan(subset_plan(pl, keep))
                if pl is spill_plan:
                    spilled = st.migrated
                else:
                    stats.merge(st)
            degraded -= dropped
            self.pages_committed += committed
            self.pages_degraded += degraded
            self.pages_dropped += dropped
            sp.set(pages_committed=committed, pages_degraded=degraded,
                   pages_dropped=dropped)

        plan_dur = max(t.plan_t1_ns - t.plan_t0_ns, 0)
        hidden = min(max(t_commit0 - t.plan_t0_ns, 0), plan_dur)
        eff = hidden / plan_dur if plan_dur > 0 else 1.0
        self.plan_ns_total += plan_dur
        self.plan_hidden_ns_total += hidden
        return self._finish_pass(decision, stats, spilled, t.summary,
                                 t.wear_pressure,
                                 power_pressure=t.power_pressure,
                                 committed_async=True,
                                 pages_committed=committed,
                                 pages_degraded=degraded,
                                 pages_dropped=dropped,
                                 plan_ms=plan_dur / 1e6,
                                 overlap_efficiency=eff)

    def _plan_fault_fallback(self, t: _PlanTicket,
                             reason: str) -> MemosReport:
        """Watchdog path: the worker-thread plan hung past
        ``plan_timeout_s`` or died with an exception.  Abandon the future
        (a hung worker keeps its thread; the executor is shut down
        without waiting and lazily respawned by the next ``begin_pass``),
        close the dirty-page epoch the snapshot opened, and run the whole
        pass synchronously against live state — the serving loop never
        stalls on a dead planner.  The pass is recorded as recovered and
        demotes the degradation ladder via ``fault_fallback``."""
        with obs.span("memos.plan_fallback", step=t.step, reason=reason):
            t.future.cancel()
            if self._executor is not None:
                self._executor.shutdown(wait=False, cancel_futures=True)
                self._executor = None
            self.store.end_dirty_epoch()
            note_recovered("plan_fallback")
            return self._plan_execute_finish(t.summary, t.wear_pressure,
                                             t.spilling, t.spill_dst,
                                             fault_fallback=reason)

    def flush(self) -> MemosReport | None:
        """Commit any in-flight plan (end of serving / shutdown)."""
        return self.commit_pending()

    def close(self) -> None:
        self.flush()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
