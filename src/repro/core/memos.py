"""MemosManager — the periodic full-hierarchy management loop (Fig. 10),
generic over the tiers of a :class:`~repro.core.hierarchy.MemoryHierarchy`.

Ties SysMon -> predictor -> placement -> migration together:

  every ``interval`` steps (paper: 20 s wall clock):
    1. close the SysMon sampling pass (WD counts over Window_Len history)
    2. predict each page's future state (+ Reverse check over K_Len)
    3. mark will-be-migrated pages, rank the hotness list (WD_FREQ_H first)
    4. migrate: locked promotions toward tier 0 for hot/WD pages,
       optimistic bulk demotions toward the slower tiers; destination
       slots via Algorithm 2 (coldest bank x coldest slab) in the
       destination tier's own allocator
    5. bandwidth balancing: spill RD (then coolest WD) pages off the
       fast channel while it is saturated, into the backing tier with
       the most bandwidth headroom
    6. NVM telemetry (Sec. 7.1): close the energy/lifetime accounting
       window of **every wear-tracked tier**; when any tier's projected
       lifetime from the live wear counters drops below
       ``lifetime_horizon_years``, the *next* pass plans with a wear
       penalty — WD pages are pinned/promoted to the fast tier, ranked
       first in the HL, and excluded from bandwidth spills.

Overhead controls from Sec. 7.4 are exposed: sampling subset fraction and
an adaptively growing interval once patterns stabilize.

Asynchronous pipeline (``MemosConfig.async_plan``)
--------------------------------------------------
The paper's monitor and migration engine run *concurrently* with the
application; the synchronous ``run_pass`` instead blocks the serving loop
for the whole pass.  With ``async_plan`` the pass splits into a
snapshot -> plan -> commit pipeline:

  * **snapshot** (dispatch boundary, cheap): close the SysMon pass, pull
    the summary, snapshot the page table / version counters / cloned
    allocators (:class:`~repro.core.migration.StoreView`) and the wear
    projection;
  * **plan** (worker thread, overlapped with the next jitted K-token
    dispatch): pattern classification + placement + Algorithm-2 slot
    targeting simulated on the cloned allocators + spill candidate
    selection — pure numpy against the immutable snapshot;
  * **commit** (next dispatch boundary): **page-granular**.  The
    snapshot opened a dirty-page epoch on the store (every version bump,
    tier change, or slot change mid-dispatch is recorded incrementally),
    so validation is a set lookup per planned page — O(dirtied pages)
    overall, not O(plan).  Reservations land through
    :func:`~repro.core.migration.commit_reservations`: a destination
    tier with no interleaved allocator call adopts the plan's clone
    wholesale (O(1), slots land exactly as simulated); otherwise the
    recorded Algorithm-2 calls replay against the live allocator, each
    reservation patched to the slot actually obtained — the slot a
    synchronous pass planning at this boundary would take.  The *clean
    subset* of every plan then executes as bulk moves — only pages
    dirtied mid-plan (or out of destination capacity at commit time)
    degrade: their reservations are released and they simply wait for
    the next pass, which sees them in its fresh snapshot.  A conflict no
    longer discards the whole plan or forces a synchronous re-plan;
    ``pages_committed`` / ``pages_degraded`` count the split per page.
"""
from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import sysmon as sysmon_mod
from .migration import (MigrationStats, StoreView, commit_reservations,
                        make_engine, plan_decision, plan_optimistic,
                        subset_plan)
from .placement import BandwidthBalancer, plan
from .tiers import TierStore


@dataclass
class MemosConfig:
    interval: int = 16            # steps between memos passes
    max_migrations: int | None = 256
    fast_bw_bound: float = 0.9    # fraction of fast-channel peak
    adaptive_interval: bool = True
    interval_growth: float = 1.5  # grow when patterns are stable (Sec. 7.4)
    interval_max: int = 256
    stability_threshold: float = 0.02  # fraction of pages changing target
    engine: str = "batched"       # "batched" (device bulk) | "reference"
    # NVM wear feedback (Sec. 7.1): act when any wear-tracked tier's
    # projected lifetime drops below the horizon; None disables feedback.
    lifetime_horizon_years: float | None = None
    wear_penalty: float = 4.0     # HL-ranking boost for WD pages under pressure
    pass_window_s: float = 1.0    # notional wall-clock span of one pass
    # overlap the plan phase with the next dispatch on a worker thread
    # (snapshot -> plan -> commit; see module docstring)
    async_plan: bool = False


@dataclass
class MemosReport:
    step: int
    migrations: MigrationStats
    n_marked: int
    fast_pages: int               # pages resident in tier 0
    slow_pages: int               # pages resident in the deepest tier
    bank_imbalance: float
    spilled: int = 0
    tier_pages: list[int] = field(default_factory=list)  # per-tier residency
    nvm: object | None = None     # deepest wear-tracked tier's NvmReport
    nvm_by_tier: dict = field(default_factory=dict)  # tier -> NvmReport
    wear_pressure: bool = False   # wear penalty applied to this pass's plan
    committed_async: bool = False  # pass went through the overlapped commit
    plan_conflict: bool = False    # some planned pages were stale (degraded)
    pages_committed: int = 0      # planned pages committed by this pass
    pages_degraded: int = 0       # planned pages left for the next pass


@dataclass
class _PlanTicket:
    """One in-flight asynchronous pass: the immutable snapshot plus the
    worker future that resolves to (decision, plans, spill_plan)."""
    step: int
    summary: object               # PassSummary with numpy leaves
    view: StoreView
    wear_pressure: bool
    spilling: bool
    spill_dst: int
    future: Future | None = None


class MemosManager:
    def __init__(self, store: TierStore, cfg: MemosConfig | None = None):
        self.store = store
        self.cfg = cfg or MemosConfig()
        self.engine = make_engine(store, self.cfg.engine)
        self.balancer = BandwidthBalancer(self.cfg.fast_bw_bound)
        # one energy meter per wear-tracked tier (lazy import: repro.nvm
        # depends on core.costmodel)
        self.meters: dict[int, object] = {}
        for t in store.hierarchy.wear_tiers():
            from repro.nvm.energy import EnergyMeter
            self.meters[t] = EnergyMeter(store, tier=t,
                                         window_s=self.cfg.pass_window_s)
        self.interval = self.cfg.interval
        self._last_target: np.ndarray | None = None
        self._steps_since = 0
        self._last_pass_step = 0
        self.reports: list[MemosReport] = []
        self.step_count = 0
        # async pipeline state
        if self.cfg.async_plan and not hasattr(self.engine, "execute_plan"):
            raise ValueError("async_plan requires a plan-executing engine "
                             "(MemosConfig.engine='batched')")
        self._executor: ThreadPoolExecutor | None = None
        self._ticket: _PlanTicket | None = None
        # page-granular commit accounting: a partially-committed pass
        # contributes to *both* counters, once per page — never
        # double-counted as a whole-pass commit and a whole-pass conflict
        self.pages_committed = 0      # planned pages committed async
        self.pages_degraded = 0       # planned pages dirtied mid-plan
        # test hook: called with (manager, decision, plans) between the
        # worker join and validation — simulates writes landing mid-plan
        self._mid_plan_hook = None

    @property
    def meter(self):
        """Deepest wear-tracked tier's meter (two-tier compat alias)."""
        wt = self.store.hierarchy.wear_tiers()
        return self.meters[wt[-1]] if wt else None

    def maybe_step(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0, steps: int = 1,
                   on_commit=None):
        """Call once per training/serving step — or once per fused decode
        dispatch with ``steps`` = the number of inner steps it covered, so
        the interval stays token-granular across dispatch sizes; fires the
        memos loop on the configured interval.  Returns (new sysmon state,
        report|None).  In async mode the report belongs to the *previous*
        boundary's pass, committed here after overlapping with the
        dispatch in between; ``on_commit(report)`` runs between that
        commit and the next snapshot, so caller reactions to the pass
        (e.g. the serving engine re-promoting demoted active pages) are
        *inside* the next plan's snapshot instead of dirtying it
        mid-plan."""
        report = self.commit_pending()
        if report is not None and on_commit is not None:
            on_commit(report)
        self.step_count += steps
        self._steps_since += steps
        if self._steps_since < self.interval:
            return sm_state, report
        # a pass can only fire at a call (dispatch) boundary; keep the
        # token-granular cadence exact by carrying the full overshoot —
        # subtracting one interval instead of snapping to the remainder —
        # so a dispatch spanning more than one interval (decode_block >
        # interval, or shrunken dispatches near sequence ends) fires its
        # skipped pass at the next boundary instead of double-counting a
        # whole interval.  The carried credit is capped at one interval:
        # the cadence can never exceed one pass per boundary, so credit
        # beyond that is unspendable and would only grow without bound.
        self._steps_since = min(self._steps_since - self.interval,
                                self.interval)
        if self.cfg.async_plan:
            sm_state = self.begin_pass(sm_state, fast_bw_util)
            return sm_state, report
        return self.run_pass(sm_state, fast_bw_util)

    # =========================================================================
    # synchronous pass
    # =========================================================================

    def run_pass(self, sm_state: sysmon_mod.SysmonState,
                 fast_bw_util: float = 0.0):
        # 1-2) close the pass; classification + prediction happen on device
        sm_state, summary = sysmon_mod.end_pass(sm_state)
        wear_pressure = self._wear_pressure()
        spilling = self.balancer.update(fast_bw_util)
        report = self._plan_execute_finish(summary, wear_pressure, spilling,
                                           self._spill_dst())
        return sm_state, report

    def _wear_pressure(self) -> bool:
        """Whether any wear-tracked tier's projected lifetime (from the
        live counters) has dropped below the horizon."""
        if not (self.meters and self.cfg.lifetime_horizon_years):
            return False
        return any(m.project_lifetime() < self.cfg.lifetime_horizon_years
                   for m in self.meters.values())

    def _spill_dst(self) -> int:
        """Bandwidth-aware spill destination: the backing tier with the
        most channel headroom over the current traffic window (ties break
        toward the faster tier, which reduces to tier 1 for unmodeled
        bandwidths), skipping capacity-exhausted pools."""
        order = self.store.backing_tier_order()
        for t in order:
            if self.store.alloc[t].n_free > 0:
                return t
        return order[0] if order else self.store.hierarchy.deepest

    def _plan_execute_finish(self, summary, wear_pressure: bool,
                             spilling: bool, spill_dst: int) -> MemosReport:
        """Steps 3-6 of the pass against *live* state: plan placement,
        execute migrations, spill, close telemetry — the synchronous
        path."""
        penalty = self.cfg.wear_penalty if wear_pressure else 0.0
        current = self.store.tier.copy()
        decision = plan(summary, current, max_migrations=self.cfg.max_migrations,
                        wear_penalty=penalty, hierarchy=self.store.hierarchy)

        bank_freq = np.asarray(summary.bank_freq)
        slab_freq = np.asarray(summary.slab_freq)
        reuse = np.asarray(summary.reuse_class)

        # 4) migrate
        stats = self.engine.execute(decision, bank_freq, slab_freq, reuse)

        # 5) bandwidth balancing (spill off the fast channel into the
        # backing tier with the most headroom while it is saturated)
        spilled = 0
        if spilling:
            cands = self.balancer.spill_candidates(
                np.asarray(summary.wd_code), np.asarray(summary.hotness),
                self.store.tier, n=self.cfg.max_migrations or 64,
                exclude_wd=wear_pressure)
            st = self.engine.migrate_optimistic(cands, spill_dst, bank_freq,
                                                slab_freq, reuse)
            spilled = st.migrated

        return self._finish_pass(decision, stats, spilled, summary,
                                 wear_pressure)

    def _finish_pass(self, decision, stats: MigrationStats, spilled: int,
                     summary, wear_pressure: bool, *,
                     committed_async: bool = False,
                     pages_committed: int = 0,
                     pages_degraded: int = 0) -> MemosReport:
        """Close the pass: adaptive interval, telemetry windows, report."""
        # adaptive interval (Sec. 7.4): grow when the plan barely changes
        tgt = np.asarray(decision.target_tier)
        if self.cfg.adaptive_interval and self._last_target is not None:
            changed = float(np.mean(tgt != self._last_target))
            if changed < self.cfg.stability_threshold:
                self.interval = min(int(self.interval * self.cfg.interval_growth),
                                    self.cfg.interval_max)
            else:
                self.interval = self.cfg.interval
        self._last_target = tgt

        # 6) close every wear-tracked tier's telemetry window (energy +
        # lifetime projection); scale the window by the steps this pass
        # actually covered so adaptive interval growth doesn't inflate the
        # apparent wear rate
        nvm_by_tier = {}
        if self.meters:
            steps = self.step_count - self._last_pass_step
            window = (self.cfg.pass_window_s * steps / self.cfg.interval
                      if steps > 0 else self.cfg.pass_window_s)
            nvm_by_tier = {t: m.end_pass(window_s=window)
                           for t, m in self.meters.items()}
        self._last_pass_step = self.step_count
        self.store.roll_traffic_window()

        bank_freq = np.asarray(summary.bank_freq)
        tier_pages = [int((self.store.tier == t).sum())
                      for t in range(self.store.n_tiers)]
        wt = self.store.hierarchy.wear_tiers()
        report = MemosReport(
            step=self.step_count,
            migrations=stats,
            n_marked=int(decision.migrate.sum()),
            fast_pages=tier_pages[0],
            slow_pages=tier_pages[-1],
            bank_imbalance=float(np.std(bank_freq)),
            spilled=spilled,
            tier_pages=tier_pages,
            nvm=nvm_by_tier.get(wt[-1]) if wt else None,
            nvm_by_tier=nvm_by_tier,
            wear_pressure=wear_pressure,
            committed_async=committed_async,
            plan_conflict=pages_degraded > 0,
            pages_committed=pages_committed,
            pages_degraded=pages_degraded,
        )
        self.reports.append(report)
        return report

    # =========================================================================
    # asynchronous pipeline: snapshot -> plan (worker) -> commit
    # =========================================================================

    def begin_pass(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0) -> sysmon_mod.SysmonState:
        """Snapshot phase, at a dispatch boundary: close the SysMon pass,
        freeze the placement-visible store state, and hand the plan to
        the worker thread.  Returns the reset SysMon state immediately so
        the next dispatch launches while the worker plans."""
        assert self._ticket is None, "previous plan not committed"
        sm_state, summary = sysmon_mod.end_pass(sm_state)
        # numpy-ify the summary once (device sync) so the worker is
        # jax-free — classification itself already ran on device
        summary_np = type(summary)(*[np.asarray(f) for f in summary])
        ticket = _PlanTicket(
            step=self.step_count,
            summary=summary_np,
            view=StoreView(self.store),
            wear_pressure=self._wear_pressure(),
            spilling=self.balancer.update(fast_bw_util),
            spill_dst=self._spill_dst(),
        )
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="memos-plan")
        ticket.future = self._executor.submit(self._plan_job, ticket)
        self._ticket = ticket
        return sm_state

    def _plan_job(self, t: _PlanTicket):
        """Worker-thread plan phase: classification + placement +
        Algorithm-2 slot targeting, all against the immutable snapshot
        (reservations simulated on the cloned allocators).  Pure numpy —
        no jax, no live-store access."""
        penalty = self.cfg.wear_penalty if t.wear_pressure else 0.0
        decision = plan(t.summary, t.view.tier.copy(),
                        max_migrations=self.cfg.max_migrations,
                        wear_penalty=penalty,
                        hierarchy=self.store.hierarchy)
        bank_freq = np.asarray(t.summary.bank_freq)
        slab_freq = np.asarray(t.summary.slab_freq)
        reuse = np.asarray(t.summary.reuse_class)
        plans = plan_decision(t.view, decision, bank_freq, slab_freq, reuse)
        spill_plan = None
        if t.spilling:
            cands = self.balancer.spill_candidates(
                np.asarray(t.summary.wd_code), np.asarray(t.summary.hotness),
                t.view.tier, n=self.cfg.max_migrations or 64,
                exclude_wd=t.wear_pressure)
            # candidates come from the snapshot's tier table, so exclude
            # pages this pass already plans to move — the synchronous path
            # picks candidates *after* migrating, so a just-demoted page
            # can never be spilled twice
            planned = {int(p) for pl in plans for p in pl.pages}
            cands = np.asarray([p for p in cands if int(p) not in planned],
                               np.int64)
            spill_plan = plan_optimistic(t.view, cands, t.spill_dst,
                                         bank_freq, slab_freq, reuse)
        return decision, plans, spill_plan

    def commit_pending(self) -> MemosReport | None:
        """Commit phase, at the next dispatch boundary — page-granular:
        join the worker, close the dirty-page epoch the snapshot opened,
        land the reservations (O(1) clone adoption per quiet tier,
        prefix replay otherwise), and bulk-execute the *clean subset* of
        every plan.  Only pages dirtied mid-plan (or past a replay
        divergence) degrade: their reservations are released and the
        next pass picks them up from its own fresh snapshot.  No-op when
        no plan is in flight."""
        if self._ticket is None:
            return None
        t, self._ticket = self._ticket, None
        decision, plans, spill_plan = t.future.result()
        if self._mid_plan_hook is not None:
            self._mid_plan_hook(self, decision, plans)
        all_plans = plans + ([spill_plan] if spill_plan is not None else [])

        # pages whose version/tier/slot changed since the snapshot — the
        # incremental epoch diff, recorded by the store as the dispatch
        # ran, replaces any per-plan array re-validation
        dirty = self.store.end_dirty_epoch()
        landed = commit_reservations(self.store, t.view, all_plans)

        stats = MigrationStats()
        spilled = 0
        committed = degraded = 0
        for pl, ok in zip(all_plans, landed):
            keep = ok.copy()
            if len(pl):
                if dirty:
                    keep &= np.asarray(
                        [int(p) not in dirty for p in pl.pages])
                # release reservations held for pages that degrade (a
                # page the replay had no capacity for holds nothing)
                for i in np.nonzero(ok & ~keep)[0]:
                    self.store.alloc[pl.dst_tier].free(
                        int(pl.dst_slots[i]), 0)
            committed += int(keep.sum())
            degraded += len(pl) - int(keep.sum())
            st = self.engine.execute_plan(subset_plan(pl, keep))
            if pl is spill_plan:
                spilled = st.migrated
            else:
                stats.merge(st)
        self.pages_committed += committed
        self.pages_degraded += degraded
        return self._finish_pass(decision, stats, spilled, t.summary,
                                 t.wear_pressure, committed_async=True,
                                 pages_committed=committed,
                                 pages_degraded=degraded)

    def flush(self) -> MemosReport | None:
        """Commit any in-flight plan (end of serving / shutdown)."""
        return self.commit_pending()

    def close(self) -> None:
        self.flush()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
