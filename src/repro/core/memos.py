"""MemosManager — the periodic full-hierarchy management loop (Fig. 10),
generic over the tiers of a :class:`~repro.core.hierarchy.MemoryHierarchy`.

Ties SysMon -> predictor -> placement -> migration together:

  every ``interval`` steps (paper: 20 s wall clock):
    1. close the SysMon sampling pass (WD counts over Window_Len history)
    2. predict each page's future state (+ Reverse check over K_Len)
    3. mark will-be-migrated pages, rank the hotness list (WD_FREQ_H first)
    4. migrate: locked promotions toward tier 0 for hot/WD pages,
       optimistic bulk demotions toward the slower tiers; destination
       slots via Algorithm 2 (coldest bank x coldest slab) in the
       destination tier's own allocator
    5. bandwidth balancing: spill RD (then coolest WD) pages off the
       fast channel while it is saturated
    6. NVM telemetry (Sec. 7.1): close the energy/lifetime accounting
       window of **every wear-tracked tier**; when any tier's projected
       lifetime from the live wear counters drops below
       ``lifetime_horizon_years``, the *next* pass plans with a wear
       penalty — WD pages are pinned/promoted to the fast tier, ranked
       first in the HL, and excluded from bandwidth spills.

Overhead controls from Sec. 7.4 are exposed: sampling subset fraction and
an adaptively growing interval once patterns stabilize.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import sysmon as sysmon_mod
from .migration import MigrationStats, make_engine
from .placement import BandwidthBalancer, plan
from .tiers import TierStore


@dataclass
class MemosConfig:
    interval: int = 16            # steps between memos passes
    max_migrations: int | None = 256
    fast_bw_bound: float = 0.9    # fraction of fast-channel peak
    adaptive_interval: bool = True
    interval_growth: float = 1.5  # grow when patterns are stable (Sec. 7.4)
    interval_max: int = 256
    stability_threshold: float = 0.02  # fraction of pages changing target
    engine: str = "batched"       # "batched" (device bulk) | "reference"
    # NVM wear feedback (Sec. 7.1): act when any wear-tracked tier's
    # projected lifetime drops below the horizon; None disables feedback.
    lifetime_horizon_years: float | None = None
    wear_penalty: float = 4.0     # HL-ranking boost for WD pages under pressure
    pass_window_s: float = 1.0    # notional wall-clock span of one pass


@dataclass
class MemosReport:
    step: int
    migrations: MigrationStats
    n_marked: int
    fast_pages: int               # pages resident in tier 0
    slow_pages: int               # pages resident in the deepest tier
    bank_imbalance: float
    spilled: int = 0
    tier_pages: list[int] = field(default_factory=list)  # per-tier residency
    nvm: object | None = None     # deepest wear-tracked tier's NvmReport
    nvm_by_tier: dict = field(default_factory=dict)  # tier -> NvmReport
    wear_pressure: bool = False   # wear penalty applied to this pass's plan


class MemosManager:
    def __init__(self, store: TierStore, cfg: MemosConfig | None = None):
        self.store = store
        self.cfg = cfg or MemosConfig()
        self.engine = make_engine(store, self.cfg.engine)
        self.balancer = BandwidthBalancer(self.cfg.fast_bw_bound)
        # one energy meter per wear-tracked tier (lazy import: repro.nvm
        # depends on core.costmodel)
        self.meters: dict[int, object] = {}
        for t in store.hierarchy.wear_tiers():
            from repro.nvm.energy import EnergyMeter
            self.meters[t] = EnergyMeter(store, tier=t,
                                         window_s=self.cfg.pass_window_s)
        self.interval = self.cfg.interval
        self._last_target: np.ndarray | None = None
        self._steps_since = 0
        self._last_pass_step = 0
        self.reports: list[MemosReport] = []
        self.step_count = 0

    @property
    def meter(self):
        """Deepest wear-tracked tier's meter (two-tier compat alias)."""
        wt = self.store.hierarchy.wear_tiers()
        return self.meters[wt[-1]] if wt else None

    def maybe_step(self, sm_state: sysmon_mod.SysmonState,
                   fast_bw_util: float = 0.0, steps: int = 1):
        """Call once per training/serving step — or once per fused decode
        dispatch with ``steps`` = the number of inner steps it covered, so
        the interval stays token-granular across dispatch sizes; fires the
        memos loop on the configured interval.  Returns (new sysmon state,
        report|None)."""
        self.step_count += steps
        self._steps_since += steps
        if self._steps_since < self.interval:
            return sm_state, None
        # a pass can only fire at a call (dispatch) boundary, so keep the
        # token-granular cadence by carrying the remainder modulo the
        # interval instead of discarding it — overshoot from one large
        # dispatch does not push the next pass a full interval out
        self._steps_since %= self.interval
        return self.run_pass(sm_state, fast_bw_util)

    def run_pass(self, sm_state: sysmon_mod.SysmonState,
                 fast_bw_util: float = 0.0):
        # 1-2) close the pass; classification + prediction happen on device
        sm_state, summary = sysmon_mod.end_pass(sm_state)

        # 3) plan: mark will-be-migrated, rank HL; under NVM wear pressure
        # (any wear-tracked tier's projected lifetime below the horizon) WD
        # pages get the penalty term: pinned to fast, ranked first,
        # excluded from spills
        wear_pressure = False
        if self.meters and self.cfg.lifetime_horizon_years:
            wear_pressure = any(
                m.project_lifetime() < self.cfg.lifetime_horizon_years
                for m in self.meters.values())
        penalty = self.cfg.wear_penalty if wear_pressure else 0.0
        current = self.store.tier.copy()
        decision = plan(summary, current, max_migrations=self.cfg.max_migrations,
                        wear_penalty=penalty, hierarchy=self.store.hierarchy)

        bank_freq = np.asarray(summary.bank_freq)
        slab_freq = np.asarray(summary.slab_freq)
        reuse = np.asarray(summary.reuse_class)

        # 4) migrate
        stats = self.engine.execute(decision, bank_freq, slab_freq, reuse)

        # 5) bandwidth balancing (spill off the fast channel into the next
        # tier down while the fast channel is saturated)
        spilled = 0
        if self.balancer.update(fast_bw_util):
            cands = self.balancer.spill_candidates(
                np.asarray(summary.wd_code), np.asarray(summary.hotness),
                self.store.tier, n=self.cfg.max_migrations or 64,
                exclude_wd=wear_pressure)
            st = self.engine.migrate_optimistic(cands, 1, bank_freq,
                                                slab_freq, reuse)
            spilled = st.migrated

        # adaptive interval (Sec. 7.4): grow when the plan barely changes
        tgt = np.asarray(decision.target_tier)
        if self.cfg.adaptive_interval and self._last_target is not None:
            changed = float(np.mean(tgt != self._last_target))
            if changed < self.cfg.stability_threshold:
                self.interval = min(int(self.interval * self.cfg.interval_growth),
                                    self.cfg.interval_max)
            else:
                self.interval = self.cfg.interval
        self._last_target = tgt

        # 6) close every wear-tracked tier's telemetry window (energy +
        # lifetime projection); scale the window by the steps this pass
        # actually covered so adaptive interval growth doesn't inflate the
        # apparent wear rate
        nvm_by_tier = {}
        if self.meters:
            steps = self.step_count - self._last_pass_step
            window = (self.cfg.pass_window_s * steps / self.cfg.interval
                      if steps > 0 else self.cfg.pass_window_s)
            nvm_by_tier = {t: m.end_pass(window_s=window)
                           for t, m in self.meters.items()}
        self._last_pass_step = self.step_count

        tier_pages = [int((self.store.tier == t).sum())
                      for t in range(self.store.n_tiers)]
        wt = self.store.hierarchy.wear_tiers()
        report = MemosReport(
            step=self.step_count,
            migrations=stats,
            n_marked=int(decision.migrate.sum()),
            fast_pages=tier_pages[0],
            slow_pages=tier_pages[-1],
            bank_imbalance=float(np.std(bank_freq)),
            spilled=spilled,
            tier_pages=tier_pages,
            nvm=nvm_by_tier.get(wt[-1]) if wt else None,
            nvm_by_tier=nvm_by_tier,
            wear_pressure=wear_pressure,
        )
        self.reports.append(report)
        return sm_state, report
