"""Write-history based future-pattern prediction (paper Sec. 3.2, Fig. 3/4).

Each page keeps its last ``Window_Len`` (default 8) WD observations as a
bitfield in one raw byte — the paper's "page shadow array (each element is
a raw byte) and bit manipulation", taken literally.  Bit 0 is the most
recent pass; bit (Window_Len-1) the oldest.

Prediction of the future state:

  * popcount(window) >= hi_thresh  ->  WD_FREQ_H   (Fig. 4 case_1)
  * popcount(window) >= lo_thresh  ->  WD_FREQ_L   (Fig. 4 case_3)
  * otherwise                      ->  UN_WD       (Fig. 4 case_2)

``Reverse`` rule (Fig. 4 case_4): when the last ``K_Len`` consecutive
observations are all WD, predict WD_FREQ_H regardless of the window
majority; when they are all non-WD, predict UN_WD ("and visa versa").
This handles sampling windows that span a phase change.

The paper's calibration: Window_Len=8 predicts a stable pattern with ~96%
accuracy, valid for ~10 future sampling intervals (benchmarks/fig3 sweeps
this on traces).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# future-state codes
UN_WD = 0
WD_FREQ_L = 1
WD_FREQ_H = 2

WINDOW_LEN = 8   # paper default (Fig. 3 knee)
K_LEN = 3        # Reverse suffix length (Fig. 4 case_4 shows a 3-long suffix)
HI_THRESH = 6    # popcount >= 6 of 8 -> WD_FREQ_H (case_1: 7 ones)
LO_THRESH = 2    # popcount >= 2 -> WD_FREQ_L (case_3: 5 ones; case_2: 1 -> UN)


def push_history(hist: jnp.ndarray, wd_bit: jnp.ndarray, window_len: int = WINDOW_LEN) -> jnp.ndarray:
    """Shift a new WD observation (0/1) into the per-page history word.
    hist dtype must hold window_len bits (uint8 for <=8, uint16 beyond —
    the Fig. 3 sweep goes to 10)."""
    mask = jnp.asarray((1 << window_len) - 1, hist.dtype)
    return ((hist << 1) | wd_bit.astype(hist.dtype)) & mask


def popcount8(x: jnp.ndarray) -> jnp.ndarray:
    """Popcount (<=16-bit values) via SWAR bit manipulation."""
    x = x.astype(jnp.int32)
    x = x - ((x >> 1) & 0x5555)
    x = (x & 0x3333) + ((x >> 2) & 0x3333)
    x = (x + (x >> 4)) & 0x0F0F
    x = (x + (x >> 8)) & 0x001F
    return x.astype(jnp.int32)


@partial(jax.jit, static_argnames=("window_len", "k_len", "hi_thresh", "lo_thresh"))
def predict_future(
    hist: jnp.ndarray,
    *,
    window_len: int = WINDOW_LEN,
    k_len: int = K_LEN,
    hi_thresh: int = HI_THRESH,
    lo_thresh: int = LO_THRESH,
) -> jnp.ndarray:
    """Predict the future WD state per page. Returns int8 codes.

    hist: uint8 [n_pages] history bitfields (bit 0 = latest pass).
    """
    ones = popcount8(hist.astype(jnp.int32) & ((1 << window_len) - 1))
    base = jnp.where(
        ones >= hi_thresh,
        jnp.int8(WD_FREQ_H),
        jnp.where(ones >= lo_thresh, jnp.int8(WD_FREQ_L), jnp.int8(UN_WD)),
    )
    # Reverse rule on the K_Len-bit suffix (the latest k observations).
    k_mask = (1 << k_len) - 1
    suffix = hist.astype(jnp.int32) & k_mask
    all_wd = suffix == k_mask
    none_wd = suffix == 0
    # all-WD suffix forces WD_FREQ_H; all-cold suffix forces UN_WD.
    out = jnp.where(all_wd, jnp.int8(WD_FREQ_H), base)
    out = jnp.where(none_wd, jnp.int8(UN_WD), out)
    return out


def is_reverse(
    hist: jnp.ndarray,
    *,
    window_len: int = WINDOW_LEN,
    k_len: int = K_LEN,
    hi_thresh: int = HI_THRESH,
    lo_thresh: int = LO_THRESH,
) -> jnp.ndarray:
    """True where the Reverse rule overrode the whole-window majority
    ("the sampling window actually spans an Un_WD phase and a coming WD
    phase", Fig. 4 case_4 — majority view vs the K_Len suffix)."""
    ones = popcount8(hist.astype(jnp.int32) & ((1 << window_len) - 1))
    majority_wd = 2 * ones >= window_len
    k_mask = (1 << k_len) - 1
    suffix = hist.astype(jnp.int32) & k_mask
    return ((suffix == k_mask) & ~majority_wd) | \
        ((suffix == 0) & majority_wd)


def predict_trace(
    wd_trace: jnp.ndarray,
    *,
    window_len: int = WINDOW_LEN,
    k_len: int = K_LEN,
    horizon: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the predictor along a [T, n_pages] WD 0/1 trace.

    Returns (predictions [T, n_pages] int8, accuracy scalar) where a
    prediction at t is scored against the observed WD state at t+horizon:
    WD_FREQ_{H,L} counts as predicting WD=1, UN_WD as WD=0.  Used by the
    Fig. 3 reproduction benchmark.
    """
    T = wd_trace.shape[0]

    def step(hist, wd_t):
        hist = push_history(hist, wd_t, window_len)
        pred = predict_future(hist, window_len=window_len, k_len=k_len)
        return hist, pred

    hdt = jnp.uint8 if window_len <= 8 else jnp.uint16
    hist0 = jnp.zeros(wd_trace.shape[1], dtype=hdt)
    _, preds = jax.lax.scan(step, hist0, wd_trace)

    if T <= horizon + window_len:
        return preds, jnp.float32(0.0)
    # score predictions made after warm-up against the state `horizon` ahead
    pred_bin = (preds[window_len : T - horizon] != UN_WD).astype(jnp.int32)
    actual = wd_trace[window_len + horizon :].astype(jnp.int32)
    acc = jnp.mean((pred_bin == actual).astype(jnp.float32))
    return preds, acc
