"""Memory-pattern classification (paper Sec. 3).

Pure, vectorized, jittable functions over per-page counter arrays.

Definitions (paper Sec. 3.1, footnote 1):
  * write operations carry weight 2 (write latency >= 2x read on NVM)
  * WD (Write-Domain):  2 * writes >= reads   (and the page was touched)
  * RD (Read-Domain):   reads > 2 * writes    (and the page was touched)
  * cold:               untouched in the sampling pass

Hotness (paper Sec. 4.2): a page is *hot* when most samplings in a pass
observe it accessed, i.e. access_count > samples / 2.

Reuse classes (paper Sec. 3.3 / Fig. 5):
  * THRASHING       : small and stable reuse interval (streaming look-ups)
  * FREQ_TOUCHED    : larger / unstable reuse interval, frequently accessed
  * RARELY_TOUCHED  : touched only sporadically
"""
from __future__ import annotations

import jax.numpy as jnp

# --- pattern codes (per-pass page state) ------------------------------------
COLD = 0
RD = 1
WD = 2

# --- reuse classes -----------------------------------------------------------
RARELY_TOUCHED = 0
FREQ_TOUCHED = 1
THRASHING = 2

WRITE_WEIGHT = 2  # empirical value from the paper (footnote 1)


def classify_wd(reads: jnp.ndarray, writes: jnp.ndarray) -> jnp.ndarray:
    """Per-page WD/RD/COLD code for one sampling pass.

    reads/writes: integer arrays [n_pages] of operation counts in the pass.
    Returns int8 [n_pages] in {COLD, RD, WD}.
    """
    touched = (reads + writes) > 0
    is_wd = (WRITE_WEIGHT * writes) >= reads
    code = jnp.where(is_wd, WD, RD).astype(jnp.int8)
    return jnp.where(touched, code, jnp.int8(COLD))


def classify_hot(access_count: jnp.ndarray, pass_samples: jnp.ndarray | int) -> jnp.ndarray:
    """Hot iff the page was seen accessed in most samplings of the pass."""
    return access_count * 2 > pass_samples


def hotness_score(access_count: jnp.ndarray, writes: jnp.ndarray) -> jnp.ndarray:
    """Ranking key for the hotness list (HL).

    Paper Fig. 10 step 3 ranks by access frequency; we fold in the weighted
    write count so a WD page of equal frequency sorts above an RD one, which
    keeps the ranking consistent with the WD-first migration priority.
    """
    return access_count.astype(jnp.float32) + 0.5 * jnp.minimum(
        writes.astype(jnp.float32), access_count.astype(jnp.float32)
    )


def classify_reuse(
    intv_cnt: jnp.ndarray,
    intv_sum: jnp.ndarray,
    intv_sqsum: jnp.ndarray,
    pass_samples: jnp.ndarray | int,
    *,
    thrash_mean_max: float = 4.0,
    thrash_std_max: float = 2.0,
    rare_count_frac: float = 0.05,
) -> jnp.ndarray:
    """Reuse class per page from online interval stats (paper Fig. 5).

    intv_cnt    : number of observed reuse intervals in the pass
    intv_sum    : sum of interval lengths (in samplings)
    intv_sqsum  : sum of squared interval lengths

    THRASHING      <- mean interval small AND stable (low std)
    RARELY_TOUCHED <- touched in < rare_count_frac of samplings
    FREQ_TOUCHED   <- everything else that is touched repeatedly
    """
    cnt = jnp.maximum(intv_cnt, 1)
    mean = intv_sum / cnt
    var = jnp.maximum(intv_sqsum / cnt - mean * mean, 0.0)
    std = jnp.sqrt(var)

    rare = intv_cnt < jnp.maximum(rare_count_frac * pass_samples, 1.0)
    thrash = (~rare) & (mean <= thrash_mean_max) & (std <= thrash_std_max)
    out = jnp.where(thrash, THRASHING, FREQ_TOUCHED).astype(jnp.int8)
    return jnp.where(rare, jnp.int8(RARELY_TOUCHED), out)


def bank_imbalance(bank_freq: jnp.ndarray) -> jnp.ndarray:
    """Std-dev of per-bank hot-page counts — the paper's imbalance metric
    (Fig. 6 / Fig. 15: 'standard deviation of the number of active pages
    between hottest and coldest banks')."""
    f = bank_freq.astype(jnp.float32)
    return jnp.std(f)
