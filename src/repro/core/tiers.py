"""TierStore — the hybrid fast/slow page store (MCHA analogue, Sec. 5.1).

Logical pages live in one of two physical pools:

  * FAST — device HBM (a jax array pool; on this CPU host it is a jax
    CpuDevice buffer, on TPU it is HBM);
  * SLOW — host DRAM (numpy pool; the NVM-channel analogue; optionally
    int8-quantized to model NVM's cheap-read/expensive-write asymmetry).

A page table maps logical page -> (tier, slot); per-page version counters
are bumped by every write so the optimistic (unlocked-DMA) migration path
can detect pages dirtied mid-copy, exactly like the paper's post-hoc
dirty-bit check (Sec. 6.3).

Slot allocation inside each pool goes through the color-aware SubBuddy
allocator so bank/slab-targeted placement (Algorithm 2) is honored.

NVM wear telemetry (Sec. 7.1): slow-pool slot ids handed out by the
allocator are *logical*; the ``repro.nvm`` wear tracker maps them to
physical rows through a remap table, charges a per-physical-slot write
counter on every slow-tier write (single-page and batched paths alike —
this is where migration demotion commits get accounted), and lets the
Start-Gap leveler rotate the physical rows without the allocator, page
table, or migration engines noticing.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.page_gather import page_gather, page_scatter

from .allocator import SubBuddyAllocator, SubBuddyConfig
from .placement import FAST, SLOW

NO_SLOT = -1


@dataclass
class TierConfig:
    n_pages: int                 # logical page count
    fast_slots: int              # HBM pool capacity (pages)
    slow_slots: int              # host pool capacity (pages)
    page_shape: tuple[int, ...]  # payload shape per page
    dtype: jnp.dtype = jnp.float32
    n_banks: int = 32
    n_slabs: int = 16
    quantize_slow: bool = False  # int8-quantize cold pages (soft-NVM analogue)
    track_wear: bool = True      # per-slot NVM wear counters (Sec. 7.1)
    wear_leveling: bool = True   # Start-Gap rotation over the slow pool
    gap_write_interval: int | None = None  # None -> costmodel 95% target


class TierStore:
    def __init__(self, cfg: TierConfig):
        # clamp the color geometry so every color exists in both pools
        # (the PFN space always contains all colors; a slot pool only does
        # when n_colors <= n_slots).
        n_banks, n_slabs = cfg.n_banks, cfg.n_slabs
        min_slots = min(cfg.fast_slots, cfg.slow_slots)
        while n_banks * n_slabs > max(min_slots, 1) and n_banks > 1:
            n_banks //= 2
        while n_banks * n_slabs > max(min_slots, 1) and n_slabs > 1:
            n_slabs //= 2
        if (n_banks, n_slabs) != (cfg.n_banks, cfg.n_slabs):
            from dataclasses import replace
            cfg = replace(cfg, n_banks=n_banks, n_slabs=n_slabs)
        self.cfg = cfg
        self.fast_pool = jnp.zeros((cfg.fast_slots, *cfg.page_shape), cfg.dtype)
        if cfg.quantize_slow:
            self.slow_pool = np.zeros((cfg.slow_slots, *cfg.page_shape), np.int8)
            self.slow_scale = np.ones((cfg.slow_slots,), np.float32)
        else:
            self.slow_pool = np.zeros((cfg.slow_slots, *cfg.page_shape),
                                      np.dtype(jnp.dtype(cfg.dtype).name)
                                      if cfg.dtype != jnp.bfloat16 else np.float32)
            self.slow_scale = None
        self.tier = np.full((cfg.n_pages,), SLOW, np.int8)
        self.slot = np.full((cfg.n_pages,), NO_SLOT, np.int64)
        self.version = np.zeros((cfg.n_pages,), np.int64)
        bcfg = dict(n_banks=cfg.n_banks, n_slabs=cfg.n_slabs)
        self.alloc = {
            FAST: SubBuddyAllocator(SubBuddyConfig(cfg.fast_slots, **bcfg)),
            SLOW: SubBuddyAllocator(SubBuddyConfig(cfg.slow_slots, **bcfg)),
        }
        # bytes moved per tier-direction, for the bandwidth balancer / figs
        self.traffic = {(FAST, SLOW): 0, (SLOW, FAST): 0}
        self.writes_to = {FAST: 0, SLOW: 0}
        self.reads_from = {FAST: 0, SLOW: 0}
        # NVM wear telemetry + Start-Gap leveling over the slow pool
        # (lazy import: repro.nvm pulls in the cost model, which sits next
        # to this module in the core package)
        self.wear = self.leveler = None
        if cfg.track_wear:
            from repro.nvm.leveling import StartGapLeveler
            from repro.nvm.wear import NvmWear
            self.wear = NvmWear(cfg.slow_slots)
            if cfg.wear_leveling:
                self.leveler = StartGapLeveler(self.wear,
                                               cfg.gap_write_interval)

    # -- page lifecycle -----------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        return int(np.prod(self.cfg.page_shape)) * jnp.dtype(self.cfg.dtype).itemsize

    def allocate(self, page: int, tier: int, color: int | None = None,
                 color_mask: int | None = None) -> bool:
        """Bind a logical page to a fresh slot in ``tier``."""
        assert self.slot[page] == NO_SLOT, f"page {page} already allocated"
        s = self.alloc[tier].alloc(0, color, color_mask)
        if s is None:
            return False
        self.tier[page] = tier
        self.slot[page] = s
        return True

    def release(self, page: int) -> None:
        s = int(self.slot[page])
        if s != NO_SLOT:
            self.alloc[int(self.tier[page])].free(s, 0)
            self.slot[page] = NO_SLOT

    # -- data access ----------------------------------------------------------
    def write_page(self, page: int, value) -> None:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        if t == FAST:
            self.fast_pool = self.fast_pool.at[s].set(
                jnp.asarray(value, self.cfg.dtype))
        else:
            self._slow_write(s, np.asarray(value, np.float32))
        self.version[page] += 1
        self.writes_to[t] += 1

    def read_page(self, page: int) -> np.ndarray:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        self.reads_from[t] += 1
        if t == FAST:
            return np.asarray(self.fast_pool[s], np.float32)
        return self._slow_read(s)

    def _phys_slow(self, slots: np.ndarray) -> np.ndarray:
        """Logical slow-pool slots -> physical rows (wear-leveling remap)."""
        return slots if self.wear is None else self.wear.phys(slots)

    def _account_slow_writes(self, phys: np.ndarray) -> None:
        """Charge wear counters and drive the Start-Gap leveler after data
        has landed on the given physical rows."""
        if self.wear is None:
            return
        self.wear.record_phys(phys)
        if self.leveler is not None:
            self.leveler.note_writes(self, np.asarray(phys).size)

    def _slow_write(self, slot: int, value: np.ndarray) -> None:
        p = slot if self.wear is None else self.wear.phys_one(slot)
        if self.cfg.quantize_slow:
            scale = max(float(np.max(np.abs(value))), 1e-8) / 127.0
            self.slow_pool[p] = np.clip(
                np.round(value / scale), -127, 127).astype(np.int8)
            self.slow_scale[p] = scale
        else:
            self.slow_pool[p] = value
        self._account_slow_writes(np.asarray([p]))

    def _slow_read(self, slot: int) -> np.ndarray:
        p = slot if self.wear is None else self.wear.phys_one(slot)
        if self.cfg.quantize_slow:
            return self.slow_pool[p].astype(np.float32) * self.slow_scale[p]
        return np.asarray(self.slow_pool[p], np.float32)

    # -- batched data access (the migration engine's bulk primitives) ----------
    def gather_fast(self, slots) -> jnp.ndarray:
        """Pack discontiguous fast-pool slots into one contiguous staging
        buffer on device (Pallas page_gather on TPU, XLA gather elsewhere)."""
        return page_gather(self.fast_pool, jnp.asarray(slots, jnp.int32))

    def scatter_fast(self, slots, pages: jnp.ndarray) -> None:
        """pool[slots[i]] = pages[i]; the pool buffer is donated, slots not
        referenced pass through untouched."""
        self.fast_pool = page_scatter(
            self.fast_pool, jnp.asarray(slots, jnp.int32),
            pages.astype(self.cfg.dtype))

    def slow_read_batch(self, slots: np.ndarray) -> np.ndarray:
        """[k, *page_shape] float32 view of slow-pool slots (vectorized
        dequantize for the soft-NVM tier)."""
        slots = self._phys_slow(np.asarray(slots, np.int64))
        if self.cfg.quantize_slow:
            pages = self.slow_pool[slots].astype(np.float32)
            scale = self.slow_scale[slots].reshape(
                (-1,) + (1,) * len(self.cfg.page_shape))
            return pages * scale
        return np.asarray(self.slow_pool[slots], np.float32)

    def slow_write_batch(self, slots: np.ndarray, values: np.ndarray) -> None:
        """slow_pool[slots[i]] = values[i], quantizing per page when the
        slow tier is int8 (bit-identical to the per-page _slow_write)."""
        slots = self._phys_slow(np.asarray(slots, np.int64))
        values = np.asarray(values, np.float32)
        if self.cfg.quantize_slow:
            axes = tuple(range(1, values.ndim))
            scale = np.maximum(np.max(np.abs(values), axis=axes), 1e-8) / 127.0
            q = np.clip(np.round(values / scale.reshape(
                (-1,) + (1,) * len(self.cfg.page_shape))), -127, 127)
            self.slow_pool[slots] = q.astype(np.int8)
            self.slow_scale[slots] = scale.astype(np.float32)
        else:
            self.slow_pool[slots] = values
        self._account_slow_writes(slots)

    def charge_fast_accesses(self, page_writes: np.ndarray,
                             n_reads: int) -> None:
        """Apply one decode dispatch's fast-tier access accounting in bulk:
        ``page_writes`` (int [n_pages], computed on device inside the fused
        step) bumps the per-page version counters (the dirty bit for
        optimistic migration) and the tier write counter; ``n_reads`` is the
        dispatch's total page-read count.  One vectorized add instead of a
        per-request Python loop per token."""
        page_writes = np.asarray(page_writes, np.int64)
        self.version += page_writes
        self.writes_to[FAST] += int(page_writes.sum())
        self.reads_from[FAST] += int(n_reads)

    def commit_moves(self, pages: np.ndarray, dst_tier: int,
                     new_slots: np.ndarray) -> None:
        """Flip the page table for an executed bulk move: free the old slots,
        bind the new ones, account traffic — one vectorized pass over the
        tier/slot arrays (the allocator free loop is host metadata only)."""
        pages = np.asarray(pages, np.int64)
        new_slots = np.asarray(new_slots, np.int64)
        if pages.size == 0:
            return
        src_tier = FAST if dst_tier == SLOW else SLOW
        assert (self.tier[pages] == src_tier).all(), \
            "commit_moves: page not in the expected source tier"
        for s in self.slot[pages]:
            self.alloc[src_tier].free(int(s), 0)
        self.tier[pages] = dst_tier
        self.slot[pages] = new_slots
        self.traffic[(src_tier, dst_tier)] += self.page_nbytes * pages.size

    # -- migration primitive (single page, already-planned) --------------------
    def move_page(self, page: int, dst_tier: int, color: int | None = None,
                  color_mask: int | None = None) -> bool:
        """Synchronous ('locked CPU copy') single-page move."""
        src_tier = int(self.tier[page])
        if src_tier == dst_tier:
            return True
        if int(self.slot[page]) == NO_SLOT:
            return False                   # released page: nothing to move
        data = self.read_page(page)
        new_slot = self.alloc[dst_tier].alloc(0, color, color_mask)
        if new_slot is None and color is not None:
            # Algorithm 2 exhausted its slab walk: fall back to any color
            # rather than dropping the migration (capacity is the real bound).
            new_slot = self.alloc[dst_tier].alloc(0, None)
        if new_slot is None:
            return False
        old_slot = int(self.slot[page])
        if dst_tier == FAST:
            self.fast_pool = self.fast_pool.at[new_slot].set(
                jnp.asarray(data, self.cfg.dtype))
        else:
            self._slow_write(new_slot, data)
        self.alloc[src_tier].free(old_slot, 0)
        self.tier[page] = dst_tier
        self.slot[page] = new_slot
        self.traffic[(src_tier, dst_tier)] += self.page_nbytes
        return True

    def occupancy(self) -> dict:
        fast_used = int(np.sum(self.tier[self.slot != NO_SLOT] == FAST))
        slow_used = int(np.sum(self.tier[self.slot != NO_SLOT] == SLOW))
        return {
            "fast_used": fast_used, "fast_total": self.cfg.fast_slots,
            "slow_used": slow_used, "slow_total": self.cfg.slow_slots,
        }
