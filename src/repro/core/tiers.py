"""TierStore — the N-tier hybrid page store (MCHA analogue, Sec. 5.1).

Logical pages live in one of the pools described by a
:class:`~repro.core.hierarchy.MemoryHierarchy` — an ordered list of
:class:`~repro.core.hierarchy.MediumSpec` tiers (fastest first):

  * **device** tiers — one jax array pool each (tier 0 is HBM and is what
    compute reads from; additional device tiers simulate e.g. a DRAM
    channel while keeping migration on-accelerator);
  * **host** tiers — numpy pools (the NVM/CXL analogue), optionally
    int8-quantized to model NVM's cheap-read/expensive-write asymmetry,
    and storing bfloat16 payloads as their uint16 bit-pattern (no silent
    widening to float32);
  * **pinned_host** tiers — host-capacity jax pools addressable from
    device code: migrations donate the buffer instead of staging numpy
    copies, int8 quantization fuses into the gather/scatter dispatch,
    and the fused serving dispatch appends KV and charges wear counters
    into them directly.

A page table maps logical page -> (tier, slot); per-page version counters
are bumped by every write so the optimistic (unlocked-DMA) migration path
can detect pages dirtied mid-copy, exactly like the paper's post-hoc
dirty-bit check (Sec. 6.3).

Slot allocation inside every pool goes through a per-tier color-aware
SubBuddy allocator so bank/slab-targeted placement (Algorithm 2) is
honored in each tier independently.

NVM wear telemetry (Sec. 7.1) attaches to **any** host tier whose spec
sets ``wear_tracked``: slot ids handed out by that tier's allocator are
*logical*; a per-tier ``repro.nvm`` wear tracker maps them to physical
rows through a remap table, charges a per-physical-slot write counter on
every write (single-page and batched paths alike — migration demotion
commits included), and lets a per-tier Start-Gap leveler rotate the
physical rows without the allocator, page table, or migration engines
noticing.

``TierConfig`` survives as the two-tier compatibility shim: constructing
``TierStore(TierConfig(...))`` routes through
``MemoryHierarchy.two_tier(...)`` and reproduces the pre-redesign
fast/slow behavior bit for bit.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.injector import get_injector
from repro.faults.integrity import PageIntegrity
from repro.kernels.page_gather import (page_gather, page_gather_dequant,
                                       page_gather_quant, page_scatter,
                                       page_scatter_quant)

from .allocator import SubBuddyAllocator, SubBuddyConfig
from .hierarchy import MediumSpec, MemoryHierarchy

NO_SLOT = -1


@dataclass
class TierConfig:
    """Two-tier compatibility config (the pre-redesign API surface).

    Kept as a thin shim: ``TierStore`` converts it to
    ``MemoryHierarchy.two_tier(...)`` + :class:`StoreConfig`.  New code
    should build a :class:`StoreConfig` directly.
    """

    n_pages: int                 # logical page count
    fast_slots: int              # HBM pool capacity (pages)
    slow_slots: int              # host pool capacity (pages)
    page_shape: tuple[int, ...]  # payload shape per page
    dtype: jnp.dtype = jnp.float32
    n_banks: int | None = None   # None -> auto-size to the smallest pool
    n_slabs: int | None = None
    quantize_slow: bool = False  # int8-quantize cold pages (soft-NVM analogue)
    track_wear: bool = True      # per-slot NVM wear counters (Sec. 7.1)
    wear_leveling: bool = True   # Start-Gap rotation over the slow pool
    gap_write_interval: int | None = None  # None -> costmodel 95% target

    def hierarchy(self) -> MemoryHierarchy:
        return MemoryHierarchy.two_tier(
            self.fast_slots, self.slow_slots,
            quantize_slow=self.quantize_slow, track_wear=self.track_wear,
            wear_leveling=self.wear_leveling,
            gap_write_interval=self.gap_write_interval)


@dataclass
class StoreConfig:
    """Generic store config: a hierarchy plus the logical page space."""

    n_pages: int
    page_shape: tuple[int, ...]
    hierarchy: MemoryHierarchy
    dtype: jnp.dtype = jnp.float32
    # color geometry; None auto-sizes (up to 32 x 16) so every color
    # exists in the smallest pool — explicit values that don't fit are
    # clamped with a warning
    n_banks: int | None = None
    n_slabs: int | None = None

    # -- two-tier compat accessors (fast = tier 0, slow = deepest) -----------
    @property
    def fast_slots(self) -> int:
        return self.hierarchy[0].slots

    @property
    def slow_slots(self) -> int:
        return self.hierarchy[self.hierarchy.deepest].slots

    @property
    def quantize_slow(self) -> bool:
        return self.hierarchy[self.hierarchy.deepest].quantize_int8


def _shrink_to_fit(n_banks: int, n_slabs: int, slots: int) -> tuple[int, int]:
    """Halve banks, then slabs, until every color exists in a pool of
    ``slots`` pages (the PFN space always contains all colors; a slot
    pool only does when n_colors <= n_slots)."""
    while n_banks * n_slabs > max(slots, 1) and n_banks > 1:
        n_banks //= 2
    while n_banks * n_slabs > max(slots, 1) and n_slabs > 1:
        n_slabs //= 2
    return n_banks, n_slabs


def _clamp_geometry(cfg: StoreConfig) -> StoreConfig:
    """Resolve the *monitor* color geometry (SysMon's bank/slab frequency
    tables): the default (``n_banks``/``n_slabs`` = None) auto-sizes
    silently up to 32 x 16 so every color exists in the smallest pool; an
    *explicitly requested* geometry that can't fit everywhere is clamped
    with a warning — silently changing what the caller asked for hid real
    misconfigurations.  Each tier's *allocator* geometry is derived
    separately from its own ``MediumSpec.slots`` (see ``_tier_geometry``);
    ``target_color`` folds the monitor's frequency space onto each tier's
    allocator geometry."""
    explicit = cfg.n_banks is not None or cfg.n_slabs is not None
    want_banks = 32 if cfg.n_banks is None else cfg.n_banks
    want_slabs = 16 if cfg.n_slabs is None else cfg.n_slabs
    min_slots = min(t.slots for t in cfg.hierarchy)
    n_banks, n_slabs = _shrink_to_fit(want_banks, want_slabs, min_slots)
    if explicit and (n_banks, n_slabs) != (want_banks, want_slabs):
        warnings.warn(
            f"TierStore color geometry {want_banks}x{want_slabs} "
            f"(banks x slabs) exceeds the smallest pool "
            f"({min_slots} slots); monitor geometry clamped to "
            f"{n_banks}x{n_slabs} (each tier's allocator keeps its own "
            "geometry sized to its pool)",
            UserWarning, stacklevel=3)
    return replace(cfg, n_banks=n_banks, n_slabs=n_slabs)


def _tier_geometry(want_banks: int | None, want_slabs: int | None,
                   spec: MediumSpec) -> tuple[int, int]:
    """Per-tier allocator geometry derived from the tier's own capacity:
    the requested (or default 32x16) grid shrunk until every color exists
    in *this* tier's pool — a 64-slot HBM tier no longer forces a
    4096-slot NVM tier down to the same handful of colors."""
    return _shrink_to_fit(32 if want_banks is None else want_banks,
                          16 if want_slabs is None else want_slabs,
                          spec.slots)


# =============================================================================
# per-tier pools
# =============================================================================

def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _pad_idx_np(slots) -> np.ndarray:
    """Pad an index vector to the next power-of-two length by repeating
    its last entry — **in numpy**, before anything touches jax.

    Migration batch sizes are data-dependent, and every distinct
    gather/scatter length would otherwise compile its own XLA executable
    (including the padding concatenate itself, were it a jnp op) — pow2
    bucketing bounds the jit cache to log2(max) shapes.  A duplicated
    index is harmless: gathers just produce extra rows (staging buffers
    stay padded end-to-end; host copies slice in numpy), and scatters
    rewrite the same slot with the same value."""
    slots = np.asarray(slots, np.int64).reshape(-1)
    pad = _pow2(slots.size) - slots.size
    if pad:
        slots = np.concatenate([slots, np.repeat(slots[-1:], pad)])
    return slots


def _pad_pages(pages, k_padded: int):
    """Pad a page batch to match its padded index vector: numpy batches
    pad by repeating the last page; a jax batch must already be padded
    (it came out of a padded gather with the matching length)."""
    if pages.shape[0] == k_padded:
        return pages
    if isinstance(pages, np.ndarray):
        pad = k_padded - pages.shape[0]
        return np.concatenate([
            pages, np.repeat(pages[-1:], pad, axis=0)])
    raise ValueError(
        f"device page batch of {pages.shape[0]} rows does not match its "
        f"padded index vector ({k_padded}); pass staging buffers through "
        "unsliced, or pad on the host")


class DevicePool:
    """A jax-resident page pool ([slots, *page_shape] in the store dtype)."""

    def __init__(self, spec: MediumSpec, page_shape: tuple[int, ...], dtype):
        self.spec = spec
        self.dtype = dtype
        self.data = jnp.zeros((spec.slots, *page_shape), dtype)

    def write_one(self, slot: int, value) -> None:
        self.data = self.data.at[slot].set(jnp.asarray(value, self.dtype))

    def read_one(self, slot: int) -> np.ndarray:
        return np.asarray(self.data[slot], np.float32)

    def gather(self, slots) -> jnp.ndarray:
        """Pack discontiguous slots into one contiguous staging buffer on
        device (Pallas page_gather on TPU, XLA gather elsewhere).  The
        result is **pow2-padded** (trailing rows repeat the last page);
        host consumers slice to the true count in numpy."""
        idx = _pad_idx_np(slots)
        return page_gather(self.data, jnp.asarray(idx, jnp.int32))

    def scatter(self, slots, pages: jnp.ndarray) -> None:
        """pool[slots[i]] = pages[i]; the pool buffer is donated, slots
        not referenced pass through untouched.  ``pages`` may be the
        padded output of a matching-size gather, or an exact-count numpy
        batch (padded here)."""
        idx = _pad_idx_np(slots)
        pages = _pad_pages(pages, idx.size)
        self.data = page_scatter(self.data, jnp.asarray(idx, jnp.int32),
                                 jnp.asarray(pages).astype(self.dtype))


class HostPool:
    """A numpy page pool with the host-tier storage formats.

    float32/float64 payloads are stored natively; bfloat16 payloads are
    stored as their **uint16 bit-pattern** (bit-exact round trip, half the
    bytes — not silently widened to float32); ``quantize_int8`` stores
    int8 + a per-page scale (the lossy soft-NVM analogue).  All reads
    return float32.
    """

    def __init__(self, spec: MediumSpec, page_shape: tuple[int, ...], dtype):
        self.spec = spec
        self.page_shape = page_shape
        self.quantized = spec.quantize_int8
        self.bf16 = (not self.quantized) and jnp.dtype(dtype) == jnp.bfloat16
        self.scale = None
        if self.quantized:
            self.data = np.zeros((spec.slots, *page_shape), np.int8)
            self.scale = np.ones((spec.slots,), np.float32)
        elif self.bf16:
            self.data = np.zeros((spec.slots, *page_shape), np.uint16)
        else:
            self.data = np.zeros((spec.slots, *page_shape),
                                 np.dtype(jnp.dtype(dtype).name))

    def _bcast(self, scale: np.ndarray) -> np.ndarray:
        return scale.reshape((-1,) + (1,) * len(self.page_shape))

    def write_one(self, phys: int, value: np.ndarray) -> None:
        if self.quantized:
            scale = max(float(np.max(np.abs(value))), 1e-8) / 127.0
            self.data[phys] = np.clip(
                np.round(value / scale), -127, 127).astype(np.int8)
            self.scale[phys] = scale
        elif self.bf16:
            self.data[phys] = value.astype(jnp.bfloat16).view(np.uint16)
        else:
            self.data[phys] = value

    def read_one(self, phys: int) -> np.ndarray:
        if self.quantized:
            return self.data[phys].astype(np.float32) * self.scale[phys]
        if self.bf16:
            return self.data[phys].view(jnp.bfloat16).astype(np.float32)
        return np.asarray(self.data[phys], np.float32)

    def write_batch(self, phys: np.ndarray, values: np.ndarray) -> None:
        """pool[phys[i]] = values[i], quantizing per page when int8
        (bit-identical to the per-page write_one)."""
        if self.quantized:
            axes = tuple(range(1, values.ndim))
            scale = np.maximum(np.max(np.abs(values), axis=axes), 1e-8) / 127.0
            q = np.clip(np.round(values / self._bcast(scale)), -127, 127)
            self.data[phys] = q.astype(np.int8)
            self.scale[phys] = scale.astype(np.float32)
        elif self.bf16:
            self.data[phys] = values.astype(jnp.bfloat16).view(np.uint16)
        else:
            self.data[phys] = values

    def read_batch(self, phys: np.ndarray) -> np.ndarray:
        if self.quantized:
            return (self.data[phys].astype(np.float32)
                    * self._bcast(self.scale[phys]))
        if self.bf16:
            return self.data[phys].view(jnp.bfloat16).astype(np.float32)
        return np.asarray(self.data[phys], np.float32)

    def swap_rows(self, a: int, b: int) -> None:
        """Swap two physical rows in place (Start-Gap leveling advance)."""
        self.data[[a, b]] = self.data[[b, a]]
        if self.scale is not None:
            self.scale[[a, b]] = self.scale[[b, a]]


def _pin_host(x: jnp.ndarray) -> jnp.ndarray:
    """Place a jax array in pinned host memory where the backend supports
    memory kinds (TPU/GPU); plain default placement otherwise (on the CPU
    backend every buffer already lives in host RAM)."""
    try:
        dev = x.devices().pop() if hasattr(x, "devices") else jax.devices()[0]
        sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind="pinned_host")
        return jax.device_put(x, sharding)
    except (ValueError, NotImplementedError, TypeError):
        return x


class PinnedHostPool:
    """A host-capacity page pool addressable from device code.

    The pool is a single jax buffer placed in pinned host memory
    (``memory_kind="pinned_host"`` where the backend supports it, plain
    placement otherwise), so migration engines gather/scatter it inside
    the jax runtime — demotion commits *donate* the pool buffer through
    ``page_scatter`` instead of staging a numpy copy — and the fused
    serving dispatch can append KV into it and bump its wear counters
    without a host round trip.

    ``quantize_int8`` keeps the pool as int8 + per-page scale with the
    quantization fused into the gather/scatter dispatch
    (``page_gather_quant`` / ``page_scatter_quant``: one kernel instead
    of gather -> host -> numpy quantize).  Non-quantized pools store the
    store dtype natively (bf16 stays bf16 — no uint16 bit-pattern
    gymnastics needed, the buffer is a real jax array).
    """

    def __init__(self, spec: MediumSpec, page_shape: tuple[int, ...], dtype):
        self.spec = spec
        self.page_shape = page_shape
        self.dtype = dtype
        self.quantized = spec.quantize_int8
        self.scale = None
        if self.quantized:
            self.data = _pin_host(jnp.zeros((spec.slots, *page_shape),
                                            jnp.int8))
            self.scale = _pin_host(jnp.ones((spec.slots,), jnp.float32))
        else:
            self.data = _pin_host(jnp.zeros((spec.slots, *page_shape), dtype))

    # -- HostPool-compatible per-physical-slot API -----------------------------
    def write_one(self, phys: int, value: np.ndarray) -> None:
        self.write_batch(np.asarray([phys], np.int64), value[None])

    def read_one(self, phys: int) -> np.ndarray:
        return self.read_batch(np.asarray([phys], np.int64))[0]

    def write_batch(self, phys: np.ndarray, values: np.ndarray) -> None:
        self.scatter(phys, np.asarray(values, np.float32))

    def read_batch(self, phys: np.ndarray) -> np.ndarray:
        k = np.asarray(phys).size
        return np.asarray(self.gather(phys), np.float32)[:k]

    # -- device-addressable bulk API (jax in, jax out) -------------------------
    def gather(self, phys) -> jnp.ndarray:
        """Pow2-padded gather, like :meth:`DevicePool.gather` (fused
        dequantize for int8 pools)."""
        idx = jnp.asarray(_pad_idx_np(phys), jnp.int32)
        if self.quantized:
            return page_gather_dequant(self.data, self.scale, idx)
        return page_gather(self.data, idx)

    def scatter(self, phys, pages) -> None:
        """pool[phys[i]] = pages[i], pool buffer donated; fuses the int8
        quantize into the same dispatch for quantized pools."""
        idx = _pad_idx_np(phys)
        pages = _pad_pages(pages, idx.size)
        idx = jnp.asarray(idx, jnp.int32)
        if self.quantized:
            self.data, self.scale = page_scatter_quant(
                self.data, self.scale, idx,
                jnp.asarray(pages).astype(jnp.float32))
        else:
            self.data = page_scatter(self.data, idx,
                                     jnp.asarray(pages).astype(self.dtype))

    def swap_rows(self, a: int, b: int) -> None:
        pair = jnp.asarray([a, b], jnp.int32)
        rev = jnp.asarray([b, a], jnp.int32)
        self.data = self.data.at[pair].set(self.data[rev])
        if self.scale is not None:
            self.scale = self.scale.at[pair].set(self.scale[rev])


class _LevelerView:
    """Adapter handing ``StartGapLeveler`` one host tier's pool (the
    leveler's ``slow_pool``/``slow_scale`` contract predates N tiers)."""

    def __init__(self, pool: HostPool | PinnedHostPool):
        self._pool = pool

    @property
    def slow_pool(self) -> np.ndarray:
        return self._pool.data

    @property
    def slow_scale(self) -> np.ndarray | None:
        return self._pool.scale

    def swap_rows(self, a: int, b: int) -> None:
        self._pool.swap_rows(a, b)


# =============================================================================
# the store
# =============================================================================

class TierStore:
    def __init__(self, cfg: TierConfig | StoreConfig):
        if isinstance(cfg, TierConfig):
            cfg = StoreConfig(n_pages=cfg.n_pages, page_shape=cfg.page_shape,
                              hierarchy=cfg.hierarchy(), dtype=cfg.dtype,
                              n_banks=cfg.n_banks, n_slabs=cfg.n_slabs)
        want_banks, want_slabs = cfg.n_banks, cfg.n_slabs   # pre-clamp ask
        cfg = _clamp_geometry(cfg)
        self.cfg = cfg
        self.hierarchy = cfg.hierarchy
        self.n_tiers = cfg.hierarchy.n_tiers

        def make_pool(t: MediumSpec):
            if t.is_device:
                return DevicePool(t, cfg.page_shape, cfg.dtype)
            if t.is_pinned:
                return PinnedHostPool(t, cfg.page_shape, cfg.dtype)
            return HostPool(t, cfg.page_shape, cfg.dtype)

        self.pools: list[DevicePool | HostPool | PinnedHostPool] = [
            make_pool(t) for t in cfg.hierarchy
        ]
        # pages start (unallocated) in the deepest tier, as in the paper's
        # everything-begins-on-NVM bring-up
        self.tier = np.full((cfg.n_pages,), cfg.hierarchy.deepest, np.int8)
        self.slot = np.full((cfg.n_pages,), NO_SLOT, np.int64)
        self.version = np.zeros((cfg.n_pages,), np.int64)
        # incremental dirty set (async memos validation): while an epoch
        # is open, every page whose version/tier/slot changes is recorded
        # here, so a commit validates in O(dirtied pages) instead of
        # re-reading the whole version array per planned page.  Tracking
        # is off outside an epoch — synchronous-only runs pay one branch.
        self._dirty_tracking = False
        self._dirty_pages: set[int] = set()
        # per-tier allocator geometry derived from each tier's own slots
        # (the monitor geometry in cfg.n_banks/n_slabs stays global)
        self.alloc = [SubBuddyAllocator(SubBuddyConfig(
            t.slots, *_tier_geometry(want_banks, want_slabs, t)))
            for t in cfg.hierarchy]
        # bytes moved per (src, dst) tier pair, for the balancer / figs;
        # _traffic_snap marks the last memos-pass boundary so spill/cascade
        # targeting can rank tiers by bandwidth headroom over the current
        # window (roll_traffic_window)
        self.traffic = {(i, j): 0 for i in range(self.n_tiers)
                        for j in range(self.n_tiers) if i != j}
        self._traffic_snap = dict(self.traffic)
        self.writes_to = {t: 0 for t in range(self.n_tiers)}
        self.reads_from = {t: 0 for t in range(self.n_tiers)}
        # per-tier NVM wear telemetry + Start-Gap leveling (host tiers with
        # wear_tracked set; lazy import — repro.nvm pulls in the cost model,
        # which sits next to this module in the core package)
        self.wear_by_tier: dict[int, object] = {}
        self.leveler_by_tier: dict[int, object] = {}
        for i in cfg.hierarchy.wear_tiers():
            from repro.nvm.leveling import StartGapLeveler
            from repro.nvm.wear import NvmWear
            spec = cfg.hierarchy[i]
            self.wear_by_tier[i] = NvmWear(spec.slots)
            if spec.wear_leveling:
                self.leveler_by_tier[i] = StartGapLeveler(
                    self.wear_by_tier[i], spec.gap_write_interval)
        # page integrity + bad-slot quarantine (armed only while the
        # global fault injector is — zero-cost dead branches otherwise)
        self.integrity = PageIntegrity(enabled=get_injector().enabled)
        self.quarantined: dict[int, set[int]] = {
            t: set() for t in range(self.n_tiers)}
        # pages unbound by a quarantine since the last drain; the serving
        # engine reads this back to fail the owning sequences cleanly
        self.quarantine_log: list[int] = []

    # -- two-tier compat surface ----------------------------------------------
    @property
    def fast_pool(self) -> jnp.ndarray:
        """Tier-0 device pool buffer (what the serving engine computes on)."""
        return self.pools[0].data

    @fast_pool.setter
    def fast_pool(self, value: jnp.ndarray) -> None:
        self.pools[0].data = value

    @property
    def _deepest_wear(self) -> int | None:
        wt = self.hierarchy.wear_tiers()
        return wt[-1] if wt else None

    @property
    def wear(self):
        """Deepest wear-tracked tier's tracker (two-tier compat alias)."""
        t = self._deepest_wear
        return None if t is None else self.wear_by_tier[t]

    @property
    def leveler(self):
        t = self._deepest_wear
        return self.leveler_by_tier.get(t) if t is not None else None

    @property
    def slow_pool(self) -> np.ndarray:
        """Deepest tier's raw pool array (compat; host tiers only)."""
        return self.pools[-1].data

    @property
    def slow_scale(self) -> np.ndarray | None:
        return self.pools[-1].scale

    # -- tier predicates -------------------------------------------------------
    def is_device_tier(self, tier: int) -> bool:
        return self.hierarchy[tier].is_device

    def is_pinned_tier(self, tier: int) -> bool:
        return self.hierarchy[tier].is_pinned

    def is_addressable_tier(self, tier: int) -> bool:
        """Device code can gather/scatter this tier's pool directly
        (device tiers and pinned-host tiers)."""
        return self.hierarchy[tier].is_device_addressable

    # -- dirty-set epochs (async memos validation) -----------------------------
    def begin_dirty_epoch(self) -> None:
        """Start recording pages whose plan-invalidating state changes:
        placement (tier/slot — allocate, release, moves) and external
        content writes (``write_page`` / ``bump_version``).  Opened when
        an async memos pass snapshots the store; the commit reads the set
        back and only those pages can be stale — the O(dirtied)
        replacement for replaying the whole version array.  Dispatch
        access charges are excluded by design: they account in-place
        appends that a commit-time migration re-reads anyway."""
        self._dirty_pages.clear()
        self._dirty_tracking = True

    def end_dirty_epoch(self) -> set[int]:
        """Stop recording and return the pages dirtied since
        :meth:`begin_dirty_epoch`."""
        self._dirty_tracking = False
        dirty, self._dirty_pages = self._dirty_pages, set()
        return dirty

    def _mark_dirty(self, pages) -> None:
        if self._dirty_tracking:
            self._dirty_pages.update(int(p) for p in np.atleast_1d(pages))

    def _mark_dirty_one(self, page: int) -> None:
        if self._dirty_tracking:
            self._dirty_pages.add(int(page))

    def bump_version(self, page: int) -> None:
        """Advance a page's version counter (the optimistic-migration
        dirty bit) through the store, so an open dirty epoch sees it.
        External writers (and conflict-injection test hooks) must use
        this instead of poking ``store.version`` directly."""
        self.version[page] += 1
        self._mark_dirty_one(page)

    # -- page lifecycle -----------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        return int(np.prod(self.cfg.page_shape)) * jnp.dtype(self.cfg.dtype).itemsize

    def allocate(self, page: int, tier: int, color: int | None = None,
                 color_mask: int | None = None) -> bool:
        """Bind a logical page to a fresh slot in ``tier``."""
        assert self.slot[page] == NO_SLOT, f"page {page} already allocated"
        inj = get_injector()
        if inj.enabled and inj.maybe_alloc_fail(tier):
            return False               # injected pool-exhaustion pressure
        s = self.alloc[tier].alloc(0, color, color_mask)
        if s is None:
            return False
        self.tier[page] = tier
        self.slot[page] = s
        self._mark_dirty_one(page)
        return True

    def release(self, page: int) -> None:
        s = int(self.slot[page])
        if s != NO_SLOT:
            t = int(self.tier[page])
            self.alloc[t].free(s, 0)
            self.integrity.drop(t, [s])
            self.slot[page] = NO_SLOT
            self._mark_dirty_one(page)

    def quarantine_slot(self, tier: int, slot: int,
                        reason: str = "") -> bool:
        """Retire a failing slot: permanently withhold it from the tier's
        allocator, unbind any page living in it (recorded in
        ``quarantine_log`` so the serving engine can fail the owner
        cleanly), and drop its checksum.  Returns False if the slot was
        already quarantined or no longer allocated."""
        slot = int(slot)
        if slot in self.quarantined[tier]:
            return False
        if not self.alloc[tier].retire(slot):
            return False               # freed since detection: nothing to do
        self.quarantined[tier].add(slot)
        self.integrity.drop(tier, [slot])
        pages = np.nonzero((self.tier == tier) & (self.slot == slot))[0]
        for p in pages:
            self.slot[p] = NO_SLOT     # page is gone, not just cold
            self._mark_dirty_one(int(p))
            self.quarantine_log.append(int(p))
        from repro import obs
        from repro.faults.injector import note_recovered
        reg = obs.get_registry()
        reg.counter("faults.quarantined_slots",
                    "slots retired by quarantine").inc()
        note_recovered("quarantine")
        return True

    # -- data access ----------------------------------------------------------
    def write_page(self, page: int, value) -> None:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        if self.is_device_tier(t):
            self.pools[t].write_one(s, value)
        else:
            self._host_write(t, s, np.asarray(value, np.float32))
        self.bump_version(page)
        self.writes_to[t] += 1

    def read_page(self, page: int) -> np.ndarray:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        self.reads_from[t] += 1
        if self.is_device_tier(t):
            return self.pools[t].read_one(s)
        return self._host_read(t, s)

    # -- host-tier access (wear remap + accounting) ----------------------------
    def _phys(self, tier: int, slots: np.ndarray) -> np.ndarray:
        """Logical host-pool slots -> physical rows (wear-leveling remap)."""
        w = self.wear_by_tier.get(tier)
        return slots if w is None else w.phys(slots)

    def _account_host_writes(self, tier: int, phys: np.ndarray) -> None:
        """Charge wear counters and drive the tier's Start-Gap leveler
        after data has landed on the given physical rows."""
        w = self.wear_by_tier.get(tier)
        if w is None:
            return
        w.record_phys(phys)
        lv = self.leveler_by_tier.get(tier)
        if lv is not None:
            lv.note_writes(_LevelerView(self.pools[tier]),
                           np.asarray(phys).size)

    def note_leveling_writes(self, tier: int, n: int) -> None:
        """Drive ``tier``'s Start-Gap leveler for ``n`` demand writes that
        were charged elsewhere (the fused dispatch counts pinned-tier KV
        appends on device; the leveler itself only advances at dispatch
        boundaries, on the host)."""
        lv = self.leveler_by_tier.get(tier)
        if lv is not None and n:
            lv.note_writes(_LevelerView(self.pools[tier]), int(n))

    def _host_write(self, tier: int, slot: int, value: np.ndarray) -> None:
        w = self.wear_by_tier.get(tier)
        p = slot if w is None else w.phys_one(slot)
        self.pools[tier].write_one(p, value)
        self._account_host_writes(tier, np.asarray([p]))
        self.integrity.record(self, tier, [slot])

    def _host_read(self, tier: int, slot: int) -> np.ndarray:
        w = self.wear_by_tier.get(tier)
        p = slot if w is None else w.phys_one(slot)
        return self.pools[tier].read_one(p)

    # -- batched data access (the migration engine's bulk primitives) ----------
    def gather_device(self, tier: int, slots) -> jnp.ndarray:
        """Pack a device-addressable tier's (logical) slots into one
        contiguous jax staging buffer.  Pinned-host tiers translate
        through the wear remap and fuse dequantization into the gather."""
        if self.is_pinned_tier(tier):
            phys = self._phys(tier, np.asarray(slots, np.int64))
            return self.pools[tier].gather(phys)
        return self.pools[tier].gather(slots)

    def scatter_device(self, tier: int, slots, pages: jnp.ndarray) -> None:
        """pool[slots[i]] = pages[i] on a device-addressable tier (pool
        donated).  Pinned-host tiers go through the wear remap, fuse int8
        quantization into the same dispatch, and charge wear counters —
        the demotion commit donates the slow pool instead of copying."""
        if self.is_pinned_tier(tier):
            phys = self._phys(tier, np.asarray(slots, np.int64))
            self.pools[tier].scatter(phys, pages)
            self._account_host_writes(tier, phys)
            self.integrity.record(self, tier, slots)
            return
        self.pools[tier].scatter(slots, pages)

    # tier-0 compat names (the serving hot path's pool primitives)
    def gather_fast(self, slots) -> jnp.ndarray:
        return self.gather_device(0, slots)

    def scatter_fast(self, slots, pages: jnp.ndarray) -> None:
        self.scatter_device(0, slots, pages)

    def host_read_batch(self, tier: int, slots: np.ndarray) -> np.ndarray:
        """[k, *page_shape] float32 view of a host tier's slots (vectorized
        dequantize for int8 soft-NVM tiers)."""
        phys = self._phys(tier, np.asarray(slots, np.int64))
        return self.pools[tier].read_batch(phys)

    def host_write_batch(self, tier: int, slots: np.ndarray,
                         values: np.ndarray) -> None:
        """pool[slots[i]] = values[i] on a host tier (bit-identical to the
        per-page path), charging wear where tracked."""
        phys = self._phys(tier, np.asarray(slots, np.int64))
        self.pools[tier].write_batch(phys, np.asarray(values, np.float32))
        self._account_host_writes(tier, phys)
        self.integrity.record(self, tier, slots)

    # deepest-tier compat names
    def slow_read_batch(self, slots: np.ndarray) -> np.ndarray:
        return self.host_read_batch(self.n_tiers - 1, slots)

    def slow_write_batch(self, slots: np.ndarray, values: np.ndarray) -> None:
        self.host_write_batch(self.n_tiers - 1, slots, values)

    def charge_fast_accesses(self, page_writes: np.ndarray,
                             n_reads: int) -> None:
        """Apply one decode dispatch's tier-0 access accounting in bulk:
        ``page_writes`` (int [n_pages], computed on device inside the fused
        step) bumps the per-page version counters (the dirty bit for
        optimistic migration) and the tier write counter; ``n_reads`` is the
        dispatch's total page-read count.  One vectorized add instead of a
        per-request Python loop per token.

        Deliberately does NOT mark the pages dirty for an open async-plan
        epoch: these are the dispatch's own in-place appends — the page
        never leaves its slot, and a commit-boundary migration reads the
        bytes fresh (``execute_plan`` stages at execute time), so the
        plan stays valid.  External writers go through ``write_page`` /
        ``bump_version``, which do mark."""
        page_writes = np.asarray(page_writes, np.int64)
        self.version += page_writes
        self.writes_to[0] += int(page_writes.sum())
        self.reads_from[0] += int(n_reads)

    def charge_accesses(self, page_writes: np.ndarray,
                        page_reads: np.ndarray) -> None:
        """Apply one dispatch's access accounting split by residency:
        per-page write/read counts (computed on device / closed-form on
        host) bump the version counters and each page's *current* tier's
        read/write counters — the pinned-serving dispatch touches both
        the tier-0 pool and the pinned deepest tier, so the charge can't
        assume tier 0 like ``charge_fast_accesses``.  Like that method,
        it does not dirty an open async-plan epoch — in-place dispatch
        appends never invalidate a pending plan."""
        page_writes = np.asarray(page_writes, np.int64)
        page_reads = np.asarray(page_reads, np.int64)
        self.version += page_writes
        for t in range(self.n_tiers):
            m = self.tier == t
            w = int(page_writes[m].sum())
            r = int(page_reads[m].sum())
            if w:
                self.writes_to[t] += w
            if r:
                self.reads_from[t] += r

    # -- bandwidth headroom (spill / cascade targeting) ------------------------
    def roll_traffic_window(self) -> None:
        """Mark a pass boundary for the per-tier inflow window."""
        self._traffic_snap = dict(self.traffic)

    def tier_inflow_bytes(self, tier: int) -> int:
        """Bytes that landed in ``tier`` since the last window roll."""
        return sum(self.traffic[(s, tier)] - self._traffic_snap[(s, tier)]
                   for s in range(self.n_tiers) if s != tier)

    def backing_tier_order(self, start: int = 1) -> list[int]:
        """Backing tiers ``start..deepest`` ordered by bandwidth headroom:
        tiers whose channel absorbed the smallest fraction of their
        ``MediumSpec.bandwidth_gbps`` over the current traffic window come
        first (unmodeled bandwidth = 0 counts as unconstrained), ties
        break toward the faster tier — which reduces to plain tier order
        for the default unmodeled hierarchies, so ``new_page`` cascades
        and bandwidth spills only re-route when a channel is actually
        saturated."""
        def utilization(t: int) -> float:
            bw = self.hierarchy[t].bandwidth_gbps
            if bw <= 0:
                return 0.0
            return self.tier_inflow_bytes(t) / (bw * 2**30)
        return sorted(range(start, self.n_tiers),
                      key=lambda t: (utilization(t), t))

    def commit_moves(self, pages: np.ndarray, dst_tier: int,
                     new_slots: np.ndarray) -> None:
        """Flip the page table for an executed bulk move: free the old slots
        (each page in its own source tier's allocator), bind the new ones,
        account per-pair traffic — one vectorized pass over the tier/slot
        arrays (the allocator free loop is host metadata only)."""
        pages = np.asarray(pages, np.int64)
        new_slots = np.asarray(new_slots, np.int64)
        if pages.size == 0:
            return
        src_tiers = self.tier[pages].copy()
        assert (src_tiers != dst_tier).all(), \
            "commit_moves: page already in the destination tier"
        for p, s in zip(pages, self.slot[pages]):
            self.alloc[int(self.tier[p])].free(int(s), 0)
            self.integrity.drop(int(self.tier[p]), [int(s)])
        self.tier[pages] = dst_tier
        self.slot[pages] = new_slots
        self._mark_dirty(pages)
        for t in np.unique(src_tiers):
            k = int((src_tiers == t).sum())
            self.traffic[(int(t), dst_tier)] += self.page_nbytes * k

    # -- migration primitive (single page, already-planned) --------------------
    def move_page(self, page: int, dst_tier: int, color: int | None = None,
                  color_mask: int | None = None) -> bool:
        """Synchronous ('locked CPU copy') single-page move between any
        two tiers."""
        src_tier = int(self.tier[page])
        if src_tier == dst_tier:
            return True
        if int(self.slot[page]) == NO_SLOT:
            return False                   # released page: nothing to move
        data = self.read_page(page)
        new_slot = self.alloc[dst_tier].alloc(0, color, color_mask)
        if new_slot is None and color is not None:
            # Algorithm 2 exhausted its slab walk: fall back to any color
            # rather than dropping the migration (capacity is the real bound).
            new_slot = self.alloc[dst_tier].alloc(0, None)
        if new_slot is None:
            return False
        old_slot = int(self.slot[page])
        if self.is_device_tier(dst_tier):
            self.pools[dst_tier].write_one(new_slot, data)
        else:
            self._host_write(dst_tier, new_slot, data)
        self.alloc[src_tier].free(old_slot, 0)
        self.integrity.drop(src_tier, [old_slot])
        self.tier[page] = dst_tier
        self.slot[page] = new_slot
        self._mark_dirty_one(page)
        self.traffic[(src_tier, dst_tier)] += self.page_nbytes
        return True

    def tier_used(self) -> list[int]:
        """Live page count per tier."""
        live = self.slot != NO_SLOT
        return [int(np.sum(self.tier[live] == t))
                for t in range(self.n_tiers)]

    def occupancy(self) -> dict:
        used = self.tier_used()
        out = {
            "fast_used": used[0], "fast_total": self.hierarchy[0].slots,
            "slow_used": used[-1],
            "slow_total": self.hierarchy[self.hierarchy.deepest].slots,
        }
        for i, spec in enumerate(self.hierarchy):
            out[f"t{i}_{spec.name.lower()}_used"] = used[i]
            out[f"t{i}_{spec.name.lower()}_total"] = spec.slots
        return out

    def publish_metrics(self, reg) -> None:
        """Publish per-tier occupancy / IO counters and per-(src, dst)
        migration traffic into an ``obs.MetricsRegistry``."""
        used = self.tier_used()
        for i, spec in enumerate(self.hierarchy):
            name = spec.name.lower()
            reg.gauge(f"store.t{i}_used",
                      f"live pages in tier {i} ({name})").set(used[i])
            reg.gauge(f"store.t{i}_slots",
                      f"capacity of tier {i} ({name})").set(spec.slots)
            reg.gauge(f"store.t{i}_reads",
                      f"page reads served from tier {i}").set(
                          self.reads_from[i])
            reg.gauge(f"store.t{i}_writes",
                      f"page writes landed in tier {i}").set(
                          self.writes_to[i])
        for (s, d), b in self.traffic.items():
            if b:      # sparse: most (src, dst) pairs never carry traffic
                reg.gauge(f"store.migration_bytes_t{s}_t{d}",
                          f"bytes migrated tier {s} -> tier {d}").set(b)
