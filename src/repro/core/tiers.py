"""TierStore — the hybrid fast/slow page store (MCHA analogue, Sec. 5.1).

Logical pages live in one of two physical pools:

  * FAST — device HBM (a jax array pool; on this CPU host it is a jax
    CpuDevice buffer, on TPU it is HBM);
  * SLOW — host DRAM (numpy pool; the NVM-channel analogue; optionally
    int8-quantized to model NVM's cheap-read/expensive-write asymmetry).

A page table maps logical page -> (tier, slot); per-page version counters
are bumped by every write so the optimistic (unlocked-DMA) migration path
can detect pages dirtied mid-copy, exactly like the paper's post-hoc
dirty-bit check (Sec. 6.3).

Slot allocation inside each pool goes through the color-aware SubBuddy
allocator so bank/slab-targeted placement (Algorithm 2) is honored.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .allocator import SubBuddyAllocator, SubBuddyConfig
from .placement import FAST, SLOW

NO_SLOT = -1


@dataclass
class TierConfig:
    n_pages: int                 # logical page count
    fast_slots: int              # HBM pool capacity (pages)
    slow_slots: int              # host pool capacity (pages)
    page_shape: tuple[int, ...]  # payload shape per page
    dtype: jnp.dtype = jnp.float32
    n_banks: int = 32
    n_slabs: int = 16
    quantize_slow: bool = False  # int8-quantize cold pages (soft-NVM analogue)


class TierStore:
    def __init__(self, cfg: TierConfig):
        # clamp the color geometry so every color exists in both pools
        # (the PFN space always contains all colors; a slot pool only does
        # when n_colors <= n_slots).
        n_banks, n_slabs = cfg.n_banks, cfg.n_slabs
        min_slots = min(cfg.fast_slots, cfg.slow_slots)
        while n_banks * n_slabs > max(min_slots, 1) and n_banks > 1:
            n_banks //= 2
        while n_banks * n_slabs > max(min_slots, 1) and n_slabs > 1:
            n_slabs //= 2
        if (n_banks, n_slabs) != (cfg.n_banks, cfg.n_slabs):
            from dataclasses import replace
            cfg = replace(cfg, n_banks=n_banks, n_slabs=n_slabs)
        self.cfg = cfg
        self.fast_pool = jnp.zeros((cfg.fast_slots, *cfg.page_shape), cfg.dtype)
        if cfg.quantize_slow:
            self.slow_pool = np.zeros((cfg.slow_slots, *cfg.page_shape), np.int8)
            self.slow_scale = np.ones((cfg.slow_slots,), np.float32)
        else:
            self.slow_pool = np.zeros((cfg.slow_slots, *cfg.page_shape),
                                      np.dtype(jnp.dtype(cfg.dtype).name)
                                      if cfg.dtype != jnp.bfloat16 else np.float32)
            self.slow_scale = None
        self.tier = np.full((cfg.n_pages,), SLOW, np.int8)
        self.slot = np.full((cfg.n_pages,), NO_SLOT, np.int64)
        self.version = np.zeros((cfg.n_pages,), np.int64)
        bcfg = dict(n_banks=cfg.n_banks, n_slabs=cfg.n_slabs)
        self.alloc = {
            FAST: SubBuddyAllocator(SubBuddyConfig(cfg.fast_slots, **bcfg)),
            SLOW: SubBuddyAllocator(SubBuddyConfig(cfg.slow_slots, **bcfg)),
        }
        # bytes moved per tier-direction, for the bandwidth balancer / figs
        self.traffic = {(FAST, SLOW): 0, (SLOW, FAST): 0}
        self.writes_to = {FAST: 0, SLOW: 0}
        self.reads_from = {FAST: 0, SLOW: 0}

    # -- page lifecycle -----------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        return int(np.prod(self.cfg.page_shape)) * jnp.dtype(self.cfg.dtype).itemsize

    def allocate(self, page: int, tier: int, color: int | None = None,
                 color_mask: int | None = None) -> bool:
        """Bind a logical page to a fresh slot in ``tier``."""
        assert self.slot[page] == NO_SLOT, f"page {page} already allocated"
        s = self.alloc[tier].alloc(0, color, color_mask)
        if s is None:
            return False
        self.tier[page] = tier
        self.slot[page] = s
        return True

    def release(self, page: int) -> None:
        s = int(self.slot[page])
        if s != NO_SLOT:
            self.alloc[int(self.tier[page])].free(s, 0)
            self.slot[page] = NO_SLOT

    # -- data access ----------------------------------------------------------
    def write_page(self, page: int, value) -> None:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        if t == FAST:
            self.fast_pool = self.fast_pool.at[s].set(
                jnp.asarray(value, self.cfg.dtype))
        else:
            self._slow_write(s, np.asarray(value, np.float32))
        self.version[page] += 1
        self.writes_to[t] += 1

    def read_page(self, page: int) -> np.ndarray:
        t, s = int(self.tier[page]), int(self.slot[page])
        assert s != NO_SLOT
        self.reads_from[t] += 1
        if t == FAST:
            return np.asarray(self.fast_pool[s], np.float32)
        return self._slow_read(s)

    def _slow_write(self, slot: int, value: np.ndarray) -> None:
        if self.cfg.quantize_slow:
            scale = max(float(np.max(np.abs(value))), 1e-8) / 127.0
            self.slow_pool[slot] = np.clip(
                np.round(value / scale), -127, 127).astype(np.int8)
            self.slow_scale[slot] = scale
        else:
            self.slow_pool[slot] = value

    def _slow_read(self, slot: int) -> np.ndarray:
        if self.cfg.quantize_slow:
            return self.slow_pool[slot].astype(np.float32) * self.slow_scale[slot]
        return np.asarray(self.slow_pool[slot], np.float32)

    # -- migration primitive (single page, already-planned) --------------------
    def move_page(self, page: int, dst_tier: int, color: int | None = None,
                  color_mask: int | None = None) -> bool:
        """Synchronous ('locked CPU copy') single-page move."""
        src_tier = int(self.tier[page])
        if src_tier == dst_tier:
            return True
        data = self.read_page(page)
        new_slot = self.alloc[dst_tier].alloc(0, color, color_mask)
        if new_slot is None and color is not None:
            # Algorithm 2 exhausted its slab walk: fall back to any color
            # rather than dropping the migration (capacity is the real bound).
            new_slot = self.alloc[dst_tier].alloc(0, None)
        if new_slot is None:
            return False
        old_slot = int(self.slot[page])
        if dst_tier == FAST:
            self.fast_pool = self.fast_pool.at[new_slot].set(
                jnp.asarray(data, self.cfg.dtype))
        else:
            self._slow_write(new_slot, data)
        self.alloc[src_tier].free(old_slot, 0)
        self.tier[page] = dst_tier
        self.slot[page] = new_slot
        self.traffic[(src_tier, dst_tier)] += self.page_nbytes
        return True

    def occupancy(self) -> dict:
        fast_used = int(np.sum(self.tier[self.slot != NO_SLOT] == FAST))
        slow_used = int(np.sum(self.tier[self.slot != NO_SLOT] == SLOW))
        return {
            "fast_used": fast_used, "fast_total": self.cfg.fast_slots,
            "slow_used": slow_used, "slow_total": self.cfg.slow_slots,
        }
