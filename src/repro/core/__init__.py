"""memos core — the paper's contribution as a composable JAX library.

Modules:
  patterns   — WD/RD/hotness/reuse classification (Sec. 3.1, 3.3)
  predictor  — write-history window prediction + Reverse rule (Sec. 3.2)
  sysmon     — on-device profiling counters + pass harvesting (Sec. 4.2)
  allocator  — color-indexed sub-buddy allocator (Sec. 6.2)
  hierarchy  — MediumSpec / MemoryHierarchy N-tier description (Sec. 1)
  placement  — channel policy, hotness list, Algorithm 2 (Sec. 5.2/5.3)
  migration  — locked + optimistic (unlocked-DMA) migration (Sec. 6.3)
  tiers      — N-tier hybrid page store (MCHA analogue, Sec. 5.1)
  memos      — the periodic management loop (Fig. 10)
  costmodel  — Table-1 latency/energy/lifetime model (Sec. 7.1)
"""
from . import (allocator, costmodel, hierarchy, memos, migration, patterns,
               placement, predictor, sysmon, tiers)
from .hierarchy import MediumSpec, MemoryHierarchy
from .memos import MemosConfig, MemosManager
from .tiers import StoreConfig, TierConfig, TierStore

__all__ = [
    "allocator", "costmodel", "hierarchy", "memos", "migration", "patterns",
    "placement", "predictor", "sysmon", "tiers", "MediumSpec",
    "MemoryHierarchy", "MemosConfig", "MemosManager", "StoreConfig",
    "TierConfig", "TierStore",
]
