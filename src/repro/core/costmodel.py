"""Latency / energy / lifetime cost model (paper Table 1 + Sec. 7.1).

Used by the evaluation benchmarks to score placements exactly the way the
paper's DRAMSim2-based emulation does, plus a TPU-constants profile for the
HBM/host-tier projection.

Paper Table 1:
  DRAM: trcd=10ns trp=10ns twr=10ns, r/w energy 51.2/51.2 nJ, standby 1 W/GB
  NVM : trcd=20ns trp=23ns twr=160ns, r/w energy 102.4/512 nJ,
        standby 0.1 W/GB, endurance 1e6
Lifetime model (Sec. 7.1): 64 B wear blocks, Start-Gap style leveling at
95% of ideal cell lifetime.
"""
from __future__ import annotations

from dataclasses import dataclass

SECONDS_PER_YEAR = 365.25 * 24 * 3600


@dataclass(frozen=True)
class MediumParams:
    name: str
    trcd_ns: float
    trp_ns: float
    twr_ns: float
    read_energy_nj: float
    write_energy_nj: float
    standby_w_per_gb: float
    endurance: float | None = None  # writes per cell; None = unlimited


# --- paper Table 1 -----------------------------------------------------------
DRAM = MediumParams("DRAM", trcd_ns=10, trp_ns=10, twr_ns=10,
                    read_energy_nj=51.2, write_energy_nj=51.2,
                    standby_w_per_gb=1.0)
NVM = MediumParams("NVM", trcd_ns=20, trp_ns=23, twr_ns=160,
                   read_energy_nj=102.4, write_energy_nj=512.0,
                   standby_w_per_gb=0.1, endurance=1e6)

# --- TPU-projection profile (v5e-class, DESIGN.md Sec. 2) ---------------------
# "latency" for a page-granular access = page_bytes / bandwidth; we express
# the fast/slow asymmetry via effective per-access service times for a 4 KB
# page equivalent.  HBM 819 GB/s; host via PCIe Gen3-class ~12 GB/s.
HBM = MediumParams("HBM", trcd_ns=4.9, trp_ns=0.0, twr_ns=4.9,
                   read_energy_nj=4.1, write_energy_nj=4.1,
                   standby_w_per_gb=0.04)
HOST = MediumParams("HOST", trcd_ns=333.0, trp_ns=0.0, twr_ns=333.0,
                    read_energy_nj=62.0, write_energy_nj=62.0,
                    standby_w_per_gb=0.005)

WEAR_BLOCK_BYTES = 64
LEVELING_EFFICIENCY = 0.95  # Start-Gap


def startgap_interval(efficiency: float = LEVELING_EFFICIENCY) -> int:
    """Demand writes between Start-Gap moves for a target leveling
    efficiency: each gap move spends overhead on 1/(interval+1) of the
    write stream, so efficiency = interval / (interval + 1)."""
    assert 0.0 < efficiency < 1.0
    return max(1, round(efficiency / (1.0 - efficiency)))


def page_access_energy_nj(m: MediumParams, page_bytes: int,
                          is_write: bool) -> float:
    """Energy for one page-granular access: Table-1 energies are per
    64 B array access, and a page access touches each of its wear blocks
    once."""
    per_access = m.write_energy_nj if is_write else m.read_energy_nj
    return (page_bytes / WEAR_BLOCK_BYTES) * per_access


def lifetime_years_from_wear(wear_writes: float, elapsed_s: float,
                             m: MediumParams = NVM,
                             efficiency: float = 1.0) -> float:
    """Lifetime projection from *measured* wear: ``wear_writes`` writes
    landed on a wear block over ``elapsed_s`` seconds; extrapolate to the
    time that block hits endurance.  The online counterpart of
    ``nvm_lifetime_years`` (which models the write stream analytically)."""
    if m.endurance is None or wear_writes <= 0 or elapsed_s <= 0:
        return float("inf")
    rate = wear_writes / elapsed_s
    return efficiency * m.endurance / rate / SECONDS_PER_YEAR


@dataclass
class AccessCounts:
    reads: float = 0.0
    writes: float = 0.0

    @property
    def total(self) -> float:
        return self.reads + self.writes


def access_latency_ns(m: MediumParams, is_write: bool,
                      row_conflict_rate: float = 0.0) -> float:
    """Mean per-access latency: activate + (write-recovery if write), plus a
    precharge penalty on row-buffer conflicts (bank imbalance raises this)."""
    base = m.trcd_ns + (m.twr_ns if is_write else 0.0)
    return base + row_conflict_rate * m.trp_ns


def mean_latency_ns(counts_fast: AccessCounts, counts_slow: AccessCounts,
                    fast: MediumParams = DRAM, slow: MediumParams = NVM,
                    conflict_fast: float = 0.0, conflict_slow: float = 0.0) -> float:
    num = (counts_fast.reads * access_latency_ns(fast, False, conflict_fast)
           + counts_fast.writes * access_latency_ns(fast, True, conflict_fast)
           + counts_slow.reads * access_latency_ns(slow, False, conflict_slow)
           + counts_slow.writes * access_latency_ns(slow, True, conflict_slow))
    den = counts_fast.total + counts_slow.total
    return num / max(den, 1.0)


def slow_tier_latency_ns(counts_slow: AccessCounts,
                         slow: MediumParams = NVM,
                         conflict: float = 0.0) -> float:
    """NVM-side average latency (paper reports this per-channel)."""
    num = (counts_slow.reads * access_latency_ns(slow, False, conflict)
           + counts_slow.writes * access_latency_ns(slow, True, conflict))
    return num / max(counts_slow.total, 1.0)


def dynamic_energy_mw(counts: AccessCounts, m: MediumParams,
                      window_s: float) -> float:
    """Average dynamic power (mW) over the window, as in Sec. 7.1."""
    nj = counts.reads * m.read_energy_nj + counts.writes * m.write_energy_nj
    return (nj * 1e-9) / max(window_s, 1e-12) * 1e3


def standby_power_w(capacity_gb: float, m: MediumParams) -> float:
    return capacity_gb * m.standby_w_per_gb


def nvm_lifetime_years(write_bytes_per_s: float, capacity_bytes: float,
                       m: MediumParams = NVM,
                       hot_block_fraction: float = 1.0) -> float:
    """Sec. 7.1 lifetime model.

    With ideal leveling every 64 B wear block absorbs an equal share of the
    write stream; ``hot_block_fraction`` < 1 models unleveled skew (writes
    concentrated on a fraction of blocks, as in the no-memos baselines).
    """
    if m.endurance is None:
        return float("inf")
    blocks = capacity_bytes / WEAR_BLOCK_BYTES
    writes_per_block_s = (write_bytes_per_s / WEAR_BLOCK_BYTES) / max(
        blocks * hot_block_fraction, 1.0)
    if writes_per_block_s <= 0:
        return float("inf")
    seconds = LEVELING_EFFICIENCY * m.endurance / writes_per_block_s
    return seconds / SECONDS_PER_YEAR
