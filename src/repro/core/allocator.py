"""Sub-Buddy allocator with color-indexed free lists (paper Sec. 6.2, Fig. 12).

The paper splits the Linux Buddy System into per-channel *sub-buddies* and
indexes each order's free blocks by a 9-bit color formed from the bank and
cache-slab bits of the PFN, giving O(1) color-exact allocation
(Algorithm 3).  TPUs have no physical-address coloring, so the color is an
explicit field of the page-pool index space instead of PFN bits:

    color(page) = page_index mod n_colors          (order-0 blocks)
    color(block) = color of its first page         (higher orders)

with n_colors = n_banks * n_slabs (default 32 * 16 = 512, as in Fig. 12).
A block of order o covers 2**o consecutive colors (wrapping), exactly like
the paper's order-1 blocks spanning two colors, so the color of the first
page plus the order determines which colors the block can satisfy.

Supports the generalized (i, j, k)-bit allocation of Sec. 5.2 through
``color_mask``: any free block whose color matches ``want & mask`` is
eligible, letting callers constrain only bank bits, only slab bits, both,
or neither.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class SubBuddyConfig:
    n_pages: int
    n_banks: int = 32
    n_slabs: int = 16
    max_order: int = 10

    @property
    def n_colors(self) -> int:
        return self.n_banks * self.n_slabs

    def color_of(self, page: int) -> int:
        return page % self.n_colors

    def bank_of(self, page: int) -> int:
        # low bits: slab (rows within a bank share a slab stride); high: bank.
        return (page % self.n_colors) // self.n_slabs

    def slab_of(self, page: int) -> int:
        return page % self.n_slabs


class SubBuddyAllocator:
    """One sub-buddy (one channel/tier).  All operations are O(1) in the
    fast path; splitting a larger block (Algorithm 3's Expand_color_block)
    costs O(max_order)."""

    def __init__(self, cfg: SubBuddyConfig):
        self.cfg = cfg
        # free_lists[order][color] -> deque of block start pages
        self.free_lists: list[dict[int, deque[int]]] = [
            {} for _ in range(cfg.max_order + 1)
        ]
        self._free_blocks: set[tuple[int, int]] = set()  # (start, order)
        self._allocated: set[tuple[int, int]] = set()    # live allocations
        self._retired: set[int] = set()   # quarantined order-0 starts
        self.n_free = 0
        # generation counter: bumped by every successful alloc/free, so a
        # snapshot (clone) taken at generation g is interchangeable with
        # the live allocator for as long as the live generation stays g —
        # the async memos commit adopts the plan's clone wholesale when no
        # allocator call interleaved, instead of replaying per reservation
        self.gen = 0
        self._seed_initial_blocks()

    # -- internal ---------------------------------------------------------
    def _seed_initial_blocks(self) -> None:
        """Carve the pool into maximal aligned blocks."""
        start = 0
        n = self.cfg.n_pages
        while start < n:
            order = self.cfg.max_order
            while order > 0 and (start % (1 << order) != 0 or start + (1 << order) > n):
                order -= 1
            self._push(start, order)
            start += 1 << order

    def _push(self, start: int, order: int) -> None:
        color = self.cfg.color_of(start)
        self.free_lists[order].setdefault(color, deque()).append(start)
        self._free_blocks.add((start, order))
        self.n_free += 1 << order

    def _pop_exact(self, order: int, color: int) -> int | None:
        dq = self.free_lists[order].get(color)
        while dq:
            start = dq.popleft()
            if (start, order) in self._free_blocks:
                self._free_blocks.discard((start, order))
                self.n_free -= 1 << order
                return start
        return None

    def _block_colors(self, order: int) -> int:
        """Number of distinct colors covered by an order-o block."""
        return min(1 << order, self.cfg.n_colors)

    # -- public API ---------------------------------------------------------
    def alloc(self, order: int = 0, color: int | None = None,
              color_mask: int | None = None) -> int | None:
        """Allocate a block of 2**order pages whose first-page color matches
        ``color`` under ``color_mask`` (None = any color).  Returns the start
        page index or None when the request cannot be satisfied.

        Algorithm 3: exact-color hit is O(1); otherwise walk to higher
        orders, split the covering block, and keep the sub-block whose color
        matches (Expand_color_block)."""
        if color is None:
            got = self._alloc_any(order)
            if got is not None:
                self._allocated.add((got, order))
                self.gen += 1
            return got
        n_colors = self.cfg.n_colors
        mask = n_colors - 1 if color_mask is None else color_mask
        want = color & mask

        # 1) exact O(1) probes at the requested order over matching colors.
        for c, dq in list(self.free_lists[order].items()):
            if (c & mask) == want and dq:
                got = self._pop_exact(order, c)
                if got is not None:
                    self._allocated.add((got, order))
                    self.gen += 1
                    return got

        # 2) split a higher-order block covering a matching color.
        for o in range(order + 1, self.cfg.max_order + 1):
            span = self._block_colors(o)
            for c, dq in list(self.free_lists[o].items()):
                if not dq:
                    continue
                # colors covered: c, c+1, ..., c+span-1 (mod n_colors)
                covered_match = any(((c + d) % n_colors) & mask == want
                                    for d in range(span))
                if not covered_match:
                    continue
                start = self._pop_exact(o, c)
                if start is None:
                    continue
                got = self._expand_color_block(start, o, order, want, mask)
                self._allocated.add((got, order))
                self.gen += 1
                return got
        return None

    def _expand_color_block(self, start: int, order: int, target_order: int,
                            want: int, mask: int) -> int:
        """Split ``start`` (order) down to target_order keeping a sub-block
        whose first-page color matches; free the other halves."""
        n_colors = self.cfg.n_colors
        while order > target_order:
            order -= 1
            half = 1 << order
            lo, hi = start, start + half
            # choose the half that still covers a matching color
            span = self._block_colors(order)
            lo_match = any(((self.cfg.color_of(lo) + d) % n_colors) & mask == want
                           for d in range(span))
            if lo_match:
                self._push(hi, order)
                start = lo
            else:
                self._push(lo, order)
                start = hi
        return start

    def _alloc_any(self, order: int) -> int | None:
        for o in range(order, self.cfg.max_order + 1):
            for c in list(self.free_lists[o].keys()):
                start = self._pop_exact(o, c)
                if start is not None:
                    while o > order:
                        o -= 1
                        self._push(start + (1 << o), o)
                    return start
        return None

    def retire(self, start: int) -> bool:
        """Permanently withhold an allocated order-0 block (bad-slot
        quarantine): the block stays in the allocated set — so
        ``check_consistency``'s exact-partition invariant holds and the
        slot is never handed out again — but any later ``free`` of it is
        rejected.  Pool capacity shrinks by one page for the lifetime of
        the allocator.  Returns False if the block isn't currently
        allocated (already freed — nothing to retire)."""
        if (start, 0) not in self._allocated:
            return False
        self._retired.add(start)
        self.gen += 1        # snapshots taken before the retire are stale
        return True

    @property
    def n_retired(self) -> int:
        return len(self._retired)

    def free(self, start: int, order: int = 0) -> None:
        """Return a block; merge buddies greedily (classic buddy coalesce)."""
        if order == 0 and start in self._retired:
            raise ValueError(f"free of quarantined block ({start}, 0)")
        if (start, order) not in self._allocated:
            raise ValueError(f"double/invalid free of block ({start}, {order})")
        self._allocated.discard((start, order))
        self.gen += 1
        while order < self.cfg.max_order:
            buddy = start ^ (1 << order)
            if (buddy, order) not in self._free_blocks:
                break
            # unlink buddy and merge
            self._free_blocks.discard((buddy, order))
            self.n_free -= 1 << order
            start = min(start, buddy)
            order += 1
        self._push(start, order)

    def clone(self) -> "SubBuddyAllocator":
        """A bookkeeping deep copy sharing the (immutable) config.

        The asynchronous memos plan phase simulates Algorithm-2 slot
        reservations against a clone on its worker thread, so the live
        allocator is never touched off the dispatch-boundary path.  At
        commit time, if the live allocator's ``gen`` still equals the
        generation the clone was taken at, no call interleaved and the
        clone (reservations included) simply *becomes* the live allocator
        — an O(1) adoption; otherwise the recorded reservations are
        replayed call by call and any matching prefix still commits."""
        other = object.__new__(SubBuddyAllocator)
        other.cfg = self.cfg
        other.free_lists = [{c: deque(dq) for c, dq in bucket.items()}
                            for bucket in self.free_lists]
        other._free_blocks = set(self._free_blocks)
        other._allocated = set(self._allocated)
        other._retired = set(self._retired)
        other.n_free = self.n_free
        other.gen = self.gen
        return other

    def check_consistency(self) -> None:
        """Bookkeeping invariants (test support): the free-block set and the
        allocation set partition the pool exactly, ``n_free`` matches the
        free-block set, and live free-list entries are indexed under their
        block's color.  Raises AssertionError on violation."""
        assert self.n_free == sum(1 << o for _, o in self._free_blocks), \
            "n_free disagrees with the free-block set"
        covered: set[int] = set()
        for start, order in self._free_blocks | self._allocated:
            span = set(range(start, start + (1 << order)))
            assert not (span & covered), \
                f"block ({start}, {order}) overlaps another live block"
            covered |= span
        assert covered == set(range(self.cfg.n_pages)), \
            "free + allocated blocks do not cover the pool exactly"
        for order, bucket in enumerate(self.free_lists):
            for color, dq in bucket.items():
                for start in dq:
                    if (start, order) in self._free_blocks:   # skip stale
                        assert self.cfg.color_of(start) == color, \
                            f"block {start} filed under wrong color {color}"

    def alloc_pages(self, n: int, color: int | None = None,
                    color_mask: int | None = None) -> list[int] | None:
        """Allocate n order-0 pages (not necessarily contiguous)."""
        got: list[int] = []
        for _ in range(n):
            p = self.alloc(0, color, color_mask)
            if p is None:
                for q in got:
                    self.free(q, 0)
                return None
            got.append(p)
        return got
