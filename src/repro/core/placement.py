"""Placement policy: channel allocation, hotness list, Algorithm 2,
channel-bandwidth balancing (paper Sec. 5.2/5.3) — generic over an
N-tier :class:`~repro.core.hierarchy.MemoryHierarchy`.

Channel-allocation principles (Sec. 5.2), generalized:
  1. hot pages (Freq-touched, Thrashing) -> tier 0 (DRAM/HBM), esp. WD;
  2. RD-intensive pages may live in slower tiers without hurting perf;
  3. cold pages sink to the deepest tier (energy + reserve fast capacity).

With more than two tiers the pages tolerant of slower media are
distributed across the intermediate tiers by **per-page utility over
medium costs**: for each intermediate tier (cheapest access cost first)
the pages with the largest latency benefit vs. the deepest tier — their
predicted read/write mix priced through each tier's ``MediumSpec``
Table-1 medium — fill its capacity, and the remainder falls through.
For a two-tier hierarchy this reduces exactly to the paper's original
fast/slow rule.

Migration marking (Fig. 10 step 3): a page is "will-be-migrated" when its
*current* tier disagrees with the tier implied by its *predicted future*
state + hotness; ranking (step 3b): WD_FREQ_H before WD_FREQ_L, then by
hotness score.

Algorithm 2: pick the coldest bank, then the coldest cache slab (excluding
the reserved slabs 0 and 15) whose associated rows in that bank still have
free capacity; walk to the next-coldest slab otherwise.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

from . import patterns, predictor
from .hierarchy import MemoryHierarchy

RESERVED_THRASH_SLAB = 0    # paper: slab 0 isolates Thrashing pages
RESERVED_RARE_SLAB = 15     # paper: slab 15 holds Rarely-touched pages


class PlacementDecision(NamedTuple):
    target_tier: np.ndarray       # int8 [n_pages] tier index
    migrate: np.ndarray           # bool [n_pages] will-be-migrated
    hotness_list: np.ndarray      # int32 [k] page ids, priority-ordered (HL)


def _wants_fastest(wd_code: np.ndarray, hot: np.ndarray, future: np.ndarray,
                   reuse_class: np.ndarray, wear_penalty: float) -> np.ndarray:
    """The three channel-allocation principles: which pages demand tier 0."""
    fast = hot | (future == predictor.WD_FREQ_H) | (future == predictor.WD_FREQ_L)
    # RD-intensive or cold pages may stay slow even if moderately touched;
    # thrashing RD streams explicitly stay slow (they are served through the
    # reserved slab and NVM reads are cheap) unless they are write-heavy.
    rd_stream = (wd_code != patterns.WD) & (reuse_class == patterns.THRASHING)
    fast = fast & ~rd_stream
    if wear_penalty > 0:
        # wear pressure (projected NVM lifetime below the horizon, Sec. 7.1):
        # every currently-WD page is steered to the fast tier regardless of
        # hotness, so the write stream stops consuming NVM endurance — the
        # paper's 40X lifetime mechanism.
        fast = fast | (wd_code == patterns.WD)
    return fast


def _fill_intermediate_tiers(tgt: np.ndarray, tolerant: np.ndarray,
                             hierarchy: MemoryHierarchy,
                             reads: np.ndarray, writes: np.ndarray, *,
                             page_weight: np.ndarray | None = None,
                             energy_aware: bool = False) -> None:
    """Distribute slow-tolerant pages over tiers 1..deepest by utility:
    each intermediate tier (cheapest first) takes the pages whose
    read/write mix gains the most latency vs. the deepest medium, up to
    its slot capacity; everything else stays targeted at the deepest
    tier.  Mutates ``tgt`` in place.

    ``page_weight`` multiplies per-page benefit (tenant QoS weight as a
    utility multiplier, Li et al.), so weighted pages win the
    capacity-constrained intermediate slots.  ``energy_aware`` prices
    tiers by Table-1 access *energy* instead of latency — the power
    governor sets it while over the dynamic-power budget, biasing
    placement toward the low-energy medium."""
    deepest = hierarchy.deepest
    if energy_aware:
        def tier_cost(t):
            m = hierarchy[t].medium
            return m.read_energy_nj + m.write_energy_nj
    else:
        def tier_cost(t):
            return hierarchy[t].read_cost_ns() + hierarchy[t].write_cost_ns()
    mids = sorted(range(1, deepest), key=lambda t: (tier_cost(t), t))
    ids = np.nonzero(tolerant)[0]
    if ids.size == 0:
        return
    r = reads[ids].astype(np.float64)
    w = writes[ids].astype(np.float64)
    deep = hierarchy[deepest]
    remaining = np.ones(ids.size, bool)
    for t in mids:
        spec = hierarchy[t]
        # per-page benefit of tier t over the deepest tier, priced through
        # the Table-1 media (>= 0 when the hierarchy is ordered)
        if energy_aware:
            benefit = (r * (deep.medium.read_energy_nj
                            - spec.medium.read_energy_nj)
                       + w * (deep.medium.write_energy_nj
                              - spec.medium.write_energy_nj))
        else:
            benefit = (r * (deep.read_cost_ns() - spec.read_cost_ns())
                       + w * (deep.write_cost_ns() - spec.write_cost_ns()))
        if page_weight is not None:
            benefit = benefit * page_weight[ids]
        cand = np.nonzero(remaining & (benefit > 0))[0]
        if cand.size == 0:
            continue
        order = np.lexsort((ids[cand], -benefit[cand]))   # benefit desc, id asc
        take = cand[order][:spec.slots]
        tgt[ids[take]] = t
        remaining[take] = False


def target_tier(wd_code: np.ndarray, hot: np.ndarray, future: np.ndarray,
                reuse_class: np.ndarray, wear_penalty: float = 0.0, *,
                hierarchy: MemoryHierarchy | None = None,
                reads: np.ndarray | None = None,
                writes: np.ndarray | None = None,
                page_weight: np.ndarray | None = None,
                energy_aware: bool = False) -> np.ndarray:
    """Target tier index per page.

    Without a ``hierarchy`` (or with a two-tier one) this is exactly the
    paper's fast/slow rule: 0 for pages demanding the fast channel, 1
    (the deepest tier) otherwise.  With more tiers, the slow-tolerant
    pages additionally spread over the intermediate tiers by per-page
    utility over the tiers' ``MediumSpec`` costs (``reads``/``writes``
    supply the access mix; omitted, everything tolerant sinks to the
    deepest tier).  ``page_weight`` / ``energy_aware`` thread the QoS
    utility multiplier and the power-cap energy bias into that fill.
    """
    fast = _wants_fastest(wd_code, hot, future, reuse_class, wear_penalty)
    deepest = 1 if hierarchy is None else hierarchy.deepest
    tgt = np.where(fast, 0, deepest).astype(np.int8)
    if hierarchy is not None and hierarchy.n_tiers > 2 \
            and reads is not None and writes is not None:
        _fill_intermediate_tiers(tgt, ~fast, hierarchy,
                                 np.asarray(reads), np.asarray(writes),
                                 page_weight=page_weight,
                                 energy_aware=energy_aware)
    return tgt


def plan(summary, current_tier: np.ndarray, *, max_migrations: int | None = None,
         wear_penalty: float = 0.0,
         hierarchy: MemoryHierarchy | None = None,
         page_weight: np.ndarray | None = None,
         energy_aware: bool = False) -> PlacementDecision:
    """Fig. 10 steps 2-3: decide targets, mark migrations, rank the HL.

    Under wear pressure (``wear_penalty > 0``) WD pages additionally get a
    ranking boost so their promotions win the migration budget, and the
    target-tier rule pins them to the fast tier (see ``target_tier``).

    ``page_weight`` is the multi-tenant QoS hook (Li et al. page-utility
    model, tenant weight as per-page utility multiplier): it scales the
    hotness score in the migration ranking, scales intermediate-tier fill
    benefit, and pages with weight > 1 *resist demotion* — a demotion
    target (deeper than the current tier) is cancelled for them, so a
    latency-critical tenant's KV pages hold their tier while unweighted
    pages around them sink.  ``energy_aware`` makes the intermediate-tier
    fill rank media by access energy (power-cap response).  With
    ``page_weight`` None/all-ones and ``energy_aware`` False the decision
    is bit-identical to the pre-QoS planner.
    """
    wd_code = np.asarray(summary.wd_code)
    hot = np.asarray(summary.hot)
    future = np.asarray(summary.future)
    reuse = np.asarray(summary.reuse_class)
    hotness = np.asarray(summary.hotness)
    weight = None if page_weight is None \
        else np.asarray(page_weight, dtype=np.float64)

    # the access mix only matters for intermediate-tier assignment, and
    # minimal summary stubs (tests) may not carry raw counters
    reads = getattr(summary, "reads", None)
    writes = getattr(summary, "writes", None)
    tgt = target_tier(
        wd_code, hot, future, reuse, wear_penalty, hierarchy=hierarchy,
        reads=None if reads is None else np.asarray(reads),
        writes=None if writes is None else np.asarray(writes),
        page_weight=weight, energy_aware=energy_aware)
    if weight is not None:
        resist = (weight > 1.0) & (tgt > current_tier)
        tgt = np.where(resist, current_tier, tgt).astype(np.int8)
    migrate = tgt != current_tier
    score = hotness.astype(np.float64)
    if weight is not None:
        score = score * weight
    if wear_penalty > 0:
        score = score + wear_penalty * (wd_code == patterns.WD)

    ids = np.nonzero(migrate)[0]
    # priority: WD_FREQ_H (2) > WD_FREQ_L (1) > UN_WD (0), then score desc.
    order = np.lexsort((-score[ids], -future[ids]))
    hl = ids[order].astype(np.int32)
    if max_migrations is not None:
        hl = hl[:max_migrations]
        keep = np.zeros_like(migrate)
        keep[hl] = True
        migrate = migrate & keep
    return PlacementDecision(tgt, migrate, hl)


def coldest_bank_and_slab(
    bank_freq: np.ndarray,
    slab_freq: np.ndarray,
    rows_free: Callable[[int, int], bool],
    *,
    reserved: tuple[int, ...] = (RESERVED_THRASH_SLAB, RESERVED_RARE_SLAB),
) -> tuple[int, int] | None:
    """Algorithm 2: (cold_bank, cold_slab) with free rows, else None.

    ``rows_free(bank, slab)`` reports whether the rows of ``bank`` associated
    with ``slab`` still have free capacity.
    """
    cold_bank = int(np.argmin(bank_freq))
    slab_order = [int(s) for s in np.argsort(slab_freq, kind="stable")
                  if int(s) not in reserved]
    for slab in slab_order:                    # WHILE rows not free: next slab
        if rows_free(cold_bank, slab):
            return cold_bank, slab
    return None


def slab_for_reuse_class(reuse_class: int) -> int | None:
    """Reserved-slab routing (Sec. 5.3 step 1): Thrashing -> slab 0,
    Rarely-touched -> slab 15, Freq-touched -> policy choice (None)."""
    if reuse_class == patterns.THRASHING:
        return RESERVED_THRASH_SLAB
    if reuse_class == patterns.RARELY_TOUCHED:
        return RESERVED_RARE_SLAB
    return None


class BandwidthBalancer:
    """Channel-bandwidth balancing (Sec. 5.2 'Data Migration Mechanism').

    Spill pages from tier 0 to the next tier down while the fast channel
    is saturated; stop as soon as fast-channel utilization *begins to
    drop* (the paper's stop rule), so fast-channel bandwidth stays
    maximized while the slower channels soak up overflow reads.
    """

    def __init__(self, fast_bw_bound: float, hysteresis: float = 0.02):
        self.bound = fast_bw_bound
        self.hysteresis = hysteresis
        self._last_util: float | None = None
        self.spilling = False

    def update(self, fast_util: float) -> bool:
        """Feed one bandwidth-utilization observation (bytes/s); returns
        whether memos should keep spilling pages off the fast channel."""
        if fast_util >= self.bound:
            self.spilling = True
        elif self._last_util is not None and self.spilling:
            if fast_util < self._last_util * (1.0 - self.hysteresis):
                self.spilling = False  # utilization began to drop -> stop
        self._last_util = fast_util
        return self.spilling

    def spill_candidates(self, wd_code: np.ndarray, hotness: np.ndarray,
                         current_tier: np.ndarray, n: int,
                         exclude_wd: bool = False) -> np.ndarray:
        """Pick n tier-0 pages to spill: RD pages first, then coolest WD
        ones.  ``exclude_wd`` keeps write-dominated pages off the slower
        channels entirely — set while the memos pass is under NVM wear
        pressure."""
        in_fast = current_tier == 0
        rd = in_fast & (wd_code == patterns.RD)
        rd_ids = np.nonzero(rd)[0]
        rd_ids = rd_ids[np.argsort(hotness[rd_ids])]
        if exclude_wd:
            return rd_ids[:n].astype(np.int32)
        wd = in_fast & (wd_code == patterns.WD)
        wd_ids = np.nonzero(wd)[0]
        wd_ids = wd_ids[np.argsort(hotness[wd_ids])]
        return np.concatenate([rd_ids, wd_ids])[:n].astype(np.int32)
