"""Shared neural building blocks: norms, rotary embeddings (incl. M-RoPE),
gated MLPs, embeddings.

Conventions:
  * pure functions over explicit param dicts (no framework dependency);
  * params stacked along a leading layer axis are handled by the caller
    (lax.scan slices them);
  * RoPE uses the *interleaved-pair* convention (pairs (2i, 2i+1)), which
    keeps each rotation pair contiguous so head_dim can be sharded across
    the `model` mesh axis at any even boundary (DESIGN.md Sec. 3.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
             gemma_style: bool = False) -> jnp.ndarray:
    """RMSNorm; gemma_style multiplies by (1 + scale) as Gemma does."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style else scale.astype(jnp.float32)
    return (x * w).astype(dtype)


# --- rotary position embeddings ------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for interleaved-pair RoPE.

    positions: [..., S] integer positions.
    Returns (cos, sin) each [..., S, head_dim/2].
    """
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Apply interleaved-pair rotation.  x: [..., S, H, D]; cos/sin either
    [..., S, D/2] (broadcast over heads) or already head-shaped."""
    orig = x.dtype
    x = x.astype(jnp.float32)
    xe = x[..., 0::2]
    xo = x[..., 1::2]
    if cos.ndim == x.ndim - 1:  # add head axis
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    ye = xe * cos - xo * sin
    yo = xe * sin + xo * cos
    y = jnp.stack([ye, yo], axis=-1).reshape(x.shape)
    return y.astype(orig)


def mrope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                 sections: tuple[int, ...]) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency slots are split
    into ``sections`` (temporal, height, width), each rotated by its own
    position stream.

    positions: [..., S, n_sections] int positions (for text tokens all
    streams are equal, degenerating to standard RoPE).
    Returns (cos, sin) each [..., S, head_dim/2].
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id per frequency slot
    sec_id = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])
    pos = positions[..., sec_id]                       # [..., S, half]
    ang = pos.astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


# --- MLPs ------------------------------------------------------------------

def swiglu_mlp(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
               w_down: jnp.ndarray, *, act=jax.nn.silu) -> jnp.ndarray:
    """SwiGLU/GeGLU feed-forward: act(x@Wg) * (x@Wu) @ Wd."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", act(g) * u, w_down)


def gelu_mlp(x: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    """Plain 2-matrix FFN (musicgen-style)."""
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(
        jnp.einsum("...d,df->...f", x, w_up)), w_down)


# --- embedding / unembedding ---------------------------------------------------

def embed(tokens: jnp.ndarray, table: jnp.ndarray,
          *, scale_by_sqrt_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        out = out * jnp.sqrt(jnp.asarray(table.shape[-1], out.dtype))
    return out


def unembed(x: jnp.ndarray, table_or_head: jnp.ndarray, *, tied: bool) -> jnp.ndarray:
    if tied:  # table: [V, d]
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          *, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy, stable, fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if valid is not None:
        v = valid.astype(jnp.float32)
        return jnp.sum(nll * v) / jnp.maximum(jnp.sum(v), 1.0)
    return jnp.mean(nll)
