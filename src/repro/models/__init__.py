from . import attention, layers, moe, ssm, transformer

__all__ = ["attention", "layers", "moe", "ssm", "transformer"]
