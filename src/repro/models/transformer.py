"""Composable decoder covering all ten assigned architectures.

Layouts:
  * ``attn``   — [norm→attention→(post)norm] + [norm→MLP|MoE→(post)norm],
                 scanned over stacked layer params (single trace per arch);
  * ``mamba``  — Mamba-2 SSD blocks, scanned;
  * ``hybrid`` — Mamba-2 backbone + a *shared* attention block applied every
                 ``shared_attn_every`` layers (zamba2), via lax.cond inside
                 the scan (both branches traced once).

Train/prefill forward uses lax.scan over layers (small HLO, remat-wrapped);
prefill and decode use a python loop so heterogeneous per-layer caches
(local/global windows, shared-attn sites, SSM state) stay simple and the
cache updates alias in place.

Sharding intent is expressed with with_sharding_constraint at block
boundaries (Megatron-SP / context-parallel per parallel/sharding.py);
everything also runs unsharded (mi=None) for CPU tests.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel import sharding as sh
from . import attention as attn_mod
from . import layers, moe as moe_mod, ssm


# =============================================================================
# parameter initialization
# =============================================================================

def _stack(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _attn_dict(p: attn_mod.AttnParams) -> dict:
    return {k: v for k, v in p._asdict().items() if v is not None}


def _attn_from_dict(d: dict) -> attn_mod.AttnParams:
    return attn_mod.AttnParams(
        wq=d["wq"], wk=d["wk"], wv=d["wv"], wo=d["wo"],
        bq=d.get("bq"), bk=d.get("bk"), bv=d.get("bv"),
        q_norm=d.get("q_norm"), k_norm=d.get("k_norm"))


def mamba_spec_of(cfg: ArchConfig) -> ssm.MambaSpec:
    return ssm.make_spec(cfg.d_model, expand=cfg.ssm_expand,
                         headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                         chunk=cfg.chunk)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    keys = iter(jax.random.split(key, 8 * cfg.n_layers + 16))
    d = cfg.d_model
    vp = sh.pad_vocab(cfg.vocab)

    def one_attn():
        return _attn_dict(attn_mod.init_attn_params(
            next(keys), d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype))

    def one_mlp(d_ff: int):
        s = d ** -0.5
        if cfg.mlp_kind == "gelu":
            return {"w_up": (jax.random.normal(next(keys), (d, d_ff)) * s
                             ).astype(dtype),
                    "w_down": (jax.random.normal(next(keys), (d_ff, d))
                               * d_ff ** -0.5).astype(dtype)}
        return {"w_gate": (jax.random.normal(next(keys), (d, d_ff)) * s
                           ).astype(dtype),
                "w_up": (jax.random.normal(next(keys), (d, d_ff)) * s
                         ).astype(dtype),
                "w_down": (jax.random.normal(next(keys), (d_ff, d))
                           * d_ff ** -0.5).astype(dtype)}

    ln = lambda: (jnp.zeros((d,), dtype) if cfg.gemma_norm
                  else jnp.ones((d,), dtype))

    layers_list = []
    if cfg.layout in ("mamba", "hybrid"):
        spec = mamba_spec_of(cfg)
        for _ in range(cfg.n_layers):
            layers_list.append({
                "ln": ln(),
                "mamba": ssm.init_mamba_params(next(keys), spec, dtype)._asdict(),
            })
    else:
        for _ in range(cfg.n_layers):
            lp: dict = {"ln1": ln(), "ln2": ln(), "attn": one_attn()}
            if cfg.is_moe:
                lp["moe"] = moe_mod.init_moe_params(
                    next(keys), d, cfg.n_experts, cfg.expert_d_ff or cfg.d_ff,
                    dtype)._asdict()
            else:
                lp["mlp"] = one_mlp(cfg.d_ff)
            if cfg.gemma_norm:
                lp["ln1_post"] = ln()
                lp["ln2_post"] = ln()
            layers_list.append(lp)

    params: dict = {"layers": _stack(layers_list), "final_norm": ln()}
    if cfg.layout == "hybrid":
        params["shared"] = {
            "ln1": ln(), "ln2": ln(),
            "attn": one_attn(), "mlp": one_mlp(cfg.d_ff),
        }
    if cfg.tie_embeddings:
        params["embed"] = (jax.random.normal(next(keys), (cfg.vocab, d))
                           * d ** -0.5).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(next(keys), (vp, d))
                           * d ** -0.5).astype(dtype)
        params["lm_head"] = (jax.random.normal(next(keys), (d, vp))
                             * d ** -0.5).astype(dtype)
    return params


# =============================================================================
# embeddings / unembedding
# =============================================================================

def embed_in(params: dict, cfg: ArchConfig, batch: dict,
             mi: sh.MeshInfo | None) -> jnp.ndarray:
    if cfg.input_mode == "embeds":
        h = batch["embeds"]
    else:
        tokens = batch["tokens"]
        if cfg.tie_embeddings:
            # vocab-sharded table: one-hot matmul (GShard-style lookup)
            oh = jax.nn.one_hot(tokens, params["embed"].shape[0],
                                dtype=params["embed"].dtype)
            h = jnp.einsum("bsv,vd->bsd", oh, params["embed"])
        else:
            h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def logits_out(params: dict, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    vp = logits.shape[-1]
    if vp != cfg.vocab:  # mask padded vocab columns
        pad_mask = jnp.arange(vp) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def _rope_tables(cfg: ArchConfig, positions: jnp.ndarray):
    """(cos, sin) tables; gemma3 gets a second global-theta pair."""
    if cfg.mrope_sections is not None:
        # text-only degenerate M-RoPE: all three streams = token index
        pos3 = jnp.stack([positions] * len(cfg.mrope_sections), axis=-1)
        c, s = layers.mrope_angles(pos3, cfg.head_dim, cfg.rope_theta,
                                   cfg.mrope_sections)
        return (c, s), (c, s)
    c, s = layers.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    if cfg.rope_theta_global is not None:
        cg, sg = layers.rope_angles(positions, cfg.head_dim,
                                    cfg.rope_theta_global)
        return (c, s), (cg, sg)
    return (c, s), (c, s)


# =============================================================================
# layer bodies
# =============================================================================

def _ffn(lp: dict, cfg: ArchConfig, x: jnp.ndarray, mi: sh.MeshInfo | None,
         valid: jnp.ndarray | None = None):
    """MLP or MoE; returns (y, expert_counts|None, aux_loss).

    ``valid`` (optional bool [B, S]) masks padding rows out of the MoE
    expert-count histogram — bucketed prefill pads sequences, and pad
    rows must not inflate the expert-hotness signal.  The routing/output
    math is untouched (pad rows still flow through and are discarded by
    the caller), only ``counts`` is recomputed from real rows.
    """
    if cfg.is_moe:
        p = moe_mod.MoEParams(**lp["moe"])
        y, (probs, idx, counts) = moe_mod.moe_apply(
            x, p, top_k=cfg.top_k,
            mesh=mi.mesh if mi is not None else None,
            dp_axes=mi.dp_axes if mi is not None else ("data",),
            model_axis=mi.model_axis if mi is not None else "model",
            capacity_factor=cfg.moe_capacity_factor,
            softmax_before_topk=cfg.softmax_before_topk)
        aux = moe_mod.aux_load_balance_loss(
            probs.reshape(-1, cfg.n_experts), idx.reshape(-1, cfg.top_k),
            cfg.n_experts)
        if valid is not None:
            idx_f = idx.reshape(-1, cfg.top_k)
            vrow = valid.reshape(-1).astype(jnp.int32)
            counts = jnp.zeros((cfg.n_experts,), jnp.int32).at[
                idx_f.reshape(-1)].add(
                    jnp.broadcast_to(vrow[:, None], idx_f.shape).reshape(-1))
        return y, counts, aux
    if cfg.mlp_kind == "gelu":
        return layers.gelu_mlp(x, lp["mlp"]["w_up"], lp["mlp"]["w_down"]), None, 0.0
    m = lp["mlp"]
    return layers.swiglu_mlp(x, m["w_gate"], m["w_up"], m["w_down"]), None, 0.0


def _attn_block(lp: dict, cfg: ArchConfig, h, positions, ropes, window,
                use_global, mi: sh.MeshInfo | None, unrolled: bool = False):
    """Pre-norm attention block with optional gemma post-norm."""
    (cl, sl), (cg, sg) = ropes
    cos = jnp.where(use_global, cg, cl) if cfg.rope_theta_global else cl
    sin = jnp.where(use_global, sg, sl) if cfg.rope_theta_global else sl
    x = layers.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                        gemma_style=cfg.gemma_norm)
    p = _attn_from_dict(lp["attn"])
    out, _ = attn_mod.attention(p, x, positions, cos, sin, window=window,
                                soft_cap=cfg.soft_cap,
                                q_chunk=cfg.attn_q_chunk, unrolled=unrolled)
    if cfg.gemma_norm:
        out = layers.rms_norm(out, lp["ln1_post"], eps=cfg.norm_eps,
                              gemma_style=True)
    return h + out


def _ffn_block(lp: dict, cfg: ArchConfig, h, mi: sh.MeshInfo | None,
               valid: jnp.ndarray | None = None):
    x = layers.rms_norm(h, lp["ln2"], eps=cfg.norm_eps,
                        gemma_style=cfg.gemma_norm)
    y, counts, aux = _ffn(lp, cfg, x, mi, valid=valid)
    if cfg.gemma_norm:
        y = layers.rms_norm(y, lp["ln2_post"], eps=cfg.norm_eps,
                            gemma_style=True)
    return h + y, counts, aux


def _shared_attn_block(sp: dict, cfg: ArchConfig, h, positions, ropes,
                       mi: sh.MeshInfo | None, unrolled: bool = False):
    """zamba2 shared transformer block (weights reused at every site)."""
    (cl, sl), _ = ropes
    x = layers.rms_norm(h, sp["ln1"], eps=cfg.norm_eps)
    p = _attn_from_dict(sp["attn"])
    out, _ = attn_mod.attention(p, x, positions, cl, sl,
                                q_chunk=cfg.attn_q_chunk, unrolled=unrolled)
    h = h + out
    x = layers.rms_norm(h, sp["ln2"], eps=cfg.norm_eps)
    m = sp["mlp"]
    h = h + layers.swiglu_mlp(x, m["w_gate"], m["w_up"], m["w_down"])
    return h


# =============================================================================
# training / prefill forward (scan over layers)
# =============================================================================

def _layer_arrays(cfg: ArchConfig):
    """Static per-layer scan inputs: window, is_global, apply_shared."""
    L = cfg.n_layers
    wins = cfg.attn_window_pattern or [0] * L
    window = jnp.asarray(wins, jnp.int32)
    use_global = jnp.asarray([w == 0 for w in wins], bool)
    if cfg.layout == "hybrid" and cfg.shared_attn_every:
        k = cfg.shared_attn_every
        shared = jnp.asarray([(i % k) == (k - 1) for i in range(L)], bool)
    else:
        shared = jnp.zeros((L,), bool)
    return window, use_global, shared


def forward_hidden(params: dict, cfg: ArchConfig, batch: dict,
                   mi: sh.MeshInfo | None = None,
                   unrolled: bool = False) -> tuple[jnp.ndarray, dict]:
    """Hidden states [B, S, d] + metrics (expert counts, aux loss).

    unrolled=True python-loops the layers with static per-layer decisions
    (no lax.scan / lax.cond) — used by the dry-run analysis lowering so
    cost_analysis counts every layer exactly once (XLA counts while-loop
    bodies a single time regardless of trip count).
    """
    h = embed_in(params, cfg, batch, mi)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ropes = _rope_tables(cfg, positions)
    window_a, use_global_a, shared_a = _layer_arrays(cfg)
    mspec = mamba_spec_of(cfg) if cfg.layout in ("mamba", "hybrid") else None
    aspec = sh.act_spec(cfg, mi, seq=True) if mi else None

    if unrolled:
        wins = cfg.attn_window_pattern or [0] * cfg.n_layers
        aux = jnp.float32(0.0)
        counts = (jnp.zeros((cfg.n_experts,), jnp.int32) if cfg.is_moe
                  else None)
        k_every = cfg.shared_attn_every

        def one_layer(h, l):
            lp = _layer_params(params, l)
            if cfg.layout in ("mamba", "hybrid"):
                x = layers.rms_norm(h, lp["ln"], eps=cfg.norm_eps)
                mp = ssm.MambaParams(**lp["mamba"])
                h = h + ssm.mamba_forward(mp, mspec, x)
                if cfg.layout == "hybrid" and k_every and \
                        (l % k_every) == (k_every - 1):
                    h = _shared_attn_block(params["shared"], cfg, h,
                                           positions, ropes, mi,
                                           unrolled=True)
                return h, None, 0.0
            w = wins[l]
            h = _attn_block(lp, cfg, h, positions, ropes,
                            jnp.int32(w), jnp.asarray(w == 0), mi,
                            unrolled=True)
            return _ffn_block(lp, cfg, h, mi)

        for l in range(cfg.n_layers):
            fn = jax.checkpoint(one_layer, static_argnums=(1,)) \
                if cfg.remat else one_layer
            h, c, a = fn(h, l)
            aux = aux + a
            if counts is not None and c is not None:
                counts = counts + c
            if mi is not None:
                h = sh.constrain(h, mi, aspec)
        h = layers.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                            gemma_style=cfg.gemma_norm)
        metrics = {"moe_aux": aux}
        if counts is not None:
            metrics["expert_counts"] = counts
        return h, metrics

    def body(carry, xs):
        h, aux_acc, counts_acc = carry
        lp, window, use_global, shared = xs
        if cfg.layout in ("mamba", "hybrid"):
            x = layers.rms_norm(h, lp["ln"], eps=cfg.norm_eps)
            mp = ssm.MambaParams(**lp["mamba"])
            h = h + ssm.mamba_forward(mp, mspec, x)
            if cfg.layout == "hybrid":
                h = jax.lax.cond(
                    shared,
                    lambda hh: _shared_attn_block(params["shared"], cfg, hh,
                                                  positions, ropes, mi),
                    lambda hh: hh, h)
            counts = None
            aux = 0.0
        else:
            h = _attn_block(lp, cfg, h, positions, ropes, window,
                            use_global, mi)
            h, counts, aux = _ffn_block(lp, cfg, h, mi)
        if mi is not None:
            h = sh.constrain(h, mi, aspec)
        aux_acc = aux_acc + aux
        if counts_acc is not None and counts is not None:
            counts_acc = counts_acc + counts
        return (h, aux_acc, counts_acc), None

    counts0 = (jnp.zeros((cfg.n_experts,), jnp.int32) if cfg.is_moe else None)
    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux, counts), _ = jax.lax.scan(
        body_fn, (h, jnp.float32(0.0), counts0),
        (params["layers"], window_a, use_global_a, shared_a))
    h = layers.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                        gemma_style=cfg.gemma_norm)
    metrics = {"moe_aux": aux}
    if counts is not None:
        metrics["expert_counts"] = counts
    return h, metrics


def loss_fn(params: dict, cfg: ArchConfig, batch: dict,
            mi: sh.MeshInfo | None = None, unrolled: bool = False):
    h, metrics = forward_hidden(params, cfg, batch, mi, unrolled=unrolled)
    logits = logits_out(params, cfg, h)
    loss = layers.softmax_cross_entropy(logits, batch["labels"])
    total = loss + cfg.aux_loss_weight * metrics["moe_aux"]
    metrics = dict(metrics, ce_loss=loss)
    return total, metrics


# =============================================================================
# decode (python loop over layers; heterogeneous caches)
# =============================================================================

def init_decode_state(cfg: ArchConfig, batch_size: int, cache_len: int,
                      dtype=jnp.float32, start_pos: int = 0) -> dict:
    """Empty caches sized for ``cache_len`` total context.

    Windowed layers get ring buffers of their window size; full-attention
    layers get ``cache_len`` slots; SSM layers get O(1) state.
    """
    B = batch_size
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    state: dict = {
        "positions": jnp.full((B,), start_pos, jnp.int32),
        "attn": [], "mamba": [],
    }
    kv_dtype = jnp.int8 if cfg.kv_cache_quant else dtype
    wins = cfg.attn_window_pattern
    for w in wins:
        W = min(w, cache_len) if w > 0 else cache_len
        c = {
            "k": jnp.zeros((B, W, Hkv, Dh), kv_dtype),
            "v": jnp.zeros((B, W, Hkv, Dh), kv_dtype),
            "pos": jnp.full((B, W), -1, jnp.int32),
        }
        if cfg.kv_cache_quant:
            c["k_scale"] = jnp.zeros((B, W, Hkv), jnp.float32)
            c["v_scale"] = jnp.zeros((B, W, Hkv), jnp.float32)
        state["attn"].append(c)
    if cfg.layout in ("mamba", "hybrid"):
        spec = mamba_spec_of(cfg)
        for _ in range(cfg.n_layers):
            state["mamba"].append({
                "h": jnp.zeros((B, spec.n_heads, spec.d_state, spec.headdim),
                               jnp.float32),
                "conv": jnp.zeros((B, spec.d_conv - 1, spec.conv_ch), dtype),
            })
        if cfg.layout == "hybrid":
            k = cfg.shared_attn_every
            n_sites = sum(1 for i in range(cfg.n_layers) if (i % k) == (k - 1))
            state["attn"] = [{
                "k": jnp.zeros((B, cache_len, Hkv, Dh), dtype),
                "v": jnp.zeros((B, cache_len, Hkv, Dh), dtype),
                "pos": jnp.full((B, cache_len), -1, jnp.int32),
            } for _ in range(n_sites)]
    return state


def _tree_slice(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def _layer_params(params: dict, i: int):
    """Layer i's params — stacked arrays (lax.scan layout) or an unstacked
    per-layer list (serve layout: avoids re-reading the whole stacked
    tensor per layer in python-loop decode/prefill)."""
    lay = params["layers"]
    return lay[i] if isinstance(lay, list) else _tree_slice(lay, i)


def unstack_params(params: dict, n_layers: int) -> dict:
    """Convert stacked layer params to the per-layer serve layout."""
    return {**params,
            "layers": [_tree_slice(params["layers"], i)
                       for i in range(n_layers)]}


def decode_step(params: dict, cfg: ArchConfig, state: dict, batch: dict,
                mi: sh.MeshInfo | None = None):
    """One-token decode.  batch: {"tokens": [B,1]} or {"embeds": [B,1,d]}.
    Returns (logits [B,1,vocab_padded], new state)."""
    h = embed_in(params, cfg, batch, mi)
    B = h.shape[0]
    pos = state["positions"]                     # [B]
    positions = pos[:, None]
    ropes = _rope_tables(cfg, positions)
    (cl, sl), (cg, sg) = ropes
    wins = cfg.attn_window_pattern
    mspec = mamba_spec_of(cfg) if cfg.layout in ("mamba", "hybrid") else None
    new_attn = list(state["attn"])
    new_mamba = list(state["mamba"])
    kvspec = sh.kv_cache_spec(mi) if mi else None

    ai = 0
    for l in range(cfg.n_layers):
        lp = _layer_params(params, l)
        if cfg.layout in ("mamba", "hybrid"):
            x = layers.rms_norm(h, lp["ln"], eps=cfg.norm_eps)
            mp = ssm.MambaParams(**lp["mamba"])
            out, hs, cs = ssm.mamba_decode_step(
                mp, mspec, x, state["mamba"][l]["h"], state["mamba"][l]["conv"])
            h = h + out
            new_mamba[l] = {"h": hs, "conv": cs}
            k_every = cfg.shared_attn_every
            if cfg.layout == "hybrid" and k_every and (l % k_every) == (k_every - 1):
                sp = params["shared"]
                x = layers.rms_norm(h, sp["ln1"], eps=cfg.norm_eps)
                c = state["attn"][ai]
                p = _attn_from_dict(sp["attn"])
                out, kc, vc, pc = attn_mod.decode_attention(
                    p, x, c["k"], c["v"], c["pos"], positions, cl, sl)
                h = h + out
                x = layers.rms_norm(h, sp["ln2"], eps=cfg.norm_eps)
                m = sp["mlp"]
                h = h + layers.swiglu_mlp(x, m["w_gate"], m["w_up"], m["w_down"])
                new_attn[ai] = {"k": kc, "v": vc, "pos": pc}
                ai += 1
        else:
            w = wins[l]
            is_global = (w == 0)
            cos = cg if (is_global and cfg.rope_theta_global) else cl
            sin = sg if (is_global and cfg.rope_theta_global) else sl
            x = layers.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                                gemma_style=cfg.gemma_norm)
            c = state["attn"][l]
            p = _attn_from_dict(lp["attn"])
            res = attn_mod.decode_attention(
                p, x, c["k"], c["v"], c["pos"], positions, cos, sin,
                window=(w if w > 0 else None), soft_cap=cfg.soft_cap,
                k_scale=c.get("k_scale"), v_scale=c.get("v_scale"))
            if cfg.kv_cache_quant:
                out, kc, vc, pc, ks, vs = res
            else:
                out, kc, vc, pc = res
            if cfg.gemma_norm:
                out = layers.rms_norm(out, lp["ln1_post"], eps=cfg.norm_eps,
                                      gemma_style=True)
            h = h + out
            h, _, _ = _ffn_block(lp, cfg, h, mi)
            if mi is not None:
                kc = sh.constrain(kc, mi, kvspec)
                vc = sh.constrain(vc, mi, kvspec)
            nc = {"k": kc, "v": vc, "pos": pc}
            if cfg.kv_cache_quant:
                nc["k_scale"] = ks
                nc["v_scale"] = vs
            new_attn[l] = nc

    h = layers.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                        gemma_style=cfg.gemma_norm)
    logits = logits_out(params, cfg, h)
    new_state = {"positions": pos + 1, "attn": new_attn, "mamba": new_mamba}
    return logits, new_state


def prefill(params: dict, cfg: ArchConfig, batch: dict, cache_len: int,
            mi: sh.MeshInfo | None = None, unrolled: bool = False):
    """Process a full prompt; returns (last-token logits, decode state).

    Python loop over layers so each layer's K/V lands directly in its cache
    (ring-placed for windowed layers)."""
    h = embed_in(params, cfg, batch, mi)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ropes = _rope_tables(cfg, positions)
    (cl, sl), (cg, sg) = ropes
    wins = cfg.attn_window_pattern
    mspec = mamba_spec_of(cfg) if cfg.layout in ("mamba", "hybrid") else None
    state = init_decode_state(cfg, B, cache_len, dtype=h.dtype, start_pos=S)
    kvspec = sh.kv_cache_spec(mi) if mi else None

    def place(cache, k, v):
        W = cache["k"].shape[1]
        n = min(S, W)
        idx = (jnp.arange(S - n, S) % W).astype(jnp.int32)
        out = {}
        if cfg.kv_cache_quant:
            def q8(u):
                sc = jnp.maximum(jnp.max(jnp.abs(u.astype(jnp.float32)), -1)
                                 / 127.0, 1e-8)
                return (jnp.clip(jnp.round(u / sc[..., None]), -127, 127)
                        .astype(jnp.int8), sc)
            k8, ks = q8(k[:, S - n:])
            v8, vs = q8(v[:, S - n:])
            kc = cache["k"].at[:, idx].set(k8)
            vc = cache["v"].at[:, idx].set(v8)
            out["k_scale"] = cache["k_scale"].at[:, idx].set(ks)
            out["v_scale"] = cache["v_scale"].at[:, idx].set(vs)
        else:
            kc = cache["k"].at[:, idx].set(k[:, S - n:].astype(cache["k"].dtype))
            vc = cache["v"].at[:, idx].set(v[:, S - n:].astype(cache["v"].dtype))
        pc = cache["pos"].at[:, idx].set(jnp.arange(S - n, S, dtype=jnp.int32))
        if mi is not None:
            kc = sh.constrain(kc, mi, kvspec)
            vc = sh.constrain(vc, mi, kvspec)
        return {"k": kc, "v": vc, "pos": pc, **out}

    ai = 0
    for l in range(cfg.n_layers):
        lp = _layer_params(params, l)
        if cfg.layout in ("mamba", "hybrid"):
            x = layers.rms_norm(h, lp["ln"], eps=cfg.norm_eps)
            mp = ssm.MambaParams(**lp["mamba"])
            out, (hs, conv_tail) = ssm.mamba_forward(mp, mspec, x,
                                                     return_state=True)
            h = h + out
            state["mamba"][l] = {"h": hs, "conv": conv_tail.astype(h.dtype)}
            k_every = cfg.shared_attn_every
            if cfg.layout == "hybrid" and k_every and (l % k_every) == (k_every - 1):
                sp = params["shared"]
                x = layers.rms_norm(h, sp["ln1"], eps=cfg.norm_eps)
                p = _attn_from_dict(sp["attn"])
                out, (k, v) = attn_mod.attention(p, x, positions, cl, sl)
                h = h + out
                x = layers.rms_norm(h, sp["ln2"], eps=cfg.norm_eps)
                m = sp["mlp"]
                h = h + layers.swiglu_mlp(x, m["w_gate"], m["w_up"], m["w_down"])
                state["attn"][ai] = place(state["attn"][ai], k, v)
                ai += 1
        else:
            w = wins[l]
            is_global = (w == 0)
            cos = cg if (is_global and cfg.rope_theta_global) else cl
            sin = sg if (is_global and cfg.rope_theta_global) else sl
            x = layers.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                                gemma_style=cfg.gemma_norm)
            p = _attn_from_dict(lp["attn"])
            out, (k, v) = attn_mod.attention(
                p, x, positions, cos, sin, window=(w if w > 0 else None),
                soft_cap=cfg.soft_cap, q_chunk=cfg.attn_q_chunk,
                unrolled=unrolled)
            if cfg.gemma_norm:
                out = layers.rms_norm(out, lp["ln1_post"], eps=cfg.norm_eps,
                                      gemma_style=True)
            h = h + out
            h, _, _ = _ffn_block(lp, cfg, h, mi)
            state["attn"][l] = place(state["attn"][l], k, v)
        if mi is not None:
            h = sh.constrain(h, mi, sh.act_spec(cfg, mi, seq=True))

    h = layers.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                        gemma_style=cfg.gemma_norm)
    logits = logits_out(params, cfg, h[:, -1:, :])
    return logits, state
