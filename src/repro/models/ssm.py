"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward for train/prefill (O(L·Q) intra-chunk matmuls + an
O(L/Q) inter-chunk scan) and an O(1)-state decode step.  The intra-chunk
block-matmul is the compute hot-spot and has a Pallas kernel
(kernels/ssd_scan); this module is the pure-jnp implementation used as the
oracle and the dry-run lowering path.

Layout: d_inner = expand * d_model; heads H = d_inner / headdim P;
B/C shared across heads within G groups (G=1 here); state size N.

Sharding: heads are sharded over the `model` mesh axis (H % |model| == 0
for the assigned archs); B/C are group-shared and replicated; the SSM
state [B, H, N, P] shards on H.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MambaParams(NamedTuple):
    in_proj_z: jnp.ndarray    # [d, d_inner]
    in_proj_x: jnp.ndarray    # [d, d_inner]
    in_proj_B: jnp.ndarray    # [d, G*N]
    in_proj_C: jnp.ndarray    # [d, G*N]
    in_proj_dt: jnp.ndarray   # [d, H]
    conv_w: jnp.ndarray       # [K, conv_ch]  depthwise over (x ‖ B ‖ C)
    conv_b: jnp.ndarray       # [conv_ch]
    dt_bias: jnp.ndarray      # [H]
    A_log: jnp.ndarray        # [H]
    D: jnp.ndarray            # [H]
    norm: jnp.ndarray         # [d_inner]  gated RMSNorm scale
    out_proj: jnp.ndarray     # [d_inner, d]


class MambaSpec(NamedTuple):
    d_model: int
    d_inner: int
    headdim: int
    n_heads: int
    d_state: int
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128

    @property
    def conv_ch(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def make_spec(d_model: int, *, expand: int = 2, headdim: int = 64,
              d_state: int = 128, d_conv: int = 4, chunk: int = 128) -> MambaSpec:
    d_inner = expand * d_model
    return MambaSpec(d_model=d_model, d_inner=d_inner, headdim=headdim,
                     n_heads=d_inner // headdim, d_state=d_state,
                     d_conv=d_conv, chunk=chunk)


def init_mamba_params(key, spec: MambaSpec, dtype=jnp.float32) -> MambaParams:
    ks = jax.random.split(key, 6)
    d, di, H = spec.d_model, spec.d_inner, spec.n_heads
    gn = spec.n_groups * spec.d_state
    s = d ** -0.5
    return MambaParams(
        in_proj_z=(jax.random.normal(ks[0], (d, di)) * s).astype(dtype),
        in_proj_x=(jax.random.normal(ks[1], (d, di)) * s).astype(dtype),
        in_proj_B=(jax.random.normal(ks[2], (d, gn)) * s).astype(dtype),
        in_proj_C=(jax.random.normal(ks[3], (d, gn)) * s).astype(dtype),
        in_proj_dt=(jax.random.normal(ks[4], (d, H)) * s).astype(dtype),
        conv_w=(jax.random.normal(ks[5], (spec.d_conv, spec.conv_ch)) * 0.1
                ).astype(dtype),
        conv_b=jnp.zeros((spec.conv_ch,), dtype),
        dt_bias=jnp.full((H,), -4.0, dtype),  # softplus(-4) ~ 0.018
        A_log=jnp.zeros((H,), dtype),         # A = -exp(0) = -1
        D=jnp.ones((H,), dtype),
        norm=jnp.ones((di,), dtype),
        out_proj=(jax.random.normal(key, (di, d)) * di ** -0.5).astype(dtype),
    )


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                           ) -> jnp.ndarray:
    """x: [B, L, C]; w: [K, C] depthwise causal conv + silu."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None):
    """Chunked SSD scan.

    x:  [B, L, H, P]   dt: [B, L, H] (post-softplus)
    A:  [H] (negative)  Bm/Cm: [B, L, G, N]
    Returns (y [B, L, H, P], h_final [B, H, N, P]).
    """
    Bsz, L, H, Pd = x.shape
    G = Bm.shape[2]
    hpg = H // G
    Q = chunk
    L0 = L
    if L % Q:  # pad to a chunk multiple; padded steps are identity
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> decay=1, no input
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nC = L // Q

    f32 = jnp.float32
    xq = x.reshape(Bsz, nC, Q, H, Pd).astype(f32)
    dtq = dt.reshape(Bsz, nC, Q, H).astype(f32)
    Bq = Bm.reshape(Bsz, nC, Q, G, N := Bm.shape[-1]).astype(f32)
    Cq = Cm.reshape(Bsz, nC, Q, G, N).astype(f32)

    dA = dtq * A.astype(f32)                       # [B, nC, Q, H]
    dA_cs = jnp.cumsum(dA, axis=2)                 # inclusive cumsum

    # --- intra-chunk (diagonal blocks) -------------------------------------
    # att[b,c,h,i,j] = (C_i · B_j) * exp(dA_cs[i] - dA_cs[j]) * dt[j], j<=i
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cq, Bq)  # [B,nC,G,Q,Q]
    CB = jnp.repeat(CB, hpg, axis=2)               # expand groups -> heads
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]   # [B,nC,Q,Q,H]
    seg = jnp.transpose(seg, (0, 1, 4, 2, 3))      # [B,nC,H,Q,Q]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    att = jnp.where(tri, CB * jnp.exp(seg), 0.0)
    att = att * jnp.transpose(dtq, (0, 1, 3, 2))[:, :, :, None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", att, xq)

    # --- chunk states -------------------------------------------------------
    # S_c = sum_j exp(dA_sum - dA_cs[j]) * dt_j * B_j ⊗ x_j   [B,nC,H,N,P]
    dA_sum = dA_cs[:, :, -1:, :]                   # [B,nC,1,H]
    decay_to_end = jnp.exp(dA_sum - dA_cs)         # [B,nC,Q,H]
    # B per head: [B,nC,Q,H,N]
    Bh = jnp.repeat(Bq, hpg, axis=3) if hpg > 1 else Bq
    Bh = Bh.reshape(Bsz, nC, Q, H, N)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                        decay_to_end * dtq, Bh, xq)

    # --- inter-chunk recurrence (scan over chunks) ---------------------------
    chunk_decay = jnp.exp(dA_sum[:, :, 0, :])      # [B,nC,H]

    def step(h, inp):
        s_c, dec = inp                             # [B,H,N,P], [B,H]
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h                            # emit state *before* chunk

    h_init = (jnp.zeros((Bsz, H, N, Pd), f32) if h0 is None
              else h0.astype(f32))
    h_fin, h_prev = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)            # [B,nC,H,N,P]

    # --- inter-chunk output: C_i · h_prev * exp(dA_cs[i]) --------------------
    Ch = jnp.repeat(Cq, hpg, axis=3) if hpg > 1 else Cq
    Ch = Ch.reshape(Bsz, nC, Q, H, N)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", Ch, h_prev)
    y_off = y_off * jnp.exp(dA_cs)[..., None]

    y = (y_diag + y_off).reshape(Bsz, L, H, Pd)[:, :L0]
    return y, h_fin


def mamba_forward(p: MambaParams, spec: MambaSpec, x: jnp.ndarray,
                  *, h0=None, conv0=None, return_state: bool = False):
    """Full Mamba-2 block over x [B, L, d] -> [B, L, d]."""
    Bsz, L, d = x.shape
    H, Pd, N, G = spec.n_heads, spec.headdim, spec.d_state, spec.n_groups

    z = jnp.einsum("bld,de->ble", x, p.in_proj_z)
    xs = jnp.einsum("bld,de->ble", x, p.in_proj_x)
    Bp = jnp.einsum("bld,de->ble", x, p.in_proj_B)
    Cp = jnp.einsum("bld,de->ble", x, p.in_proj_C)
    dt = jnp.einsum("bld,dh->blh", x, p.in_proj_dt)

    # depthwise conv is per-channel, so convolve x / B / C separately with
    # static slices of the shared conv weight: x stays `model`-sharded on
    # its channels, B/C stay replicated — no concat, no all-gather.
    di, gn = spec.d_inner, G * N
    conv_tail_raw = None
    if return_state:
        conv_tail_raw = jnp.concatenate(
            [xs[:, -(spec.d_conv - 1):], Bp[:, -(spec.d_conv - 1):],
             Cp[:, -(spec.d_conv - 1):]], axis=-1)

    def conv_part(u, lo, hi, ctx=None):
        w, b = p.conv_w[:, lo:hi], p.conv_b[lo:hi]
        if ctx is not None:
            u2 = jnp.concatenate([ctx, u], axis=1)
            return _causal_depthwise_conv(u2, w, b)[:, ctx.shape[1]:]
        return _causal_depthwise_conv(u, w, b)

    c0 = (None, None, None) if conv0 is None else (
        conv0[..., :di], conv0[..., di:di + gn], conv0[..., di + gn:])
    xs = conv_part(xs, 0, di, c0[0])
    Bp = conv_part(Bp, di, di + gn, c0[1])
    Cp = conv_part(Cp, di + gn, di + 2 * gn, c0[2])

    xh = xs.reshape(Bsz, L, H, Pd)
    Bm = Bp.reshape(Bsz, L, G, N)
    Cm = Cp.reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))
    A = -jnp.exp(p.A_log.astype(jnp.float32))

    y, h_fin = ssd_chunked(xh, dt, A, Bm, Cm, spec.chunk, h0)
    y = y + xh.astype(jnp.float32) * p.D.astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, L, spec.d_inner)

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p.norm.astype(jnp.float32)
    out = jnp.einsum("ble,ed->bld", y.astype(x.dtype), p.out_proj)
    if return_state:
        return out, (h_fin, conv_tail_raw)
    return out


def mamba_decode_step(p: MambaParams, spec: MambaSpec, x: jnp.ndarray,
                      h: jnp.ndarray, conv_state: jnp.ndarray):
    """One-token decode.  x: [B, 1, d]; h: [B, H, N, P];
    conv_state: [B, d_conv-1, conv_ch] rolling raw xBC context.
    Returns (out [B,1,d], h, conv_state)."""
    Bsz = x.shape[0]
    H, Pd, N, G = spec.n_heads, spec.headdim, spec.d_state, spec.n_groups

    z = jnp.einsum("bld,de->ble", x, p.in_proj_z)[:, 0]
    xs = jnp.einsum("bld,de->ble", x, p.in_proj_x)[:, 0]
    Bp = jnp.einsum("bld,de->ble", x, p.in_proj_B)[:, 0]
    Cp = jnp.einsum("bld,de->ble", x, p.in_proj_C)[:, 0]
    dt = jnp.einsum("bld,dh->blh", x, p.in_proj_dt)[:, 0]

    xbc = jnp.concatenate([xs, Bp, Cp], axis=-1)      # [B, conv_ch]
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p.conv_w) + p.conv_b
    conv_out = jax.nn.silu(conv_out)
    conv_state = window[:, 1:, :]

    xs = conv_out[..., :spec.d_inner].reshape(Bsz, H, Pd).astype(jnp.float32)
    Bm = conv_out[..., spec.d_inner:spec.d_inner + G * N].reshape(Bsz, G, N)
    Cm = conv_out[..., spec.d_inner + G * N:].reshape(Bsz, G, N)
    hpg = H // G
    Bh = jnp.repeat(Bm, hpg, axis=1).astype(jnp.float32)   # [B, H, N]
    Ch = jnp.repeat(Cm, hpg, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))
    A = -jnp.exp(p.A_log.astype(jnp.float32))
    dec = jnp.exp(dt * A)                                   # [B, H]
    h = h * dec[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xs)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    y = y + xs * p.D.astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, spec.d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p.norm.astype(jnp.float32)
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p.out_proj)
    return out[:, None, :], h, conv_state
