"""Attention: GQA with RoPE / M-RoPE, causal + sliding-window masks,
qk-norm, QKV bias; prefill and decode (dense or paged KV) paths.

Shapes:  x [B, S, d];  q [B, S, Hq, Dh];  k/v [B, S, Hkv, Dh].
The window parameter is a *traced scalar* so local/global layer patterns
(gemma3 5:1) run through one trace with a per-layer window array instead
of distinct branches.

All einsums keep the head axis explicit so the `model` mesh axis can shard
either the head count or (when heads don't divide the axis) the head_dim —
interleaved-pair RoPE keeps rotation pairs contiguous under Dh sharding.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers

NEG_INF = -2.0e38


class AttnParams(NamedTuple):
    wq: jnp.ndarray            # [d, Hq, Dh]
    wk: jnp.ndarray            # [d, Hkv, Dh]
    wv: jnp.ndarray            # [d, Hkv, Dh]
    wo: jnp.ndarray            # [Hq, Dh, d]
    bq: jnp.ndarray | None     # [Hq, Dh] or None  (qwen2 QKV bias)
    bk: jnp.ndarray | None
    bv: jnp.ndarray | None
    q_norm: jnp.ndarray | None  # [Dh] qk_norm scales (qwen3)
    k_norm: jnp.ndarray | None


def init_attn_params(key, d_model: int, n_heads: int, n_kv_heads: int,
                     d_head: int, *, qkv_bias: bool = False,
                     qk_norm: bool = False, dtype=jnp.float32) -> AttnParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return AttnParams(
        wq=(jax.random.normal(k1, (d_model, n_heads, d_head)) * s).astype(dtype),
        wk=(jax.random.normal(k2, (d_model, n_kv_heads, d_head)) * s).astype(dtype),
        wv=(jax.random.normal(k3, (d_model, n_kv_heads, d_head)) * s).astype(dtype),
        wo=(jax.random.normal(k4, (n_heads, d_head, d_model)) * s).astype(dtype),
        bq=jnp.zeros((n_heads, d_head), dtype) if qkv_bias else None,
        bk=jnp.zeros((n_kv_heads, d_head), dtype) if qkv_bias else None,
        bv=jnp.zeros((n_kv_heads, d_head), dtype) if qkv_bias else None,
        q_norm=jnp.ones((d_head,), dtype) if qk_norm else None,
        k_norm=jnp.ones((d_head,), dtype) if qk_norm else None,
    )


def project_qkv(p: AttnParams, x: jnp.ndarray,
                cos: jnp.ndarray, sin: jnp.ndarray):
    """Project + (optional bias, qk-norm) + RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    if p.q_norm is not None:
        q = layers.rms_norm(q, p.q_norm)
        k = layers.rms_norm(k, p.k_norm)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    return q, k, v


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
               window: jnp.ndarray | int | None) -> jnp.ndarray:
    """Additive mask bias [.., Sq, Sk]: causal + optional sliding window.

    window is a traced scalar (tokens of look-back); <=0 or None = full
    causal. Positions may be batched ([B, S]) or flat ([S])."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = dk <= dq
    if window is not None:
        w = jnp.asarray(window)
        in_win = (dq - dk) < jnp.where(w > 0, w, jnp.iinfo(jnp.int32).max)
        ok = ok & in_win
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask_bias: jnp.ndarray, *, soft_cap: float | None = None,
         q_chunk: int | None = None, unrolled: bool = False) -> jnp.ndarray:
    """Scaled dot-product attention, KV-expansion form (train/prefill).

    q: [B, Sq, Hq, Dh]; k/v: [B, Sk, Hkv, Dh]; mask_bias: [B|1, Sq, Sk].
    GQA KV is expanded to Hq heads so every einsum is uniformly sharded on
    the q-head axis under TP (the expansion is a broadcast-slice per shard,
    free of collectives; Sk here is the activation length, so the extra
    bytes are small — decode uses the grouped form below instead).

    q_chunk (§Perf iteration 1): flash-style query chunking — only a
    [B, H, q_chunk, Sk] logits block materializes at a time (the full
    softmax row lives within a chunk, so no online-softmax state is
    needed), and jax.checkpoint on the chunk body keeps the backward pass
    from saving any logits.  ``unrolled=True`` python-loops the chunks so
    dry-run cost analysis counts them exactly; deployment uses lax.scan.
    The Pallas flash kernel (kernels/flash_attention) is the TPU runtime
    equivalent with the same blocking.
    """
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = Dh ** -0.5
    kf = k.astype(jnp.float32)

    def dense(qc: jnp.ndarray, mb: jnp.ndarray) -> jnp.ndarray:
        logits = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32) * scale,
                            kf)
        if soft_cap is not None:
            logits = jnp.tanh(logits / soft_cap) * soft_cap
        logits = logits + mb[:, None, :, :]
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)

    return dense(q, mask_bias)


def sdpa_qchunked(q, k, v, positions, *, window=None, soft_cap=None,
                  q_chunk: int = 1024, unrolled: bool = False):
    """Query-chunked sdpa: per-chunk mask construction + jax.checkpoint on
    the chunk body, so neither the [Sq, Sk] mask nor any logits block
    bigger than [B, H, q_chunk, Sk] ever materializes (fwd or bwd)."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if Sq % q_chunk or Sq <= q_chunk:
        bias = _mask_bias(positions, positions, window)
        return sdpa(q, k, v, bias, soft_cap=soft_cap)
    scale = Dh ** -0.5
    kf = k.astype(jnp.float32)
    k_pos = positions

    def body(qc, qpos):
        logits = jnp.einsum("bqhd,bkhd->bhqk",
                            qc.astype(jnp.float32) * scale, kf)
        if soft_cap is not None:
            logits = jnp.tanh(logits / soft_cap) * soft_cap
        logits = logits + _mask_bias(qpos, k_pos, window)[:, None, :, :]
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)

    body = jax.checkpoint(body)
    nq = Sq // q_chunk
    if unrolled:
        outs = [body(q[:, i * q_chunk:(i + 1) * q_chunk],
                     positions[:, i * q_chunk:(i + 1) * q_chunk])
                for i in range(nq)]
        return jnp.concatenate(outs, axis=1)
    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, Hq, Dh), 1, 0)
    ps = jnp.moveaxis(positions.reshape(B, nq, q_chunk), 1, 0)
    outs = jax.lax.map(lambda args: body(*args), (qs, ps))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, Dh)


def sdpa_grouped(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 mask_bias: jnp.ndarray, *,
                 soft_cap: float | None = None) -> jnp.ndarray:
    """Grouped-query form (decode): never expands the KV cache.

    q: [B, Sq, Hq, Dh]; k/v: [B, Sk, Hkv, Dh].  With the cache sequence
    dim sharded over `model`, the softmax reductions and the PV contraction
    become tiny cross-shard psums — distributed flash-decode."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    scale = Dh ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if soft_cap is not None:
        logits = jnp.tanh(logits / soft_cap) * soft_cap
    logits = logits + mask_bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, Dh)


def attention(p: AttnParams, x: jnp.ndarray, positions: jnp.ndarray,
              cos: jnp.ndarray, sin: jnp.ndarray,
              *, window: jnp.ndarray | int | None = None,
              soft_cap: float | None = None,
              q_chunk: int | None = None,
              unrolled: bool = False) -> jnp.ndarray:
    """Full self-attention over x (training / prefill). positions: [B, S]."""
    q, k, v = project_qkv(p, x, cos, sin)
    if q_chunk is not None:
        out = sdpa_qchunked(q, k, v, positions, window=window,
                            soft_cap=soft_cap, q_chunk=q_chunk,
                            unrolled=unrolled)
    else:
        bias = _mask_bias(positions, positions, window)
        out = sdpa(q, k, v, bias, soft_cap=soft_cap)
    return jnp.einsum("bshk,hkd->bsd", out, p.wo), (k, v)


def decode_attention(p: AttnParams, x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos_cache: jnp.ndarray, positions: jnp.ndarray,
                     cos: jnp.ndarray, sin: jnp.ndarray,
                     *, window: jnp.ndarray | int | None = None,
                     soft_cap: float | None = None,
                     k_scale: jnp.ndarray | None = None,
                     v_scale: jnp.ndarray | None = None):
    """One-token decode against a (dense or rolling-window) KV cache.

    x: [B, 1, d]; k/v_cache: [B, Smax, Hkv, Dh]; pos_cache: int32 [B, Smax]
    giving the *token position* held by each cache slot (-1 = empty);
    positions: [B, 1] position of the new token.  The write slot is
    ``position % Smax`` — identity for a full-length cache, a rolling
    ring-buffer for a sliding-window cache (Smax = window), which is how
    mixtral SWA / gemma3 local layers bound KV at 500k context.

    With int8 caches (k/v_scale given, per-[B, slot, Hkv] scales), the new
    token's K/V quantize on write and the attend dequantizes on read —
    halving decode's dominant HBM term (KV bytes).  On TPU the
    paged_attention kernel performs the dequant in VMEM; the memos slow
    tier uses the same trick for cold pages (TierStore.quantize_slow).

    Returns (out [B,1,d], k_cache, v_cache, pos_cache[, k_scale, v_scale]).
    The Pallas paged kernel (kernels/paged_attention) replaces the attend
    on TPU serving.
    """
    B, _, _ = x.shape
    Smax = k_cache.shape[1]
    q, k_new, v_new = project_qkv(p, x, cos, sin)
    quantized = k_scale is not None

    # scatter-append at the ring slot (not a full-cache rewrite — keeps
    # decode memory traffic O(B·Hkv·Dh), not O(B·Smax·Hkv·Dh))
    b_idx = jnp.arange(B)
    pos = positions[:, 0]
    slot = pos % Smax
    if quantized:
        def q8(u):  # [B, Hkv, Dh] -> int8 + per-head scale
            s = jnp.max(jnp.abs(u.astype(jnp.float32)), axis=-1) / 127.0
            s = jnp.maximum(s, 1e-8)
            return (jnp.clip(jnp.round(u / s[..., None]), -127, 127)
                    .astype(jnp.int8), s)
        k8, ks = q8(k_new[:, 0])
        v8, vs = q8(v_new[:, 0])
        k_cache = k_cache.at[b_idx, slot].set(k8)
        v_cache = v_cache.at[b_idx, slot].set(v8)
        k_scale = k_scale.at[b_idx, slot].set(ks)
        v_scale = v_scale.at[b_idx, slot].set(vs)
        k_read = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_read = v_cache.astype(jnp.float32) * v_scale[..., None]
    else:
        k_cache = k_cache.at[b_idx, slot].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[b_idx, slot].set(v_new[:, 0].astype(v_cache.dtype))
        k_read, v_read = k_cache, v_cache
    pos_cache = pos_cache.at[b_idx, slot].set(pos.astype(pos_cache.dtype))

    valid = (pos_cache >= 0) & (pos_cache <= pos[:, None])
    if window is not None:
        w = jnp.asarray(window)
        valid = valid & ((pos[:, None] - pos_cache)
                         < jnp.where(w > 0, w, jnp.iinfo(jnp.int32).max))
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[:, None, :]

    out = sdpa_grouped(q, k_read, v_read, bias, soft_cap=soft_cap)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p.wo)
    if quantized:
        return out, k_cache, v_cache, pos_cache, k_scale, v_scale
    return out, k_cache, v_cache, pos_cache
