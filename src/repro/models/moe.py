"""Mixture-of-Experts FFN: top-k routing, sort-based grouped GEMM
(`lax.ragged_dot`), and explicit expert/tensor parallelism via shard_map.

Parallelism policy (DESIGN.md Sec. 3.3):
  * E >= model-axis size  -> **EP**: experts sharded over `model`; tokens
    (replicated across `model` under TP) are selected per shard by a
    stable sort on expert id with a per-shard capacity, computed with the
    shard's local experts, and combined with a psum — the same psum a
    dense TP MLP needs, so EP adds no extra collective traffic.
  * E <  model-axis size  -> **TP**: every shard holds all experts' d_ff
    slice; sorted grouped GEMM over the slice, psum of the down-proj.

Expert hotness for memos: the router's per-expert token counts are exactly
the paper's bank-utilization histogram (Algorithm 1); they are returned to
the caller so SysMon can track expert pages and the placement engine can
rebalance expert->device maps (bank rebalancing) and tier cold experts.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # pre-0.5 jax: experimental namespace, replication check spelled check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


class MoEParams(NamedTuple):
    w_router: jnp.ndarray   # [d, E]
    w_gate: jnp.ndarray     # [E, d, ff]
    w_up: jnp.ndarray       # [E, d, ff]
    w_down: jnp.ndarray     # [E, ff, d]


def init_moe_params(key, d_model: int, n_experts: int, d_ff: int,
                    dtype=jnp.float32) -> MoEParams:
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return MoEParams(
        w_router=(jax.random.normal(k0, (d_model, n_experts)) * s).astype(dtype),
        w_gate=(jax.random.normal(k1, (n_experts, d_model, d_ff)) * s).astype(dtype),
        w_up=(jax.random.normal(k2, (n_experts, d_model, d_ff)) * s).astype(dtype),
        w_down=(jax.random.normal(k3, (n_experts, d_ff, d_model)) * s).astype(dtype),
    )


def route(x_flat: jnp.ndarray, w_router: jnp.ndarray, top_k: int,
          *, norm_topk: bool = True, softmax_before_topk: bool = True):
    """Top-k routing.  Returns (weights [T,k] f32, idx [T,k] i32,
    probs [T,E] f32, counts [E] i32 — the expert hotness histogram)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    if softmax_before_topk:          # olmoe style
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, top_k)
    else:                            # mixtral style: softmax over the top-k
        top_logits, idx = jax.lax.top_k(logits, top_k)
        w = jax.nn.softmax(top_logits, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    if norm_topk:
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    counts = jnp.zeros(w_router.shape[1], jnp.int32).at[idx.reshape(-1)].add(1)
    return w, idx.astype(jnp.int32), probs, counts


def aux_load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing aux loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    f = jnp.zeros(n_experts, jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(T * idx.shape[-1], 1)
    pbar = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pbar)


def _grouped_ffn(xg: jnp.ndarray, gs: jnp.ndarray, w_gate, w_up, w_down,
                 act=jax.nn.silu) -> jnp.ndarray:
    """Grouped SwiGLU over expert-sorted rows via ragged_dot."""
    g = jax.lax.ragged_dot(xg, w_gate, gs, preferred_element_type=jnp.float32)
    u = jax.lax.ragged_dot(xg, w_up, gs, preferred_element_type=jnp.float32)
    h = (act(g) * u).astype(xg.dtype)
    y = jax.lax.ragged_dot(h, w_down, gs, preferred_element_type=jnp.float32)
    return y


def moe_sorted_local(x_flat: jnp.ndarray, p: MoEParams, top_k: int,
                     *, softmax_before_topk: bool = True,
                     norm_topk: bool = True, act=jax.nn.silu):
    """Single-shard sort-based MoE over all experts (no dropping).

    Used standalone on one device and as the per-shard body of the TP path
    (where p.w_gate/up/down are the shard's d_ff slice)."""
    T, d = x_flat.shape
    E = p.w_router.shape[1]
    w, idx, probs, counts = route(x_flat, p.w_router, top_k,
                                  norm_topk=norm_topk,
                                  softmax_before_topk=softmax_before_topk)
    flat_e = idx.reshape(-1)                                 # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    tok = order // top_k
    xg = x_flat[tok]                                          # [T*k, d]
    gs = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    y = _grouped_ffn(xg, gs, p.w_gate, p.w_up, p.w_down, act)  # [T*k, d] f32
    gatew = w.reshape(-1)[order]
    y = y * gatew[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(y)
    return out.astype(x_flat.dtype), probs, idx, counts


def _ep_shard_body(x_flat, p: MoEParams, top_k, n_ep, capacity,
                   model_axis, softmax_before_topk, norm_topk, act):
    """Per-shard EP body (runs under shard_map; x replicated over `model`)."""
    T, d = x_flat.shape
    E = p.w_router.shape[1]           # local view: w_router replicated
    E_local = E // n_ep
    m = jax.lax.axis_index(model_axis) % n_ep

    w, idx, probs, counts = route(x_flat, p.w_router, top_k,
                                  norm_topk=norm_topk,
                                  softmax_before_topk=softmax_before_topk)
    flat_e = idx.reshape(-1)                                   # [T*k]
    local_e = flat_e - m * E_local
    mine = (local_e >= 0) & (local_e < E_local)
    key = jnp.where(mine, local_e, E_local)                    # not-mine last
    order = jnp.argsort(key, stable=True)[:capacity]           # mine first
    valid = key[order] < E_local
    tok = order // top_k
    xg = x_flat[tok] * valid[:, None].astype(x_flat.dtype)

    # group sizes over local experts; invalid tail rides in the last group
    cnt = jnp.zeros(E_local + 1, jnp.int32).at[key[order]].add(1)
    gs = cnt[:E_local].at[E_local - 1].add(cnt[E_local])

    y = _grouped_ffn(xg, gs, p.w_gate, p.w_up, p.w_down, act)   # local experts
    gatew = w.reshape(-1)[order] * valid
    y = y * gatew[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(y)
    out = jax.lax.psum(out, model_axis)
    return out.astype(x_flat.dtype), probs, idx, counts


def _tp_shard_body(x_flat, p: MoEParams, top_k, model_axis,
                   softmax_before_topk, norm_topk, act):
    """Per-shard TP body: all experts present, d_ff sliced over `model`."""
    out, probs, idx, counts = moe_sorted_local(
        x_flat, p, top_k, softmax_before_topk=softmax_before_topk,
        norm_topk=norm_topk, act=act)
    out = jax.lax.psum(out.astype(jnp.float32), model_axis).astype(x_flat.dtype)
    return out, probs, idx, counts


def moe_apply(x: jnp.ndarray, p: MoEParams, *, top_k: int,
              mesh: jax.sharding.Mesh | None = None,
              dp_axes: tuple[str, ...] = ("data",), model_axis: str = "model",
              capacity_factor: float = 1.25,
              softmax_before_topk: bool = True, norm_topk: bool = True,
              act=jax.nn.silu):
    """MoE FFN over x [B, S, d].  Returns (y [B,S,d], aux) where aux carries
    (router probs, topk idx, expert counts) for the aux loss and SysMon.

    With a mesh, runs under shard_map with EP when E >= |model| else TP.
    """
    B, S, d = x.shape
    E = p.w_router.shape[1]
    xf = x.reshape(B * S, d)

    if mesh is None:
        y, probs, idx, counts = moe_sorted_local(
            xf, p, top_k, softmax_before_topk=softmax_before_topk,
            norm_topk=norm_topk, act=act)
        return y.reshape(B, S, d), (probs, idx, counts)

    import math
    n_model = mesh.shape[model_axis]
    n_dp = math.prod(mesh.shape[a] for a in dp_axes)
    # tiny decode batches (long-context, B=1) replicate over the data axes
    dp_replicated = (B * S) % n_dp != 0
    dp_spec = P(None) if dp_replicated else P(dp_axes)
    use_ep = E >= n_model and E % n_model == 0

    if use_ep:
        n_ep = n_model
        T_local = (B * S) if dp_replicated else (B * S) // n_dp
        capacity = int(T_local * top_k / n_ep * capacity_factor)
        capacity = max(8, -(-capacity // 8) * 8)  # round up to 8
        capacity = min(capacity, T_local * top_k)
        pspec = MoEParams(P(), P(model_axis, None, None),
                          P(model_axis, None, None), P(model_axis, None, None))
        body = partial(_ep_shard_body, top_k=top_k, n_ep=n_ep,
                       capacity=capacity, model_axis=model_axis,
                       softmax_before_topk=softmax_before_topk,
                       norm_topk=norm_topk, act=act)
    else:
        pspec = MoEParams(P(), P(None, None, model_axis),
                          P(None, None, model_axis), P(None, model_axis, None))
        body = partial(_tp_shard_body, top_k=top_k, model_axis=model_axis,
                       softmax_before_topk=softmax_before_topk,
                       norm_topk=norm_topk, act=act)

    out_specs = (dp_spec, dp_spec, dp_spec, P())  # y, probs, idx, counts

    def wrapped(xx, pp):
        y, probs, idx, counts = body(xx, pp)
        # expert histogram: global sum (SysMon's bank-frequency table)
        if not dp_replicated:
            counts = jax.lax.psum(counts, dp_axes)
        return y, probs, idx, counts

    fn = _shard_map(
        wrapped,
        mesh=mesh,
        in_specs=(dp_spec, pspec),
        out_specs=out_specs,
        **{_CHECK_KW: False},
    )
    y, probs, idx, counts = fn(xf, p)
    return y.reshape(B, S, d), (probs, idx, counts)
