"""Low-overhead span tracer — the timeline half of ``repro.obs``.

The paper's memos is "powered by a kernel-level monitoring module"; this
is its user-space analogue for the repro: monotonic-clock spans recorded
into a **preallocated ring buffer**, thread-aware so the async memos
pipeline's worker-thread plan spans interleave correctly with the main
thread's dispatch spans when exported to Chrome's trace-event format
(``obs/export.py`` -> chrome://tracing / Perfetto).

Design constraints, in order:

  * **disabled is (near) free** — ``Tracer.span()`` on a disabled tracer
    is one attribute load + one branch and returns a shared immutable
    no-op context manager: no event, no allocation, no attribute
    retention.  Instrumentation can therefore live permanently on the
    serving hot path's *host* sections (the jitted dispatch itself is
    opaque to host tracing by construction — its wall time is the
    enclosing span).
  * **enabled is cheap** — recording one span is two ``monotonic_ns``
    calls, one small object, and one ring-slot store under a lock (spans
    are recorded at *exit*, so the buffer sees one entry per span, not
    two).  The ring never grows: when full, the oldest events are
    overwritten and counted in ``n_dropped`` rather than stalling or
    reallocating.
  * **threads attribute themselves** — every event records the OS-level
    ``threading.get_ident()`` of the recording thread; the tracer keeps a
    tid -> thread-name map so exporters can emit proper per-thread
    tracks.

Span nesting needs no explicit parent pointers: within one thread,
context-manager discipline guarantees child spans are fully contained in
their parent's [start, start+dur) interval, which is exactly the nesting
model Chrome trace "X" (complete) events use.
"""
from __future__ import annotations

import threading
import time
from typing import NamedTuple


class SpanEvent(NamedTuple):
    """One completed span (ph="X") or instant marker (ph="i")."""

    name: str
    ph: str            # "X" complete span | "i" instant event
    ts_ns: int         # monotonic start time
    dur_ns: int        # 0 for instants
    tid: int           # OS thread ident of the recording thread
    attrs: dict | None


class _NullSpan:
    """The shared do-nothing span handed out while tracing is disabled:
    enters, exits, and swallows ``set()`` without recording anything."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class _Span:
    """A live span: times itself between ``__enter__`` and ``__exit__``
    and records one event on exit."""

    __slots__ = ("_tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. the dispatch size
        chosen after provisioning)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        self.t0 = time.monotonic_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.monotonic_ns()
        self._tracer._record(self.name, "X", self.t0, t1 - self.t0,
                             self.attrs)
        return False


class Tracer:
    """Preallocated-ring span recorder (see module docstring)."""

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        assert capacity > 0
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._buf: list[SpanEvent | None] = [None] * self.capacity
        self._n = 0                       # total events ever recorded
        self._lock = threading.Lock()
        self._thread_names: dict[int, str] = {}

    # -- recording -------------------------------------------------------------
    def span(self, name: str, **attrs):
        """Context manager timing one span.  Disabled -> the shared no-op
        span (no event, no retained attributes)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs or None)

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration marker (Chrome "i" event)."""
        if not self.enabled:
            return
        self._record(name, "i", time.monotonic_ns(), 0, attrs or None)

    def _record(self, name: str, ph: str, ts_ns: int, dur_ns: int,
                attrs: dict | None) -> None:
        tid = threading.get_ident()
        if tid not in self._thread_names:
            self._thread_names[tid] = threading.current_thread().name
        ev = SpanEvent(name, ph, ts_ns, dur_ns, tid, attrs)
        with self._lock:
            self._buf[self._n % self.capacity] = ev
            self._n += 1

    # -- inspection ------------------------------------------------------------
    @property
    def n_recorded(self) -> int:
        """Total events recorded since the last ``clear()`` (including
        events already overwritten by ring wraparound)."""
        return self._n

    @property
    def n_dropped(self) -> int:
        """Events lost to ring wraparound."""
        return max(self._n - self.capacity, 0)

    @property
    def thread_names(self) -> dict[int, str]:
        return dict(self._thread_names)

    def events(self) -> list[SpanEvent]:
        """Surviving events, oldest first (recording order = span *end*
        order; exporters sort by start time where it matters)."""
        with self._lock:
            n, buf = self._n, list(self._buf)
        if n <= self.capacity:
            return [e for e in buf[:n] if e is not None]
        start = n % self.capacity
        return buf[start:] + buf[:start]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0
            self._thread_names.clear()
