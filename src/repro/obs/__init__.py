"""repro.obs — unified tracing + metrics for the memos pipeline.

The paper's memos is "powered by our newly designed kernel-level
monitoring module"; this package is that module's observability surface
for the repro, in two halves sharing one process-wide home:

  * **spans** (``obs/trace.py``) — monotonic-clock spans in a
    preallocated ring buffer, thread-aware, **disabled by default and a
    true no-op while disabled** (one branch, a shared null context
    manager, zero events, zero retained attributes).  Instrumentation
    covers the serving dispatch boundaries (admit / provision / dispatch
    / retire), the async memos snapshot -> plan -> commit phases (plan
    spans land on the worker thread), batched migration per (src, dst)
    tier group, and Start-Gap adoption.
  * **metrics** (``obs/metrics.py``) — a registry of counters, gauges,
    and log-bucketed histograms that MemosManager, TierStore, and
    PagedServingEngine publish into at pass/dispatch boundaries:
    per-token and per-dispatch latency, plan latency vs. overlap window
    (the overlap-efficiency gauge), pages committed/degraded, per-tier
    occupancy, per-(src, dst) migration bytes, per-wear-tier energy and
    max wear.  Metric publication is boundary-granular and always on —
    its cost is a handful of dict/lock ops per dispatch, invisible next
    to a jitted K-token decode.

Exporters (``obs/export.py``): Chrome trace-event JSON (chrome://tracing
/ Perfetto), JSONL, and Prometheus-style text.

Usage::

    from repro import obs

    obs.configure(trace=True)            # flip the span recorder on
    with obs.span("my.phase", k=16):     # timeline span
        ...
    obs.get_registry().histogram("my.latency_s").observe(dt)
    obs.export.write_chrome_trace("trace.json", obs.get_tracer())

The module-level singletons (`get_tracer()` / `get_registry()`) are the
process's default sinks; tests and sweeps isolate themselves with
``reset()`` (drops all events + metrics) rather than swapping instances,
because instrumented library code looks the singletons up at publish
time.
"""
from __future__ import annotations

from . import export  # noqa: F401  (re-export: obs.export.write_chrome_trace)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import NULL_SPAN, SpanEvent, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "SpanEvent",
    "Tracer", "NULL_SPAN", "configure", "get_registry", "get_tracer",
    "instant", "reset", "span", "tracing_enabled", "export",
]

_tracer = Tracer(enabled=False)
_registry = MetricsRegistry()


def get_tracer() -> Tracer:
    return _tracer


def get_registry() -> MetricsRegistry:
    return _registry


def configure(*, trace: bool | None = None,
              capacity: int | None = None) -> None:
    """Flip tracing on/off and/or resize the span ring.  Resizing drops
    recorded events (the ring is preallocated, never grown in place)."""
    global _tracer
    if capacity is not None and capacity != _tracer.capacity:
        _tracer = Tracer(capacity=capacity, enabled=_tracer.enabled)
    if trace is not None:
        _tracer.enabled = bool(trace)


def tracing_enabled() -> bool:
    return _tracer.enabled


def span(name: str, **attrs):
    """Time a span against the process tracer (no-op context manager
    while tracing is disabled)."""
    t = _tracer
    if not t.enabled:
        return NULL_SPAN
    return t.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    t = _tracer
    if t.enabled:
        t.instant(name, **attrs)


def reset() -> None:
    """Drop all recorded spans and all metrics (keeps the enabled flag
    and ring capacity) — sweep/test isolation."""
    _tracer.clear()
    _registry.reset()
