"""Metrics registry — counters, gauges, and log-bucketed histograms.

The aggregate half of ``repro.obs`` (the span tracer in ``obs/trace.py``
is the timeline half): MemosManager, TierStore, and the serving engine
publish into one :class:`MetricsRegistry` at pass/dispatch boundaries —
per-token latency, dispatch wall time, plan latency vs. the overlap
window, pages committed/degraded, per-tier occupancy and per-(src,dst)
migration bytes, per-wear-tier energy and max wear.

Histograms are **log-bucketed**: geometric bucket edges cover many
decades of latency in ~a hundred int64 counters, so p50/p99 estimation
costs O(buckets) with relative error bounded by the bucket growth factor
(default ``2**0.25`` ~ 19% width, interpolated below that).  All metrics
are lock-protected; publication only happens at boundary granularity
(never inside the jitted dispatch), so the locks are uncontended in
practice.
"""
from __future__ import annotations

import math
import threading


class Counter:
    """Monotonically increasing value (int or float increments)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins value."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Log-bucketed histogram over (0, inf).

    Bucket upper edges are the geometric series ``lo * factor**i`` up to
    ``hi`` plus one overflow bucket; ``observe(v, n)`` is one searchsorted
    + three adds.  ``quantile(q)`` interpolates linearly inside the
    winning bucket and clamps to the observed min/max, so exact-value
    streams (all observations equal) report exact quantiles.
    """

    def __init__(self, name: str, help: str = "", lo: float = 1e-7,
                 hi: float = 1e3, factor: float = 2 ** 0.25):
        assert lo > 0 and hi > lo and factor > 1
        self.name = name
        self.help = help
        n = int(math.ceil(math.log(hi / lo) / math.log(factor))) + 1
        self.edges = [lo * factor ** i for i in range(n)]   # upper bounds
        self.counts = [0] * (n + 1)                         # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def _bucket(self, v: float) -> int:
        # first edge >= v (bisect on a ~100-entry list)
        lo_i, hi_i = 0, len(self.edges)
        while lo_i < hi_i:
            mid = (lo_i + hi_i) // 2
            if self.edges[mid] < v:
                lo_i = mid + 1
            else:
                hi_i = mid
        return lo_i

    def observe(self, v: float, n: int = 1) -> None:
        """Record ``n`` observations of value ``v`` (the fused dispatch
        observes its per-token latency once with n=K)."""
        if n <= 0:
            return
        v = float(v)
        with self._lock:
            self.counts[self._bucket(v)] += n
            self.count += n
            self.sum += v * n
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0 <= q <= 1); 0.0 when empty."""
        if not self.count:
            return 0.0
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self.max
                frac = (rank - cum) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def to_dict(self) -> dict:
        return {
            "type": "histogram", "count": self.count, "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.mean,
            "p50": self.quantile(0.5), "p90": self.quantile(0.9),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.

    ``reset()`` drops every metric — benchmark sweeps call it between
    engine configs so each config's histograms stand alone.  Holders of a
    metric object across a reset keep a detached instance; re-fetching by
    name after a reset returns the fresh one, which is why publishers
    look metrics up at publish time instead of caching them.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(name, Histogram, help=help, **kw)

    def collect(self) -> dict[str, object]:
        with self._lock:
            return dict(self._metrics)

    def to_dict(self) -> dict:
        """{name: metric.to_dict()} snapshot, sorted by name."""
        return {n: m.to_dict() for n, m in sorted(self.collect().items())}

    def flat(self) -> dict:
        """Flattened scalar view: counters/gauges as ``name``, histogram
        summary stats as ``name.count`` / ``name.p50`` / ... — the shape
        benchmark JSONs and ``report.py`` consume."""
        out = {}
        for n, m in sorted(self.collect().items()):
            d = m.to_dict()
            if d["type"] == "histogram":
                for k in ("count", "sum", "mean", "p50", "p90", "p99"):
                    out[f"{n}.{k}"] = d[k]
            else:
                out[n] = d["value"]
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
