"""Exporters for the ``repro.obs`` tracer and metrics registry.

Three formats:

  * **Chrome trace-event JSON** (``chrome_trace`` / ``write_chrome_trace``)
    — load the file in chrome://tracing or https://ui.perfetto.dev to see
    the dispatch/plan/commit pipeline as per-thread tracks: with
    ``overlap_plan`` the ``memos.plan`` spans sit on the ``memos-plan_*``
    worker track directly under the main thread's next ``serve.dispatch``
    span — the overlap the async pipeline exists to create, visible
    instead of inferred.
  * **JSONL** (``to_jsonl`` / ``write_jsonl``) — one event object per
    line, for ad-hoc grepping/pandas.
  * **Prometheus-style text** (``prometheus_text`` / ``write_prometheus``)
    — the metrics registry as ``# TYPE`` blocks; histograms emit
    cumulative ``_bucket{le=...}`` series plus ``_sum`` / ``_count``.
"""
from __future__ import annotations

import json
from pathlib import Path

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer

PID = 0   # single-process: one pid, one track group


def _json_attrs(attrs: dict | None) -> dict:
    if not attrs:
        return {}
    return {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else str(v)) for k, v in attrs.items()}


def chrome_trace(tracer: Tracer) -> dict:
    """The tracer's surviving events as a Chrome trace-event object
    (timestamps microseconds, rebased to the earliest event)."""
    events = tracer.events()
    t0 = min((e.ts_ns for e in events), default=0)
    out = []
    for tid, name in sorted(tracer.thread_names.items()):
        out.append({"ph": "M", "name": "thread_name", "pid": PID,
                    "tid": tid, "args": {"name": name}})
    for e in events:
        ev = {"name": e.name, "ph": e.ph, "ts": (e.ts_ns - t0) / 1e3,
              "pid": PID, "tid": e.tid, "args": _json_attrs(e.attrs)}
        if e.ph == "X":
            ev["dur"] = e.dur_ns / 1e3
        else:              # instant events need a scope
            ev["s"] = "t"
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"dropped_events": tracer.n_dropped}}


def write_chrome_trace(path: str | Path, tracer: Tracer) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(tracer), indent=1))
    return path


def to_jsonl(tracer: Tracer) -> str:
    lines = []
    for e in tracer.events():
        lines.append(json.dumps({
            "name": e.name, "ph": e.ph, "ts_ns": e.ts_ns,
            "dur_ns": e.dur_ns, "tid": e.tid,
            "thread": tracer.thread_names.get(e.tid, ""),
            "args": _json_attrs(e.attrs)}))
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(path: str | Path, tracer: Tracer) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(to_jsonl(tracer))
    return path


def _prom_name(name: str) -> str:
    """Metric name -> Prometheus-legal name (dots and dashes fold to
    underscores, prefixed so the repro's series group together)."""
    safe = "".join(c if (c.isalnum() or c == "_") else "_" for c in name)
    return f"repro_{safe}"


def prometheus_text(registry: MetricsRegistry) -> str:
    lines: list[str] = []
    for name, m in sorted(registry.collect().items()):
        pn = _prom_name(name)
        if m.help:
            lines.append(f"# HELP {pn} {m.help}")
        if isinstance(m, Counter):
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {m.value}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {m.value}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for edge, c in zip(m.edges, m.counts):
                cum += c
                if c:          # sparse: only emit buckets that moved
                    lines.append(f'{pn}_bucket{{le="{edge:g}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pn}_sum {m.sum}")
            lines.append(f"{pn}_count {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str | Path, registry: MetricsRegistry) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(prometheus_text(registry))
    return path
