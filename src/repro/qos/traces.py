"""Open-loop arrival-trace generation + replayable JSONL trace files.

The trace-replay idiom of ``benchmarks/simulator.py`` (seeded generator
-> one record per line -> replay -> JSON summary) scaled from page
traces to request traffic: each trace is a list of request arrivals —
arrival time, tenant, full prompt token ids, output budget — generated
by seeded open-loop processes so the offered load is independent of how
fast the engine serves (queues genuinely build under overload).

Arrival processes (one per tenant stream, merged by time):

  * ``poisson``  — exponential inter-arrival gaps at ``rate_rps``;
  * ``bursty``   — Poisson bursts of ``burst_size`` back-to-back
    arrivals (gap process at ``rate_rps / burst_size`` keeps the mean
    rate at ``rate_rps``), each burst spread over ``burst_spread_s``;
  * ``diurnal``  — sinusoidally modulated rate
    ``rate_rps * (1 + amplitude * sin(2 pi t / period_s))`` via
    thinning against the peak rate.

Prompt and output lengths are per-stream clipped-lognormal mixes.
Everything is drawn from ``np.random.RandomState`` seeded per stream,
and floats are rounded before writing, so the same (spec, seed) always
produces a byte-identical file — pinned by tests/test_qos.py.

Trace JSONL schema (documented for replay in benchmarks/traces/README.md):

  line 1:  {"meta": {"name", "seed", "version", "duration_s",
                     "steps_per_s", "vocab", "tenants": {name: class},
                     "n_requests"}}
  line 2+: {"rid", "t", "tenant", "cls", "prompt": [ids...], "max_new"}

``t`` is the arrival time in seconds; replay maps it to the engine's
deterministic step clock as ``step = floor(t * steps_per_s)``.

CLI (regenerates the canonical committed set):

    PYTHONPATH=src python -m repro.qos.traces --out-dir benchmarks/traces
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .tenants import BATCH, CLASSES, LATENCY_CRITICAL, STANDARD

TRACE_VERSION = 1


@dataclass(frozen=True)
class LengthDist:
    """Clipped-lognormal integer lengths (mixed short/long traffic)."""
    mean: float
    sigma: float = 0.4
    lo: int = 1
    hi: int = 64

    def sample(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        v = rng.lognormal(mean=float(np.log(self.mean)), sigma=self.sigma,
                          size=n)
        return np.clip(np.round(v).astype(np.int64), self.lo, self.hi)


@dataclass(frozen=True)
class ArrivalSpec:
    """One tenant's open-loop arrival stream."""
    tenant: str
    tier_class: str = STANDARD
    process: str = "poisson"              # poisson | bursty | diurnal
    rate_rps: float = 4.0
    burst_size: int = 4                   # bursty only
    burst_spread_s: float = 0.05          # bursty only
    period_s: float = 2.0                 # diurnal only
    amplitude: float = 0.8                # diurnal only
    prompt: LengthDist = field(default_factory=lambda: LengthDist(6, lo=2,
                                                                  hi=16))
    output: LengthDist = field(default_factory=lambda: LengthDist(10, lo=2,
                                                                  hi=24))

    def __post_init__(self):
        if self.tier_class not in CLASSES:
            raise ValueError(f"unknown class {self.tier_class!r}")
        if self.process not in ("poisson", "bursty", "diurnal"):
            raise ValueError(f"unknown process {self.process!r}")


@dataclass
class TraceEvent:
    rid: int
    t: float                              # arrival time, seconds
    tenant: str
    cls: str
    prompt: list[int]
    max_new: int

    def step(self, steps_per_s: float) -> int:
        """Arrival on the engine's deterministic step clock."""
        return int(self.t * steps_per_s)


def _poisson_times(rate: float, duration: float,
                   rng: np.random.RandomState) -> list[float]:
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            return out
        out.append(t)


def _bursty_times(spec: ArrivalSpec, duration: float,
                  rng: np.random.RandomState) -> list[float]:
    burst_rate = spec.rate_rps / max(spec.burst_size, 1)
    out = []
    for t0 in _poisson_times(burst_rate, duration, rng):
        offs = np.sort(rng.uniform(0.0, spec.burst_spread_s,
                                   size=spec.burst_size))
        out.extend(float(t0 + o) for o in offs if t0 + o < duration)
    return out


def _diurnal_times(spec: ArrivalSpec, duration: float,
                   rng: np.random.RandomState) -> list[float]:
    peak = spec.rate_rps * (1.0 + spec.amplitude)
    out = []
    for t in _poisson_times(peak, duration, rng):
        lam = spec.rate_rps * (1.0 + spec.amplitude
                               * np.sin(2.0 * np.pi * t / spec.period_s))
        if rng.uniform() * peak < lam:      # thinning
            out.append(t)
    return out


def generate_trace(name: str, specs: list[ArrivalSpec], duration_s: float,
                   seed: int, *, vocab: int = 256,
                   steps_per_s: float = 24.0
                   ) -> tuple[dict, list[TraceEvent]]:
    """Generate one merged, rid-ordered trace from per-tenant streams.

    Each stream draws from its own ``RandomState(seed + 7919 * index)``
    so adding a stream never perturbs the others' arrivals."""
    events: list[tuple[float, int, int, TraceEvent]] = []
    for idx, spec in enumerate(specs):
        rng = np.random.RandomState(seed + 7919 * idx)
        if spec.process == "poisson":
            times = _poisson_times(spec.rate_rps, duration_s, rng)
        elif spec.process == "bursty":
            times = _bursty_times(spec, duration_s, rng)
        else:
            times = _diurnal_times(spec, duration_s, rng)
        n = len(times)
        plens = spec.prompt.sample(rng, n)
        olens = spec.output.sample(rng, n)
        for j, t in enumerate(times):
            prompt = rng.randint(0, vocab, size=int(plens[j])).tolist()
            ev = TraceEvent(rid=-1, t=round(float(t), 6),
                            tenant=spec.tenant, cls=spec.tier_class,
                            prompt=[int(x) for x in prompt],
                            max_new=int(olens[j]))
            events.append((ev.t, idx, j, ev))
    events.sort(key=lambda e: e[:3])
    ordered = []
    for rid, (_, _, _, ev) in enumerate(events):
        ev.rid = rid
        ordered.append(ev)
    meta = {
        "name": name, "seed": seed, "version": TRACE_VERSION,
        "duration_s": duration_s, "steps_per_s": steps_per_s,
        "vocab": vocab,
        "tenants": {s.tenant: s.tier_class for s in specs},
        "n_requests": len(ordered),
    }
    return meta, ordered


def write_trace(path: Path, meta: dict, events: list[TraceEvent]) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    lines = [json.dumps({"meta": meta}, sort_keys=True)]
    for ev in events:
        lines.append(json.dumps(
            {"rid": ev.rid, "t": ev.t, "tenant": ev.tenant, "cls": ev.cls,
             "prompt": ev.prompt, "max_new": ev.max_new}, sort_keys=True))
    path.write_text("\n".join(lines) + "\n")
    return path


def read_trace(path: Path) -> tuple[dict, list[TraceEvent]]:
    lines = Path(path).read_text().splitlines()
    head = json.loads(lines[0])
    assert "meta" in head, f"{path}: first line must be the meta record"
    meta = head["meta"]
    assert meta.get("version") == TRACE_VERSION, \
        f"{path}: trace version {meta.get('version')} != {TRACE_VERSION}"
    events = []
    for line in lines[1:]:
        if not line.strip():
            continue
        d = json.loads(line)
        events.append(TraceEvent(rid=d["rid"], t=d["t"], tenant=d["tenant"],
                                 cls=d["cls"], prompt=d["prompt"],
                                 max_new=d["max_new"]))
    return meta, events


# -- the canonical committed scenario set -------------------------------------
# Small, seeded, and replayable byte-for-byte: qos_bench replays these
# files directly (truncated under --tiny), so the committed results are
# reproducible from the committed traces alone.

def canonical_specs() -> dict[str, tuple[list[ArrivalSpec], float, int]]:
    """name -> (streams, duration_s, seed)."""
    lc = ArrivalSpec("lc", LATENCY_CRITICAL, process="poisson",
                     rate_rps=3.0,
                     prompt=LengthDist(5, lo=2, hi=10),
                     output=LengthDist(8, lo=4, hi=14))
    std = ArrivalSpec("std", STANDARD, process="poisson", rate_rps=4.0,
                      prompt=LengthDist(6, lo=2, hi=14),
                      output=LengthDist(10, lo=4, hi=18))
    bat = ArrivalSpec("bat", BATCH, process="bursty", rate_rps=6.0,
                      burst_size=4, burst_spread_s=0.04,
                      prompt=LengthDist(8, lo=4, hi=18),
                      output=LengthDist(12, lo=6, hi=20))
    bat_diurnal = ArrivalSpec("bat", BATCH, process="diurnal", rate_rps=5.0,
                              period_s=2.0, amplitude=0.8,
                              prompt=LengthDist(8, lo=4, hi=16),
                              output=LengthDist(12, lo=6, hi=20))
    return {
        # overload: offered load ~2x the engine's service rate, so the
        # priority policy has queues to discriminate between
        "mixed_overload": ([lc, std, bat], 4.0, 7),
        # steady mixed load for the power-cap scenario
        "steady_power": ([std, bat_diurnal], 4.0, 11),
        # shorter mix replayed under a media fault storm
        "storm_mix": ([lc, std,
                       ArrivalSpec("bat", BATCH, process="poisson",
                                   rate_rps=3.0,
                                   prompt=LengthDist(7, lo=4, hi=14),
                                   output=LengthDist(10, lo=6, hi=16))],
                      3.0, 13),
    }


def write_canonical(out_dir: Path) -> list[Path]:
    out = []
    for name, (specs, duration, seed) in canonical_specs().items():
        meta, events = generate_trace(name, specs, duration, seed)
        out.append(write_trace(Path(out_dir) / f"{name}.jsonl", meta,
                               events))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", type=Path,
                    default=Path(__file__).resolve().parents[3]
                    / "benchmarks" / "traces")
    args = ap.parse_args()
    for p in write_canonical(args.out_dir):
        meta, events = read_trace(p)
        print(f"wrote {p} ({meta['n_requests']} requests, "
              f"{meta['duration_s']}s, seed {meta['seed']})")


if __name__ == "__main__":
    main()
