"""Multi-tenant QoS: workload model, scheduling policy knobs, power cap.

The paper's second headline number is **+23.6% QoS** — memos keeps
latency-critical workloads fast while co-running batch workloads share
the hierarchy.  This package makes that a first-class, measurable
dimension of the serving stack:

  * :mod:`repro.qos.tenants` — tenant classes (``latency_critical`` /
    ``standard`` / ``batch``) with per-class SLOs, priorities, and the
    per-page utility weight that flows into memos placement (Li et al.'s
    page-utility model, tenant weight as a multiplier);
  * :mod:`repro.qos.traces` — open-loop arrival-trace generators
    (Poisson / bursty / diurnal, mixed prompt & output length
    distributions) writing replayable JSONL traces under
    ``benchmarks/traces/``;
  * :mod:`repro.qos.power` — the power-cap governor: consumes
    ``NvmReport.dynamic_power_mw`` against a budget and throttles batch
    admission / biases placement toward the low-energy medium while over
    cap.

With no tenants configured (a bare :class:`QoSConfig`, or none at all)
every hook degenerates to the pre-QoS behavior bit for bit — pinned by
``tests/test_qos.py``.
"""
from .power import PowerGovernor
from .tenants import (BATCH, CLASSES, LATENCY_CRITICAL, STANDARD, QoSConfig,
                      SloSpec, TenantSpec, tenant_for_class)

__all__ = [
    "BATCH", "CLASSES", "LATENCY_CRITICAL", "STANDARD",
    "PowerGovernor", "QoSConfig", "SloSpec", "TenantSpec",
    "tenant_for_class",
]
