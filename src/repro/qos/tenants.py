"""Tenant classes, per-class SLOs, and the serving QoS configuration.

Three canonical classes cover the paper's co-location story:

  * ``latency_critical`` — interactive traffic.  Highest admission /
    preemption priority, and a page-utility weight > 1 so its KV pages
    resist demotion to the slow tiers (the per-tenant ranking follows
    the page-utility performance model of Li et al., with the tenant
    weight as a multiplier on per-page utility).
  * ``standard``        — default traffic; neutral in every policy.
  * ``batch``           — throughput traffic.  Lowest priority: first
    preemption victim, first to be deferred when the power governor
    shrinks admission.

SLO targets exist in two clocks: wall-clock milliseconds (reported) and
engine decode *steps* (deterministic — the clock the benchmark gates
use, since a trace replay produces the same step timeline on every
machine).
"""
from __future__ import annotations

from dataclasses import dataclass, field

LATENCY_CRITICAL = "latency_critical"
STANDARD = "standard"
BATCH = "batch"
CLASSES = (LATENCY_CRITICAL, STANDARD, BATCH)


@dataclass(frozen=True)
class SloSpec:
    """Per-class service-level objectives.  ``None`` disables a target."""
    ttft_p99_ms: float | None = None      # wall-clock time to first token
    itl_p99_ms: float | None = None       # wall-clock inter-token latency
    ttft_steps: int | None = None         # step-clock TTFT (deterministic)


# class -> (priority, page-utility weight, SLO).  Priorities are ordinal
# (higher admits first / preempts last); weights multiply per-page
# utility in the memos placement ranking.
CLASS_DEFAULTS: dict[str, tuple[int, float, SloSpec]] = {
    LATENCY_CRITICAL: (2, 4.0, SloSpec(ttft_p99_ms=500.0, itl_p99_ms=100.0,
                                       ttft_steps=24)),
    STANDARD: (1, 1.0, SloSpec(ttft_p99_ms=2000.0, itl_p99_ms=200.0,
                               ttft_steps=64)),
    BATCH: (0, 1.0, SloSpec()),           # best-effort: no targets
}


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: a named stream of requests with a class and overrides."""
    name: str
    tier_class: str = STANDARD
    priority: int = 1
    page_weight: float = 1.0
    slo: SloSpec = field(default_factory=SloSpec)
    # optional absolute completion deadline relative to submit (seconds);
    # carried onto Request.deadline for schedulers/benchmarks to consume
    deadline_s: float | None = None

    def __post_init__(self):
        if self.tier_class not in CLASSES:
            raise ValueError(f"tenant {self.name!r}: unknown class "
                             f"{self.tier_class!r}; pick from {CLASSES}")
        if self.page_weight <= 0:
            raise ValueError(f"tenant {self.name!r}: page_weight must be "
                             f"positive, got {self.page_weight}")


def tenant_for_class(name: str, tier_class: str = STANDARD, *,
                     priority: int | None = None,
                     page_weight: float | None = None) -> TenantSpec:
    """A tenant with its class's default priority / weight / SLO."""
    prio, weight, slo = CLASS_DEFAULTS[tier_class]
    return TenantSpec(name=name, tier_class=tier_class,
                      priority=prio if priority is None else priority,
                      page_weight=weight if page_weight is None else
                      page_weight, slo=slo)


# the spec every un-tenanted request gets: standard class, neutral
# priority 0 and weight 1.0 so an engine with a bare QoSConfig behaves
# bit-identically to one with no QoSConfig at all
DEFAULT_TENANT = TenantSpec(name="default", tier_class=STANDARD,
                            priority=0, page_weight=1.0)


@dataclass(frozen=True)
class QoSConfig:
    """Serving-engine QoS knobs.  The default instance is inert: no
    tenants, no power cap — every scheduler / placement decision is
    bit-identical to an engine with ``qos=None``."""

    tenants: tuple[TenantSpec, ...] = ()
    # priority-aware admission (highest priority first, resumed before
    # new within a priority) and preemption (lowest priority first, then
    # LIFO).  With no tenants every request is priority 0, so both
    # reduce exactly to the legacy order.
    priority_aware: bool = True
    # thread tenant page weights into memos placement (demotion
    # resistance for latency-critical pages)
    placement_weights: bool = True
    # dynamic-power budget (mW) enforced by the memos power governor
    # against the sum of per-wear-tier ``NvmReport.dynamic_power_mw``;
    # None disables the cap
    power_budget_mw: float | None = None
    # healthy (under-budget) passes before one throttle level is released
    power_recover_passes: int = 2

    def spec(self, tenant: str | None) -> TenantSpec:
        """The tenant's spec, or the inert default for unknown/None."""
        if tenant is not None:
            for t in self.tenants:
                if t.name == tenant:
                    return t
        return DEFAULT_TENANT

    @property
    def any_weighted(self) -> bool:
        return any(t.page_weight != 1.0 for t in self.tenants)
