"""Power-cap governor: NVM dynamic power vs. a configurable budget.

Closes the PR 2 follow-up: ``NvmReport.dynamic_power_mw`` finally feeds
a control loop.  The :class:`~repro.core.memos.MemosManager` feeds the
governor the summed per-wear-tier dynamic power at the end of every
pass; while over budget the governor raises an integer **throttle
level**, and

  * the serving engine shrinks batch admission by one slot per level
    (``batch_limit``) — fewer live rows, fewer slow-tier token writes
    per step;
  * the next memos pass plans under *power pressure*: write-dominated
    pages are steered to the fast tier and intermediate-tier fill ranks
    media by Table-1 access **energy** instead of latency, biasing
    placement toward the low-energy medium.

Recovery is hysteretic: ``recover_passes`` consecutive under-budget
passes release one level, so the cap doesn't oscillate on the pass
boundary.  The loop is deterministic — level changes depend only on the
sequence of per-pass power readings.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class PowerGovernor:
    budget_mw: float
    recover_passes: int = 2
    max_throttle: int = 8

    throttle: int = 0             # current shrink level (0 = cap satisfied)
    last_power_mw: float = 0.0    # most recent per-pass reading
    peak_power_mw: float = 0.0
    over_budget_passes: int = 0
    _calm: int = 0

    def observe(self, power_mw: float) -> bool:
        """Feed one pass's total dynamic power; returns whether this
        reading exceeded the budget."""
        self.last_power_mw = float(power_mw)
        self.peak_power_mw = max(self.peak_power_mw, self.last_power_mw)
        if power_mw > self.budget_mw:
            self.throttle = min(self.throttle + 1, self.max_throttle)
            self.over_budget_passes += 1
            self._calm = 0
            return True
        self._calm += 1
        if self.throttle and self._calm >= self.recover_passes:
            self.throttle -= 1
            self._calm = 0
        return False

    @property
    def pressure(self) -> bool:
        """Whether the next memos pass should plan energy-biased."""
        return self.throttle > 0

    def batch_limit(self, max_batch: int) -> int:
        """Admission width under the current throttle (never below 1)."""
        return max(1, max_batch - self.throttle)
