"""Sharding policy: logical-axis rules mapping every parameter / activation
to a PartitionSpec over the production mesh (DESIGN.md Sec. 3.3).

Two attention-parallelism modes, picked per arch:

  * ``megatron`` — heads divide the `model` axis: q/k/v/o sharded on heads
    (KV expanded to Hq so GQA shards uniformly), MLP column/row split,
    activations sequence-sharded between blocks (Megatron-SP) in training.
  * ``context``  — heads do NOT divide the axis (qwen2.5-14b 40H,
    musicgen 24H): attention weights replicated (or FSDP-sharded over
    `data`), activations sequence-sharded over `model`; attention
    all-gathers the (small, GQA) K/V; MLP stays column/row split.

Decode always sequence-shards the KV cache over `model` (distributed
flash-decode: softmax over a sharded axis reduces to tiny cross-shard
max/sum reductions) and batch-shards over the data axes.

ZeRO: optimizer state and grad accumulators are sharded over
(data x model) regardless of the param spec (see optim/).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    seq_shard: bool = True          # Megatron-SP activations between blocks

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def n_data(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def attn_mode(cfg: ArchConfig, mi: MeshInfo) -> str:
    if cfg.layout == "mamba":
        return "none"
    return "megatron" if cfg.n_heads % mi.n_model == 0 else "context"


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple


# --- parameter specs ---------------------------------------------------------

def param_specs(cfg: ArchConfig, mi: MeshInfo, *, fsdp_attn: bool = False):
    """Build the PartitionSpec pytree matching init_params' structure."""
    M = mi.model_axis
    mode = attn_mode(cfg, mi)

    def attn_spec():
        if mode == "megatron":
            # kv heads shard over `model` only when they divide it; smaller
            # GQA kv projections are replicated (KV expands to Hq heads
            # inside sdpa, a collective-free broadcast-slice per shard).
            kv = M if cfg.n_kv_heads % mi.n_model == 0 else None
            s = {
                "wq": P(None, None, M, None), "wk": P(None, None, kv, None),
                "wv": P(None, None, kv, None), "wo": P(None, M, None, None),
            }
            biases = {"bq": P(None, M, None), "bk": P(None, kv, None),
                      "bv": P(None, kv, None)}
            qk = P(None, None)
        else:  # context: replicated (optionally FSDP over data on d_model)
            r = P(None, mi.dp_axes[-1] if fsdp_attn else None, None, None)
            s = {"wq": r, "wk": r, "wv": r,
                 "wo": P(None, None, None,
                         mi.dp_axes[-1] if fsdp_attn else None)}
            b = P(None, None, None)
            biases = {"bq": b, "bk": b, "bv": b}
            qk = P(None, None)
        if cfg.qkv_bias:
            s |= biases
        if cfg.qk_norm:
            s |= {"q_norm": qk, "k_norm": qk}
        return s

    def mlp_spec():
        if cfg.mlp_kind == "gelu":
            return {"w_up": P(None, None, M), "w_down": P(None, M, None)}
        return {"w_gate": P(None, None, M), "w_up": P(None, None, M),
                "w_down": P(None, M, None)}

    def moe_spec():
        if cfg.n_experts >= mi.n_model and cfg.n_experts % mi.n_model == 0:
            return {"w_router": P(None, None, None),
                    "w_gate": P(None, M, None, None),
                    "w_up": P(None, M, None, None),
                    "w_down": P(None, M, None, None)}
        return {"w_router": P(None, None, None),
                "w_gate": P(None, None, None, M),
                "w_up": P(None, None, None, M),
                "w_down": P(None, None, M, None)}

    def mamba_spec():
        # heads (d_inner blocks) shard over model; B/C/dt small -> replicated
        return {
            "in_proj_z": P(None, None, M), "in_proj_x": P(None, None, M),
            "in_proj_B": P(None, None, None), "in_proj_C": P(None, None, None),
            "in_proj_dt": P(None, None, None),
            "conv_w": P(None, None, None), "conv_b": P(None, None),
            "dt_bias": P(None, None), "A_log": P(None, None),
            "D": P(None, None), "norm": P(None, M),
            "out_proj": P(None, M, None),
        }

    norm = P(None, None)  # [L, d]
    layers: dict = {}
    if cfg.layout == "mamba":
        layers = {"ln": norm, "mamba": mamba_spec()}
    elif cfg.layout == "hybrid":
        layers = {"ln": norm, "mamba": mamba_spec()}
    else:
        layers = {"ln1": norm, "ln2": norm, "attn": attn_spec()}
        if cfg.is_moe:
            layers["moe"] = moe_spec()
        else:
            layers["mlp"] = mlp_spec()
        if cfg.gemma_norm:
            layers["ln1_post"] = norm
            layers["ln2_post"] = norm

    specs: dict = {"layers": layers, "final_norm": P(None)}
    if cfg.layout == "hybrid":
        sa = {k: v if not isinstance(v, dict) else v
              for k, v in attn_spec().items()}
        # shared block specs have no leading layer axis: drop first dim
        def drop_lead(p: P) -> P:
            return P(*p[1:])
        specs["shared"] = {
            "ln1": P(None), "ln2": P(None),
            "attn": {k: drop_lead(v) for k, v in attn_spec().items()},
            "mlp": {k: drop_lead(v) for k, v in mlp_spec().items()},
        }
    if cfg.tie_embeddings:
        specs["embed"] = P(M, None)          # vocab-sharded; one-hot lookup
    else:
        specs["embed"] = P(None, M)          # d-sharded; plain take
        specs["lm_head"] = P(None, M)        # padded vocab sharded
    return specs


# --- activation specs ----------------------------------------------------------

def act_spec(cfg: ArchConfig, mi: MeshInfo, *, seq: bool) -> P:
    """[B, S, d] activations between blocks."""
    dp = P(mi.dp_axes)
    if seq and mi.seq_shard and cfg.layout not in ("mamba",):
        return P(mi.dp_axes, mi.model_axis, None)
    return P(mi.dp_axes, None, None)


def kv_cache_spec(mi: MeshInfo) -> P:
    """[B, S, Hkv, Dh] decode cache: batch over data, seq over model
    (distributed flash-decode)."""
    return P(mi.dp_axes, mi.model_axis, None, None)


def constrain(x, mi: MeshInfo | None, spec: P):
    if mi is None:
        return x
    return jax.lax.with_sharding_constraint(x, mi.sharding(spec))
