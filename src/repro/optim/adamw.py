"""AdamW with global-norm clipping, ZeRO-sharded states, and optional
bf16 moment compression (distributed-optimization memory trick).

The optimizer state spec is derived from the param spec by additionally
sharding one unsharded dimension over the data axes (ZeRO-1): states are
elementwise, so any dim works — we pick the first divisible one (usually
the stacked layer dim).  Gradient accumulators reuse the same specs
(ZeRO-2-style).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any      # pytree like params (fp32 or bf16)
    v: Any


def init(params, *, compress_moments: bool = False) -> AdamWState:
    dt = jnp.bfloat16 if compress_moments else jnp.float32
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: float | None = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


# --- ZeRO state specs -----------------------------------------------------------

def zero_spec(shape: tuple[int, ...], pspec: P, dp_axes: tuple[str, ...],
              n_data: int) -> P:
    """Shard one additional (currently unsharded, divisible) dim over data."""
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and dim % n_data == 0 and dim > 0:
            entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(*entries)
    return P(*entries)  # nothing divisible: keep param spec


def zero_specs(param_shapes, param_specs, dp_axes, n_data):
    leaves_s, treedef = jax.tree.flatten(param_shapes)
    leaves_p = treedef.flatten_up_to(param_specs)
    return treedef.unflatten(
        [zero_spec(s.shape, p, dp_axes, n_data)
         for s, p in zip(leaves_s, leaves_p)])
