from . import adamw
from .schedule import cosine_with_warmup

__all__ = ["adamw", "cosine_with_warmup"]
