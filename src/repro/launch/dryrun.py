"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder devices and extract roofline inputs.

MUST set XLA_FLAGS before any jax import (jax locks the device count at
first init) — hence the first two lines.

Usage:
  python -m repro.launch.dryrun --arch olmoe_1b_7b --shape train_4k \
      [--multi-pod] [--out results.json]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, cells, get_arch  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_mesh_info, make_production_mesh  # noqa: E402
from repro.launch.train import init_opt_shardings, make_train_step  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import adamw  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# HLO collective-traffic accounting (ring-algorithm per-chip approximations;
# see DESIGN.md Sec. 7):  kind -> (which shapes, multiplier)
_SHAPE_RE = re.compile(r"(?:bf16|f16|f32|f64|f8\w*|u8|u16|u32|u64|s8|s16|s32|s64|pred)\[[0-9,]*\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "u8": 1, "s8": 1,
                "u16": 2, "s16": 2, "u32": 4, "s32": 4, "u64": 8, "s64": 8,
                "pred": 1}


def _shape_bytes(tok: str) -> int:
    dt, dims = tok.split("[")
    dims = dims.rstrip("]")
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    base = 1
    for k, v in _DTYPE_BYTES.items():
        if dt.startswith(k):
            base = v
            break
    return n * base


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-chip collective traffic from optimized HLO text."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s+(\S+)\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        lhs, rhs = line.split("=", 1)
        # output shapes: tokens before the op name; operand shapes: after '('
        pre, _, post = rhs.partition("(")
        out_bytes = sum(_shape_bytes(t) for t in _SHAPE_RE.findall(pre))
        in_bytes = sum(_shape_bytes(t) for t in
                       _SHAPE_RE.findall(post.split("replica_groups")[0]))
        if kind == "all-reduce":
            traffic = 2 * out_bytes
        elif kind == "all-gather":
            traffic = out_bytes
        elif kind == "reduce-scatter":
            traffic = in_bytes
        elif kind == "all-to-all":
            traffic = in_bytes
        else:  # collective-permute
            traffic = out_bytes
        out[kind] += traffic
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


_OP_RE = re.compile(r"^\s*%?\S+ = (\S+?)\[([0-9,]*)\]\S* ([\w-]+)\(")


def parse_op_bytes(hlo_text: str) -> dict:
    """Output-byte totals for backend-artifact ops.  The CPU backend has no
    native bf16 compute, so it wraps every bf16 dot in convert-to-f32 (+
    layout copies); a TPU MXU consumes bf16 directly.  The roofline
    subtracts these from the memory term (EXPERIMENTS.md §Roofline)."""
    agg = {"convert": 0, "copy": 0, "bitcast": 0, "transpose": 0,
           "all_ops": 0}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        base = 1
        for k, v in _DTYPE_BYTES.items():
            if dt.startswith(k):
                base = v
                break
        b = n * base
        agg["all_ops"] += b
        if op in agg:
            agg[op] += b
    return agg


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               seq_shard: bool = True, save_hlo: bool = False,
               analysis: bool = False, q_chunk: int | None = None,
               kv_int8: bool = False, unstack: bool = False,
               tag: str = "") -> dict:
    cfg = get_arch(arch_id)
    from dataclasses import replace as _replace
    if q_chunk:
        cfg = _replace(cfg, attn_q_chunk=q_chunk)
    if kv_int8:
        cfg = _replace(cfg, kv_cache_quant=True)
    serve_unstacked = unstack and SHAPES[shape_name].kind != "train"
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mi = make_mesh_info(mesh, seq_shard=seq_shard)

    pstructs = S.param_struct(cfg, unstacked=serve_unstacked)
    psh, pspecs = S.param_shardings(cfg, mi, unstacked=serve_unstacked)

    analysis_scale = 1  # multiply analysis flops/collectives by this
    t0 = time.time()
    if shape.kind == "train":
        ostructs = jax.eval_shape(lambda: adamw.init(pstructs))
        osh = init_opt_shardings(cfg, mi)
        if analysis:
            # unrolled, single-microbatch lowering: no while loops, so HLO
            # cost totals are exact; scale by the real microbatch count.
            plan = S.plan_microbatches(cfg, shape, mi)
            analysis_scale = plan.n_micro
            bspecs, bsh = S.train_input_specs(cfg, shape, mi, force_n_micro=1)
            step = make_train_step(cfg, mi, unrolled=True)
        else:
            bspecs, bsh = S.train_input_specs(cfg, shape, mi)
            step = make_train_step(cfg, mi)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
        with jax.set_mesh(mesh):
            lowered = jitted.lower(pstructs, ostructs, bspecs)
    elif shape.kind == "prefill":
        plan = S.plan_microbatches(cfg, shape, mi)
        bspecs, bsh = S.prefill_input_specs(cfg, shape, mi)

        def serve_prefill(params, batch):
            return T.prefill(params, cfg, batch, plan.cache_len, mi,
                             unrolled=analysis or bool(q_chunk))

        jitted = jax.jit(serve_prefill, in_shardings=(psh, bsh))
        with jax.set_mesh(mesh):
            lowered = jitted.lower(pstructs, bspecs)
    else:  # decode / long_decode
        state, sspecs, ssh, tok, tsh = S.decode_input_specs(cfg, shape, mi)

        def serve_step(params, st, batch):
            return T.decode_step(params, cfg, st, batch, mi)

        jitted = jax.jit(serve_step, in_shardings=(psh, ssh, tsh),
                         donate_argnums=(1,))
        with jax.set_mesh(mesh):
            lowered = jitted.lower(pstructs, state, tok)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            mem_d[attr] = int(getattr(mem, attr))
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and (
                  k in ("flops", "bytes accessed", "optimal_seconds")
                  or k.startswith("bytes accessed"))}
    text = compiled.as_text()
    coll = parse_collectives(text)
    op_bytes = parse_op_bytes(text)

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "kind": shape.kind,
        "analysis": analysis, "analysis_scale": analysis_scale,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d, "cost": cost_d, "collectives": coll,
        "op_bytes": op_bytes,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if save_hlo:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        hlo_path = RESULTS_DIR / f"{arch_id}__{shape_name}__{result['mesh']}.hlo"
        hlo_path.write_text(text)
        result["hlo_file"] = str(hlo_path)
    return result


def run_and_save(arch_id: str, shape_name: str, *, multi_pod: bool,
                 seq_shard: bool = True, save_hlo: bool = False,
                 analysis: bool = False, q_chunk: int | None = None,
                 kv_int8: bool = False, unstack: bool = False,
                 tag: str = "") -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = ("__analysis" if analysis else "") + (f"__{tag}" if tag else "")
    out_path = RESULTS_DIR / f"{arch_id}__{shape_name}__{mesh_tag}{suffix}.json"
    try:
        res = lower_cell(arch_id, shape_name, multi_pod=multi_pod,
                         seq_shard=seq_shard, save_hlo=save_hlo,
                         analysis=analysis, q_chunk=q_chunk,
                         kv_int8=kv_int8, unstack=unstack)
        res["status"] = "ok"
    except Exception as e:  # record the failure for triage
        res = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out_path.write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled lowering with exact cost totals")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--unstack", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    todo = cells() if args.all else [(args.arch, args.shape)]
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    suffix = "__analysis" if args.analysis else ""
    for arch_id, shape_name in todo:
        if args.skip_existing:
            p = RESULTS_DIR / f"{arch_id}__{shape_name}__{mesh_tag}{suffix}.json"
            if p.exists() and json.loads(p.read_text()).get("status") == "ok":
                print(f"[   skip] {arch_id} {shape_name} {mesh_tag}")
                continue
        t0 = time.time()
        res = run_and_save(arch_id, shape_name, multi_pod=args.multi_pod,
                           seq_shard=not args.no_seq_shard,
                           save_hlo=args.save_hlo, analysis=args.analysis,
                           q_chunk=args.q_chunk, kv_int8=args.kv_int8,
                           unstack=args.unstack, tag=args.tag)
        status = res.get("status")
        extra = ""
        if status == "ok":
            extra = (f"flops={res['cost'].get('flops', 0):.3g} "
                     f"coll={res['collectives']['total_bytes']:.3g}B "
                     f"compile={res['compile_s']}s")
        else:
            extra = res.get("error", "")[:200]
        print(f"[{time.time()-t0:7.1f}s] {arch_id} {shape_name} "
              f"{res.get('mesh')}: {status} {extra}", flush=True)


if __name__ == "__main__":
    main()
