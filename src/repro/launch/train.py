"""Training-step assembly: gradient accumulation (lax.scan over
microbatches), fp32 ZeRO-sharded grad accumulators, AdamW update, donated
buffers — plus the runnable single-host training driver used by the
examples and integration tests.
"""
from __future__ import annotations

import argparse
import math
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_arch, smoke
from repro.configs.base import ArchConfig
from repro.data import Prefetcher, ShardInfo, SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw, cosine_with_warmup
from repro.parallel import sharding as sh


def make_train_step(cfg: ArchConfig, mi: sh.MeshInfo | None, *,
                    lr_fn=None, clip_norm: float = 1.0,
                    weight_decay: float = 0.1, unrolled: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves have leading [n_micro, micro_batch, ...]; grads accumulate
    in fp32 across the microbatch scan (ZeRO-sharded when mi is given).

    unrolled=True: analysis mode — python-loop layers and (when n_micro==1)
    skip the microbatch scan entirely, so the lowered HLO has no while
    loops and cost_analysis totals are exact (see launch/dryrun.py).
    """
    if lr_fn is None:
        lr_fn = lambda step: 3e-4

    zspecs = None
    if mi is not None:
        pspecs = sh.param_specs(cfg, mi)
        pstructs = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        zspecs = adamw.zero_specs(pstructs, pspecs, mi.dp_axes, mi.n_data)

    def zconstrain(tree):
        if mi is None or zspecs is None:
            return tree
        leaves, treedef = jax.tree.flatten(tree)
        spec_leaves = treedef.flatten_up_to(zspecs)
        return treedef.unflatten([
            jax.lax.with_sharding_constraint(x, NamedSharding(mi.mesh, s))
            for x, s in zip(leaves, spec_leaves)])

    def train_step(params, opt_state, batch):
        n_micro = jax.tree.leaves(batch)[0].shape[0]

        def micro(carry, mb):
            gacc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                T.loss_fn, has_aux=True)(params, cfg, mb, mi, unrolled)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
            g = zconstrain(g)
            return (g, loss_acc + metrics["ce_loss"]), None

        gacc0 = zconstrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        if n_micro == 1 and unrolled:
            mb0 = jax.tree.map(lambda x: x[0], batch)
            (grads, loss_sum), _ = micro((gacc0, jnp.float32(0.0)), mb0)
        else:
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (gacc0, jnp.float32(0.0)), batch)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        lr = lr_fn(opt_state.step)
        new_params, new_opt, om = adamw.update(
            grads, opt_state, params, lr=lr, clip_norm=clip_norm,
            weight_decay=weight_decay)
        if mi is not None:
            pspecs_ = sh.param_specs(cfg, mi)
            leaves, treedef = jax.tree.flatten(new_params)
            spec_leaves = treedef.flatten_up_to(pspecs_)
            new_params = treedef.unflatten([
                jax.lax.with_sharding_constraint(
                    x, NamedSharding(mi.mesh, s))
                for x, s in zip(leaves, spec_leaves)])
        metrics = {"loss": loss_sum / n_micro, "lr": lr, **om}
        return new_params, new_opt, metrics

    return train_step


def init_opt_shardings(cfg: ArchConfig, mi: sh.MeshInfo):
    """NamedShardings for AdamWState (ZeRO-sharded moments)."""
    pstructs = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = sh.param_specs(cfg, mi)
    zspecs = adamw.zero_specs(pstructs, pspecs, mi.dp_axes, mi.n_data)
    mk = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mi.mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return adamw.AdamWState(step=NamedSharding(mi.mesh, P()),
                            m=mk(zspecs), v=mk(zspecs))


# --- single-host driver (examples / integration tests) -------------------------

def train_loop(cfg: ArchConfig, *, steps: int = 100, global_batch: int = 8,
               seq_len: int = 64, n_micro: int = 2, lr: float = 1e-3,
               ckpt_dir: str | None = None, ckpt_every: int = 50,
               seed: int = 0, memos_cfg=None, log_every: int = 10,
               resume: bool = True, crash_at: int | None = None):
    """Runnable training driver with checkpoint/restart and (for MoE archs)
    memos expert tiering.  Returns the loss history."""
    source = SyntheticLM(cfg.vocab, seq_len, global_batch, seed=seed,
                         input_mode=cfg.input_mode, d_model=cfg.d_model)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    lr_fn = partial(cosine_with_warmup, peak_lr=lr, warmup=10, total=steps)
    step_fn = jax.jit(make_train_step(cfg, None, lr_fn=lr_fn))

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        (params, opt), start, _ = ckpt.restore((params, opt))
        start = int(start)

    losses = []
    for step in range(start, steps):
        raw = source.batch(step)
        batch = {k: np.reshape(v, (n_micro, v.shape[0] // n_micro,
                                   *v.shape[1:]))
                 for k, v in raw.items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, (params, opt))
        if crash_at is not None and step + 1 == crash_at:
            if ckpt:
                ckpt.wait()
            raise RuntimeError(f"simulated crash at step {step + 1}")
    if ckpt:
        ckpt.save(steps, (params, opt), block=True)
    return losses, params, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe_1b_7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    losses, _, _ = train_loop(cfg, steps=args.steps,
                              global_batch=args.batch, seq_len=args.seq,
                              ckpt_dir=args.ckpt)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
