"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # pre-0.5 jax has no explicit axis types
    AxisType = None

from repro.parallel.sharding import MeshInfo


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_info(mesh, *, seq_shard: bool = True) -> MeshInfo:
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a != "model")
    return MeshInfo(mesh=mesh, dp_axes=dp_axes, model_axis="model",
                    seq_shard=seq_shard)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small host-device mesh for CPU sharding tests (needs
    --xla_force_host_platform_device_count >= n_data*n_model)."""
    return _mesh((n_data, n_model), ("data", "model"))
