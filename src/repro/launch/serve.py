"""Serving driver: batched requests through the paged tiering engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, smoke
from repro.models import transformer as T
from repro.serving import PagedServingEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=3)
    ap.add_argument("--fast-slots", type=int, default=24)
    ap.add_argument("--tiers", type=int, choices=(2, 3), default=2,
                    help="2 = HBM->NVM; 3 = HBM->DRAM-sim->NVM demo")
    ap.add_argument("--dram-slots", type=int, default=16,
                    help="middle-tier capacity for --tiers 3")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--no-memos", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    if cfg.layout != "attn":
        raise SystemExit(f"{args.arch}: paged serving engine supports "
                         "attention-layout archs (dense/MoE)")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hier = None
    if args.tiers == 3:
        from repro.core.hierarchy import MemoryHierarchy
        hier = MemoryHierarchy.three_tier(args.fast_slots, args.dram_slots,
                                          1024)
    eng = PagedServingEngine(cfg, params, ServeConfig(
        page_size=args.page_size, max_batch=args.max_batch,
        fast_slots=args.fast_slots, slow_slots=1024, hierarchy=hier,
        memos_enabled=not args.no_memos))

    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(0, cfg.vocab,
                                   size=rng.randint(3, 14)).tolist(),
                       max_new=args.max_new)
            for _ in range(args.requests)]
    eng.run(max_steps=5000)

    print(f"served {len(reqs)} requests in {eng.step_count} steps; "
          f"{eng.tokens_out} tokens generated")
    lats = [(r.finish_step or 0) - r.arrival for r in reqs]
    print(f"latency steps: mean {np.mean(lats):.1f} max {max(lats)}")
    st = eng.kv.store
    print(f"tier traffic: ->host {st.traffic[(0, 1)]}B  ->HBM "
          f"{st.traffic[(1, 0)]}B  migrations "
          f"{sum(r.migrations.migrated for r in eng.memos.reports)}")
    if eng.expert_counts is not None:
        c = eng.expert_counts
        print(f"expert hotness: top {np.argsort(-c)[:4].tolist()} "
              f"(counts {np.sort(c)[::-1][:4].tolist()}), "
              f"cold experts: {int((c == 0).sum())}/{len(c)}")


if __name__ == "__main__":
    main()
