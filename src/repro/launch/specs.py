"""ShapeDtypeStruct input stand-ins + shardings for every
(architecture x input shape x mesh) dry-run cell — weak-type-correct,
shardable, zero device allocation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.parallel import sharding as sh

PARAM_DTYPE = jnp.bfloat16
ACT_DTYPE = jnp.bfloat16
CACHE_PAD = 512  # decode caches get seq_len + CACHE_PAD slots (512 keeps
                 # cache_len divisible by every seq-sharding group size)


@dataclass(frozen=True)
class RuntimePlan:
    n_micro: int          # gradient-accumulation microbatches (train)
    micro_batch: int      # global tokens-batch per microbatch
    cache_len: int = 0    # decode cache capacity


def plan_microbatches(cfg: ArchConfig, shape: ShapeConfig,
                      mi: sh.MeshInfo) -> RuntimePlan:
    """Pick grad-accum so the per-device microbatch is 1-2 sequences —
    the activation-memory knob for big models (DESIGN.md Sec. 3.3)."""
    if shape.kind != "train":
        return RuntimePlan(1, shape.global_batch,
                           cache_len=shape.seq_len + CACHE_PAD)
    per_dev = 1 if cfg.d_model * cfg.n_layers >= 3072 * 32 else 2
    micro = max(mi.n_data * per_dev, 1)
    micro = min(micro, shape.global_batch)
    while shape.global_batch % micro:
        micro -= 1
    return RuntimePlan(shape.global_batch // micro, micro)


# --- inputs ------------------------------------------------------------------

def train_input_specs(cfg: ArchConfig, shape: ShapeConfig, mi: sh.MeshInfo,
                      force_n_micro: int | None = None) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, shardings) for the [n_micro, Bm, S] batch."""
    plan = plan_microbatches(cfg, shape, mi)
    nm, bm, S = plan.n_micro, plan.micro_batch, shape.seq_len
    if force_n_micro is not None:
        nm = force_n_micro
    dp = P(None, mi.dp_axes, None)
    specs: dict[str, Any] = {
        "labels": jax.ShapeDtypeStruct((nm, bm, S), jnp.int32)}
    shards: dict[str, Any] = {"labels": NamedSharding(mi.mesh, dp)}
    if cfg.input_mode == "embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((nm, bm, S, cfg.d_model),
                                               ACT_DTYPE)
        shards["embeds"] = NamedSharding(mi.mesh, P(None, mi.dp_axes, None, None))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((nm, bm, S), jnp.int32)
        shards["tokens"] = NamedSharding(mi.mesh, dp)
    return specs, shards


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig, mi: sh.MeshInfo):
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeds":
        specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), ACT_DTYPE)}
        shards = {"embeds": NamedSharding(mi.mesh, P(mi.dp_axes, None, None))}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        shards = {"tokens": NamedSharding(mi.mesh, P(mi.dp_axes, None))}
    return specs, shards


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig, mi: sh.MeshInfo):
    """Decode-state + one-token-batch stand-ins.

    decode_32k: batch over data axes, cache seq over model.
    long_500k (batch=1): cache seq over *all* axes — the whole pod holds
    one sequence's KV (distributed flash-decode)."""
    B, S = shape.global_batch, shape.seq_len
    cache_len = S + CACHE_PAD
    long_ctx = shape.kind == "long_decode"

    state = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, cache_len, dtype=ACT_DTYPE,
                                    start_pos=S))

    batch_axes = () if long_ctx else mi.dp_axes
    seq_axes = (tuple(mi.dp_axes) + (mi.model_axis,)) if long_ctx \
        else (mi.model_axis,)

    def kv_spec(arr):
        # [B, W, Hkv, Dh]: ring buffers (W small) replicate on seq
        W = arr.shape[1]
        seq = seq_axes if W >= 4096 else None
        return P(batch_axes or None, seq, None, None)

    def pos_spec(arr):
        W = arr.shape[1]
        seq = seq_axes if W >= 4096 else None
        return P(batch_axes or None, seq)

    def attn_specs(c):
        d = {"k": kv_spec(c["k"]), "v": kv_spec(c["v"]),
             "pos": pos_spec(c["pos"])}
        if "k_scale" in c:
            d["k_scale"] = pos_spec(c["k_scale"])
            d["v_scale"] = pos_spec(c["v_scale"])
        return d

    state_specs = {
        "positions": P(batch_axes or None),
        "attn": [attn_specs(c) for c in state["attn"]],
        "mamba": [{"h": P(batch_axes or None, mi.model_axis, None, None),
                   "conv": P(batch_axes or None, None, mi.model_axis)}
                  for _ in state["mamba"]],
    }
    state_shards = jax.tree.map(
        lambda s: NamedSharding(mi.mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    if cfg.input_mode == "embeds":
        tok = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), ACT_DTYPE)}
        tok_sh = {"embeds": NamedSharding(mi.mesh,
                                          P(batch_axes or None, None, None))}
    else:
        tok = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        tok_sh = {"tokens": NamedSharding(mi.mesh, P(batch_axes or None, None))}
    return state, state_specs, state_shards, tok, tok_sh


def param_struct(cfg: ArchConfig, dtype=PARAM_DTYPE, unstacked: bool = False):
    """ShapeDtypeStructs of the param tree (no allocation)."""
    fn = (lambda: T.unstack_params(
              T.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype),
              cfg.n_layers)) if unstacked else \
        (lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    return jax.eval_shape(fn)


def param_shardings(cfg: ArchConfig, mi: sh.MeshInfo, unstacked: bool = False):
    specs = sh.param_specs(cfg, mi)
    if unstacked:
        def drop_lead(p):
            return P(*p[1:]) if len(p) > 0 else p
        lay = jax.tree.map(drop_lead, specs["layers"],
                           is_leaf=lambda x: isinstance(x, P))
        specs = {**specs, "layers": [lay] * cfg.n_layers}
    return jax.tree.map(lambda s: NamedSharding(mi.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P)), specs
