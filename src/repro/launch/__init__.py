"""Launcher: mesh construction, dry-run, train/serve drivers.

NOTE: dryrun must be run as a fresh process (`python -m repro.launch.dryrun`)
because it sets XLA_FLAGS before jax initializes.
"""
from . import mesh

__all__ = ["mesh"]
