"""Deterministic, shard-aware synthetic data pipeline.

Key property for fault tolerance: the stream is a *stateless function of
(seed, step, shard)* — resuming from a checkpointed step reproduces the
exact same batches with no pipeline state beyond the integer step, so
checkpoint/restore is bit-exact (tested in test_train_integration.py).

Tokens follow a noisy affine recurrence (t_{i+1} = a*t_i + b + noise mod V)
so a model can actually learn structure — the end-to-end example's loss
decreases — while generation stays O(batch) with numpy Philox counters.

A background prefetch thread overlaps host generation with device steps
(the host-side half of compute/transfer overlap).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ShardInfo:
    shard: int = 0
    n_shards: int = 1


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, shard: ShardInfo = ShardInfo(),
                 noise: float = 0.05, input_mode: str = "tokens",
                 d_model: int = 0):
        assert global_batch % shard.n_shards == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // shard.n_shards
        self.seed = seed
        self.shard = shard
        self.noise = noise
        self.input_mode = input_mode
        self.d_model = d_model

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, self.shard.shard, 0, 0]))

    def batch(self, step: int) -> dict:
        """Batch for ``step`` on this shard: {tokens|embeds, labels}."""
        rng = self._rng(step)
        B, S, V = self.local_batch, self.seq_len, self.vocab
        a = 31 + 2 * (step % 5)          # odd multiplier, varies per step
        t0 = rng.integers(0, V, size=(B, 1))
        seq = [t0]
        for _ in range(S):
            nxt = (a * seq[-1] + 17) % V
            flip = rng.random((B, 1)) < self.noise
            rand = rng.integers(0, V, size=(B, 1))
            seq.append(np.where(flip, rand, nxt))
        arr = np.concatenate(seq, axis=1)         # [B, S+1]
        tokens = arr[:, :-1].astype(np.int32)
        labels = arr[:, 1:].astype(np.int32)
        if self.input_mode == "embeds":
            emb = rng.standard_normal((B, S, self.d_model)).astype(np.float32)
            return {"embeds": emb, "labels": labels}
        return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Background thread generating batches ahead of consumption."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        # drain so the worker unblocks
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
