from .pipeline import Prefetcher, ShardInfo, SyntheticLM

__all__ = ["Prefetcher", "ShardInfo", "SyntheticLM"]
