"""Structured fault and degradation exceptions.

Split by *who recovers*:

* :class:`CapacityError` / :class:`PageCorruptionError` fail one
  request cleanly (``Request.error``) while the engine keeps serving —
  the "fail the sequence, never the server" half of the invariant;
* :class:`TransientMigrationFault` / :class:`InjectedPlanFault` are
  injected beneath retry/watchdog machinery and should normally never
  escape to a caller.
"""
from __future__ import annotations


class FaultError(RuntimeError):
    """Base class for every injected or capacity fault."""


class CapacityError(FaultError):
    """All pools exhausted and preemption cannot free a page.

    Raised per-request (attached to ``Request.error``), not per-engine:
    the blocked sequence fails cleanly, everything else keeps decoding.
    """

    def __init__(self, msg: str, *, rid: int | None = None,
                 occupancy: dict | None = None):
        super().__init__(msg)
        self.rid = rid
        self.occupancy = occupancy or {}


class PageCorruptionError(FaultError):
    """A page's stored bits no longer match its recorded checksum and
    the slot was quarantined — the owning sequence fails cleanly."""

    def __init__(self, msg: str, *, rid: int | None = None,
                 pages: list[int] | None = None):
        super().__init__(msg)
        self.rid = rid
        self.pages = list(pages or [])


class TransientMigrationFault(FaultError):
    """Injected failure of one per-(src,dst) bulk move; retried with
    backoff by the migration engine, surfaced only past the cap."""


class InjectedPlanFault(FaultError):
    """Injected exception inside the async plan worker; absorbed by the
    MemosManager watchdog (sync fallback + ladder demotion)."""
