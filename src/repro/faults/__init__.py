"""Fault injection + graceful degradation (the self-healing layer).

``faults.configure(FaultConfig(...))`` arms the global seeded injector;
with it disarmed (the default, and after ``faults.reset()``) every
injection site is a dead branch and all serving/memos/migration paths
are bit-identical to an injection-free build.  See ``injector.py`` for
the four injection sites, ``integrity.py`` for the checksum/scrub/
quarantine detection layer, ``degradation.py`` for the overlap → sync
→ memos-off ladder, and ``errors.py`` for who recovers from what.
"""
from .degradation import (RUNG_OFF, RUNG_OVERLAP, RUNG_SYNC,
                          DegradationLadder)
from .errors import (CapacityError, FaultError, InjectedPlanFault,
                     PageCorruptionError, TransientMigrationFault)
from .injector import (FaultConfig, FaultInjector, configure, get_injector,
                       note_recovered, reset)
from .integrity import PageIntegrity

__all__ = [
    "FaultConfig", "FaultInjector", "configure", "get_injector", "reset",
    "note_recovered", "PageIntegrity", "DegradationLadder",
    "RUNG_OFF", "RUNG_SYNC", "RUNG_OVERLAP",
    "FaultError", "CapacityError", "PageCorruptionError",
    "InjectedPlanFault", "TransientMigrationFault",
]
