"""Three-rung degradation ladder + circuit breaker.

    rung 2  OVERLAP    async plan on the worker thread (full pipeline)
    rung 1  SYNC       synchronous memos pass (no worker exposure)
    rung 0  MEMOS_OFF  no planning/migration at all — serve-only

Any pass-level failure (watchdog fallback, plan exception, migration
retry exhaustion) demotes one rung and resets the health streak; after
``recovery_passes`` consecutive healthy passes the breaker re-promotes
one rung, so a transient storm degrades boundedly and the pipeline
climbs back to full overlap once the media calms down.  The current
rung is published as the ``faults.ladder_rung`` gauge.
"""
from __future__ import annotations

RUNG_OFF = 0
RUNG_SYNC = 1
RUNG_OVERLAP = 2

_RUNG_NAMES = {RUNG_OFF: "memos-off", RUNG_SYNC: "sync",
               RUNG_OVERLAP: "overlap"}


class DegradationLadder:
    def __init__(self, top: int = RUNG_OVERLAP, recovery_passes: int = 3):
        self.top = top
        self.rung = top
        self.recovery_passes = recovery_passes
        self._healthy = 0
        self.demotions = 0
        self.promotions = 0
        self.failures: list[str] = []      # demotion reasons, in order

    @property
    def rung_name(self) -> str:
        return _RUNG_NAMES[self.rung]

    def record_failure(self, reason: str = "") -> bool:
        """One failed pass: demote a rung (if any left).  Returns True
        when a demotion happened."""
        self._healthy = 0
        self.failures.append(reason)
        if self.rung > RUNG_OFF:
            self.rung -= 1
            self.demotions += 1
            self._publish()
            return True
        return False

    def record_healthy(self) -> bool:
        """One clean pass: after ``recovery_passes`` in a row, re-promote
        a rung.  Returns True when a promotion happened."""
        self._healthy += 1
        if self.rung < self.top and self._healthy >= self.recovery_passes:
            self.rung += 1
            self.promotions += 1
            self._healthy = 0
            self._publish()
            from .injector import note_recovered
            note_recovered("promotion")
            return True
        return False

    def _publish(self) -> None:
        from repro import obs
        obs.get_registry().gauge(
            "faults.ladder_rung",
            "degradation rung: 2=overlap 1=sync 0=memos-off",
        ).set(self.rung)
