"""Per-page checksums for the slow tiers — detection half of recovery.

Checksums (definition + single-bit detection proof in
``repro.kernels.page_checksum``) are keyed by **(tier, logical slot)**:
logical slots are stable under the wear-leveling remap, so a Start-Gap
advance that physically relocates a row never invalidates its checksum
— the data moves with the remap.  Device tier 0 is trusted (HBM is not
the asymmetric media the fault model targets); every host/pinned tier
is covered.

Lifecycle: recorded on every write that lands in a covered tier
(demotion commits, host write paths, in-dispatch pinned KV appends at
the step boundary), dropped when the slot is freed, verified on
promotion pre-flight, on the serving engine's pre-dispatch sweep, and
by the budgeted round-robin :meth:`scrub` at memos-pass boundaries.  A
mismatch means the stored bits changed outside any write path — the
slot is quarantined and the owning sequence fails cleanly.
"""
from __future__ import annotations

import numpy as np


def _pad_pow2(idx: np.ndarray) -> np.ndarray:
    """Pow2-pad an index vector (mirrors tiers._pad_idx_np; re-stated
    here because faults sits below core in the import order)."""
    idx = np.asarray(idx, np.int64).reshape(-1)
    pad = (1 << max(idx.size - 1, 0).bit_length()) - idx.size
    if pad:
        idx = np.concatenate([idx, np.repeat(idx[-1:], pad)])
    return idx


class PageIntegrity:
    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.sums: dict[tuple[int, int], int] = {}   # (tier, slot) -> uint32
        self._scrub_cursor = 0

    def covers(self, store, tier: int) -> bool:
        return not store.is_device_tier(tier)

    # -- checksum computation over the *stored* bits ---------------------------
    def slot_checksums(self, store, tier: int, slots) -> np.ndarray:
        # kernel import is deferred: repro.kernels pulls in repro.core,
        # which imports this module — a top-level import would cycle
        from repro.kernels.page_checksum import checksum_np, page_checksum
        slots = np.asarray(slots, np.int64).reshape(-1)
        phys = store._phys(tier, slots)
        pool = store.pools[tier]
        if isinstance(pool.data, np.ndarray):
            return checksum_np(pool.data[phys])
        # pinned jax pool: one checksum dispatch over the padded row list
        import jax.numpy as jnp
        idx = _pad_pow2(phys)
        out = np.asarray(page_checksum(pool.data, jnp.asarray(idx, jnp.int32)))
        return out[:slots.size]

    # -- lifecycle -------------------------------------------------------------
    def record(self, store, tier: int, slots) -> None:
        if not self.enabled or not self.covers(store, tier):
            return
        slots = np.asarray(slots, np.int64).reshape(-1)
        if slots.size == 0:
            return
        sums = self.slot_checksums(store, tier, slots)
        for s, c in zip(slots, sums):
            self.sums[(tier, int(s))] = int(c)

    def drop(self, tier: int, slots) -> None:
        if not self.enabled:
            return
        for s in np.atleast_1d(np.asarray(slots, np.int64)):
            self.sums.pop((tier, int(s)), None)

    def verify(self, store, tier: int, slots) -> list[int]:
        """Return the subset of ``slots`` whose stored bits no longer
        match their recorded checksum (unrecorded slots pass — there is
        nothing to verify against)."""
        if not self.enabled or not self.covers(store, tier):
            return []
        slots = np.asarray(slots, np.int64).reshape(-1)
        known = np.asarray([(tier, int(s)) in self.sums for s in slots])
        if not known.any():
            return []
        slots = slots[known]
        sums = self.slot_checksums(store, tier, slots)
        return [int(s) for s, c in zip(slots, sums)
                if self.sums[(tier, int(s))] != int(c)]

    def scrub(self, store, budget: int) -> list[tuple[int, int]]:
        """Verify up to ``budget`` recorded slots, round-robin across
        passes; returns the (tier, slot) pairs that failed."""
        if not self.enabled or not self.sums or budget <= 0:
            return []
        keys = sorted(self.sums.keys())
        start = self._scrub_cursor % len(keys)
        batch = [keys[(start + i) % len(keys)]
                 for i in range(min(budget, len(keys)))]
        self._scrub_cursor = (start + len(batch)) % max(len(keys), 1)
        bad: list[tuple[int, int]] = []
        by_tier: dict[int, list[int]] = {}
        for t, s in batch:
            by_tier.setdefault(t, []).append(s)
        for t, slots in by_tier.items():
            bad.extend((t, s) for s in self.verify(store, t, slots))
        return bad
