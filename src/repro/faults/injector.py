"""Deterministic, seeded fault injector — the storm generator.

One module-global :class:`FaultInjector` (configured like ``repro.obs``:
``faults.configure(FaultConfig(...))`` / ``faults.reset()``) feeds four
injection sites:

* **NVM media errors** (:meth:`FaultInjector.tick`, called by the
  serving engine at the end of every step boundary): seeded single-bit
  flips and stuck-at bits scattered into live host/pinned-tier rows,
  with per-slot fault probability scaled by the tier's existing wear
  counters (``wear_bias``) so heavily-worn slots fail first — the
  paper's wear-out failure mode made concrete.  Stuck-at faults persist:
  they re-assert on every tick, so a re-written row goes bad again until
  the slot is quarantined.
* **async-plan faults** (:meth:`maybe_plan_fault`, called inside
  ``MemosManager._plan_job`` on the worker thread): injected exceptions
  and artificial latency; a delay longer than ``plan_timeout_s`` is the
  hang that trips the watchdog.
* **migration faults** (:meth:`maybe_migration_fault`, at the head of
  every per-(src,dst) bulk move): transient move failures beneath the
  retry-with-backoff machinery.
* **allocation pressure** (:meth:`maybe_alloc_fail`, inside
  ``TierStore.allocate``): simulated pool exhaustion driving the
  preemption/backpressure path.

Determinism: each site draws from its **own** seeded stream, so the
worker thread's plan draws never race the main thread's media/migration
draws — a given seed replays the same storm.  When disabled (the
default) no site ever touches an RNG or mutates state, keeping every
path bit-identical to an injection-free build.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from .errors import InjectedPlanFault, TransientMigrationFault

_NO_SLOT = -1      # mirrors tiers.NO_SLOT (not imported: faults sits below core)


@dataclass(frozen=True)
class FaultConfig:
    seed: int = 0
    # media: per-live-slot probability per engine step (before wear bias)
    media_flip_rate: float = 0.0      # transient single-bit flips
    media_stuck_rate: float = 0.0     # persistent stuck-at bits
    wear_bias: float = 4.0            # fault-rate multiplier slope vs. mean wear
    # async plan worker
    plan_exception_rate: float = 0.0  # per plan job
    plan_delay_rate: float = 0.0      # per plan job
    plan_delay_s: float = 0.0         # > plan_timeout_s == a hang
    # migration bulk moves
    migrate_fail_rate: float = 0.0    # per per-(src,dst) move attempt
    # allocator
    alloc_fail_rate: float = 0.0      # per TierStore.allocate call
    enabled: bool = True


class FaultInjector:
    def __init__(self, cfg: FaultConfig | None):
        self.cfg = cfg or FaultConfig(enabled=False)
        self.enabled = cfg is not None and self.cfg.enabled
        s = self.cfg.seed
        # one stream per site: the plan stream is drawn on the worker
        # thread, the rest on the main thread — separate streams keep a
        # seed's storm identical regardless of thread interleaving
        self._rng_media = np.random.RandomState(s)
        self._rng_plan = np.random.RandomState(s + 1)
        self._rng_migrate = np.random.RandomState(s + 2)
        self._rng_alloc = np.random.RandomState(s + 3)
        # persistent stuck-at bits: tier -> list of (phys, byte, bit, val)
        self._stuck: dict[int, list[tuple[int, int, int, int]]] = {}
        self.counts = {"media_flip": 0, "media_stuck": 0, "plan_exception": 0,
                       "plan_delay": 0, "migrate": 0, "alloc": 0}

    # -- shared accounting -----------------------------------------------------
    def _note(self, kind: str, n: int = 1) -> None:
        self.counts[kind] += n
        from repro import obs
        reg = obs.get_registry()
        reg.counter("faults.injected", "total injected faults").inc(n)
        reg.counter(f"faults.injected_{kind}",
                    f"injected {kind} faults").inc(n)

    @property
    def total_injected(self) -> int:
        return sum(self.counts.values())

    # -- site 1: NVM media errors ---------------------------------------------
    def tick(self, store) -> int:
        """Scatter media faults into live host/pinned rows (one engine
        step boundary).  Returns the number of bits actually corrupted."""
        if not self.enabled:
            return 0
        c = self.cfg
        n = 0
        for t in range(store.n_tiers):
            if store.is_device_tier(t):
                continue
            n += self._reassert_stuck(store, t)
            if c.media_flip_rate <= 0 and c.media_stuck_rate <= 0:
                continue
            live = np.nonzero((store.tier == t)
                              & (store.slot != _NO_SLOT))[0]
            if live.size == 0:
                continue
            phys = store._phys(t, store.slot[live].astype(np.int64))
            weight = np.ones(live.size)
            w = store.wear_by_tier.get(t)
            if w is not None and c.wear_bias > 0:
                wear = np.asarray(w.wear_counts(), np.float64)
                weight += c.wear_bias * wear[phys] / (wear.mean() + 1.0)
            row_bytes = self._row_bytes(store.pools[t])
            r = self._rng_media.random_sample(live.size)
            for i in np.nonzero(r < np.minimum(
                    c.media_flip_rate * weight, 1.0))[0]:
                byte = int(self._rng_media.randint(row_bytes))
                bit = int(self._rng_media.randint(8))
                self._xor_bit(store.pools[t], int(phys[i]), byte, bit)
                self._note("media_flip")
                n += 1
            if c.media_stuck_rate > 0:
                r = self._rng_media.random_sample(live.size)
                for i in np.nonzero(r < np.minimum(
                        c.media_stuck_rate * weight, 1.0))[0]:
                    fault = (int(phys[i]),
                             int(self._rng_media.randint(row_bytes)),
                             int(self._rng_media.randint(8)),
                             int(self._rng_media.randint(2)))
                    self._stuck.setdefault(t, []).append(fault)
                    if self._force_bit(store.pools[t], *fault):
                        n += 1
                    self._note("media_stuck")
        return n

    def _reassert_stuck(self, store, tier: int) -> int:
        """Stuck-at bits re-corrupt rewritten rows on every tick."""
        n = 0
        for fault in self._stuck.get(tier, ()):
            if self._force_bit(store.pools[tier], *fault):
                self._note("media_stuck")
                n += 1
        return n

    @staticmethod
    def _row_bytes(pool) -> int:
        return int(np.prod(pool.data.shape[1:])) * pool.data.dtype.itemsize

    @staticmethod
    def _xor_bit(pool, phys: int, byte: int, bit: int) -> None:
        if isinstance(pool.data, np.ndarray):
            flat = pool.data[phys].view(np.uint8).reshape(-1)
            flat[byte] ^= np.uint8(1 << bit)
        else:                      # pinned jax pool: round-trip one row
            row = np.array(pool.data[phys])
            flat = row.view(np.uint8).reshape(-1)
            flat[byte] ^= np.uint8(1 << bit)
            pool.data = pool.data.at[phys].set(row)

    @staticmethod
    def _force_bit(pool, phys: int, byte: int, bit: int, val: int) -> bool:
        """Set one bit to ``val``; returns True if the byte changed."""
        def apply(flat):
            cur = (int(flat[byte]) >> bit) & 1
            if cur == val:
                return False
            flat[byte] ^= np.uint8(1 << bit)
            return True

        if isinstance(pool.data, np.ndarray):
            return apply(pool.data[phys].view(np.uint8).reshape(-1))
        row = np.array(pool.data[phys])
        changed = apply(row.view(np.uint8).reshape(-1))
        if changed:
            pool.data = pool.data.at[phys].set(row)
        return changed

    # -- site 2: async plan worker --------------------------------------------
    def maybe_plan_fault(self) -> None:
        """Called inside the plan job, on the worker thread."""
        if not self.enabled:
            return
        c = self.cfg
        if (c.plan_delay_rate > 0 and c.plan_delay_s > 0
                and self._rng_plan.random_sample() < c.plan_delay_rate):
            self._note("plan_delay")
            time.sleep(c.plan_delay_s)
        if (c.plan_exception_rate > 0
                and self._rng_plan.random_sample() < c.plan_exception_rate):
            self._note("plan_exception")
            raise InjectedPlanFault("injected plan-worker exception")

    # -- site 3: migration bulk moves -----------------------------------------
    def maybe_migration_fault(self, src_tier: int, dst_tier: int,
                              pages: int) -> None:
        if not self.enabled or self.cfg.migrate_fail_rate <= 0:
            return
        if self._rng_migrate.random_sample() < self.cfg.migrate_fail_rate:
            self._note("migrate")
            raise TransientMigrationFault(
                f"injected transient fault moving {pages} pages "
                f"t{src_tier}->t{dst_tier}")

    # -- site 4: allocation pressure ------------------------------------------
    def maybe_alloc_fail(self, tier: int) -> bool:
        if not self.enabled or self.cfg.alloc_fail_rate <= 0:
            return False
        if self._rng_alloc.random_sample() < self.cfg.alloc_fail_rate:
            self._note("alloc")
            return True
        return False


def note_recovered(kind: str, n: int = 1) -> None:
    """Record a successful recovery action (retry landed, sync fallback
    served, slot quarantined, preemption freed a page, rung re-promoted)
    into the obs registry."""
    from repro import obs
    reg = obs.get_registry()
    reg.counter("faults.recovered", "total recovery actions").inc(n)
    reg.counter(f"faults.recovered_{kind}", f"recoveries: {kind}").inc(n)


_injector = FaultInjector(None)


def configure(cfg: FaultConfig | None) -> FaultInjector:
    """Install (or with ``None`` remove) the global fault injector."""
    global _injector
    _injector = FaultInjector(cfg)
    return _injector


def get_injector() -> FaultInjector:
    return _injector


def reset() -> None:
    configure(None)
