from .checkpointer import Checkpointer
from .fault_tolerance import (ElasticMeshPlan, HeartbeatMonitor,
                              StragglerPolicy, plan_elastic_remesh)

__all__ = ["Checkpointer", "ElasticMeshPlan", "HeartbeatMonitor",
           "StragglerPolicy", "plan_elastic_remesh"]
