"""Fault-tolerance runtime: heartbeats, straggler mitigation, elastic
re-meshing (DESIGN.md Sec. 3.3).

At 1000+ nodes the failure model is: (a) hard node loss (process gone),
(b) stragglers (a slow host dragging the synchronous collective), (c)
transient step failures.  The controller-side pieces here are pure logic
(testable on one host) and drive the same mechanisms a real deployment
uses: restore-from-checkpoint onto a smaller mesh, or drop/requeue a
straggler's shard.

ElasticMeshPlan keeps the `model` axis intact (TP requires the full group:
losing one chip in a TP group kills the group) and shrinks the `data`/
`pod` axes to the largest fitting power-of-two — the standard elastic
policy for 2D meshes.  Because checkpoints store shardings by *logical
axis name* (checkpointer.py), restoring onto the shrunk mesh is just
device_put with the same specs on the new mesh; global batch is preserved
by raising gradient-accumulation steps (same optimizer trajectory
modulo batch-element ordering).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    """Tracks per-host step-completion timestamps; flags dead hosts and
    stragglers (step latency > factor x running median)."""
    n_hosts: int
    dead_timeout_s: float = 60.0
    straggler_factor: float = 3.0
    window: int = 16

    def __post_init__(self):
        now = time.monotonic()
        self.last_seen = [now] * self.n_hosts
        self.latencies: list[list[float]] = [[] for _ in range(self.n_hosts)]

    def beat(self, host: int, step_latency_s: float,
             now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        self.last_seen[host] = now
        lat = self.latencies[host]
        lat.append(step_latency_s)
        if len(lat) > self.window:
            lat.pop(0)

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in enumerate(self.last_seen)
                if now - t > self.dead_timeout_s]

    def stragglers(self) -> list[int]:
        meds = sorted(sum(l) / len(l) for l in self.latencies if l)
        if not meds:
            return []
        median = meds[len(meds) // 2]
        out = []
        for h, l in enumerate(self.latencies):
            if l and (sum(l) / len(l)) > self.straggler_factor * median:
                out.append(h)
        return out


@dataclass(frozen=True)
class ElasticMeshPlan:
    """New mesh after losing ``lost_hosts`` hosts."""
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]
    grad_accum_scale: int   # multiply grad-accum steps by this to keep
                            # the global batch constant

    @property
    def chips_before(self) -> int:
        n = 1
        for s in self.old_shape:
            n *= s
        return n

    @property
    def chips_after(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def plan_elastic_remesh(shape: tuple[int, ...], axes: tuple[str, ...],
                        lost_chips: int) -> ElasticMeshPlan:
    """Shrink the leading data-parallel axis (pod-major) to the largest
    power-of-two that fits the surviving chips, preserving the model axis."""
    assert axes[-1] == "model", "model axis must be innermost"
    model = shape[-1]
    data_total = 1
    for s in shape[:-1]:
        data_total *= s
    surviving = data_total * model - lost_chips
    new_data = 1
    while new_data * 2 * model <= surviving:
        new_data *= 2
    if len(shape) == 3:  # (pod, data, model)
        pod = min(shape[0], new_data)
        new_shape = (pod, new_data // pod, model)
    else:
        new_shape = (new_data, model)
    scale = max(1, data_total // new_data)
    return ElasticMeshPlan(shape, new_shape, axes, scale)


@dataclass
class StragglerPolicy:
    """Synchronous-training straggler mitigation: after ``patience``
    consecutive flags, the controller (a) reroutes that host's data shard
    to its DP peers (work requeue), and (b) if flagged again, triggers the
    elastic re-mesh path.  Backup-task dispatch (speculative re-execution
    of the slow shard) is returned as the intermediate action."""
    patience: int = 3
    flags: dict = field(default_factory=dict)

    def observe(self, flagged: list[int]) -> dict[int, str]:
        actions: dict[int, str] = {}
        for h in list(self.flags):
            if h not in flagged:
                del self.flags[h]
        for h in flagged:
            self.flags[h] = self.flags.get(h, 0) + 1
            if self.flags[h] >= 2 * self.patience:
                actions[h] = "remesh"
            elif self.flags[h] >= self.patience:
                actions[h] = "backup_dispatch"
            else:
                actions[h] = "observe"
        return actions
