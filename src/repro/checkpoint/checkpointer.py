"""Async, atomic, sharding-aware checkpointing.

Properties required for 1000+-node runs:
  * **step-atomic**: a checkpoint directory appears only via rename() of a
    fully written temp dir — a crash mid-save never corrupts the latest
    restore point;
  * **async**: device->host transfer happens synchronously (cheap), disk
    writes happen on a background thread so the train loop keeps stepping;
  * **sharding-by-logical-axes**: the manifest stores each leaf's
    PartitionSpec *by axis name*, not device ids, so restore can re-layout
    onto a different mesh shape (elastic rescale after node loss);
  * **pipeline-exact resume**: the data pipeline is stateless-by-step, so
    storing the step integer makes resume bit-exact;
  * **bounded retention**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._save_error: BaseException | None = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             specs=None, block: bool = False) -> None:
        """Snapshot ``tree`` at ``step``.  Device arrays are fetched to host
        synchronously (consistent snapshot); writing runs async."""
        self.wait()
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise RuntimeError("previous async checkpoint failed") from err
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.array(x) for x in leaves]  # copy: snapshot must
        # be immune to later in-place mutation of live numpy buffers
        spec_strs = None
        if specs is not None:
            _, spec_leaves, _ = _flatten_with_names(specs)
            sflat = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: x is None or not isinstance(x, (dict, list)))[0]
            spec_strs = [str(s) for s in sflat]
        manifest = {
            "step": step,
            "names": names,
            "dtypes": [str(x.dtype) for x in host_leaves],
            "shapes": [list(x.shape) for x in host_leaves],
            "specs": spec_strs,
            "extra": extra or {},
        }

        def _write():
            try:
                tmp = self.dir / f".tmp_step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for i, arr in enumerate(host_leaves):
                    np.save(tmp / f"leaf_{i}.npy", arr)
                with open(tmp / "manifest.json", "w") as f:
                    json.dump(manifest, f)
                final = self.dir / f"step_{step}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)          # atomic commit
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._save_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise RuntimeError("async checkpoint failed") from err

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like, *, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for the *current* mesh (elastic re-layout)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step}"
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        names, leaves, treedef = _flatten_with_names(like)
        assert names == manifest["names"], "checkpoint/model structure mismatch"
        arrs = [np.load(path / f"leaf_{i}.npy") for i in range(len(names))]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            arrs = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                    for a, s in zip(arrs, sh_leaves)]
        restored = treedef.unflatten(arrs)
        return restored, manifest["step"], manifest.get("extra", {})
