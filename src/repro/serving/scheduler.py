"""Continuous-batching scheduler with memos-aware preemption.

Requests stream in; the scheduler packs up to ``max_batch`` sequences into
decode slots.  When the HBM page pool can't host a new sequence's pages,
the lowest-priority *running* sequence is preempted: its pages stop being
touched, SysMon sees them go cold/RD, and the memos loop migrates them to
the host tier (lazy path) — freeing HBM without an explicit eviction
policy.  On resume the engine requests an *eager* promotion of the
sequence's pages (paper Sec. 6.3's eager mode is exactly this user-driven
path).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    arrival: int = 0
    # runtime state
    tokens: list[int] = field(default_factory=list)   # processed tokens
    generated: list[int] = field(default_factory=list)
    pages: list[int] = field(default_factory=list)   # logical page ids
    slot: int | None = None
    done: bool = False
    preempted: bool = False
    start_step: int | None = None
    finish_step: int | None = None
    # terminal failure (CapacityError, PageCorruptionError, ...): the
    # request retired without completing; ``generated`` holds whatever
    # was produced before the fault
    error: Exception | None = None

    @property
    def pos(self) -> int:
        return len(self.tokens)

    @property
    def remaining_steps(self) -> int:
        """Decode steps left until this request finishes: the unconsumed
        prompt prefix (steps that don't emit) plus the generation budget.
        The engine sizes its fused dispatch K so no sequence overruns."""
        return max(len(self.prompt) - 1 - self.pos, 0) + \
            (self.max_new - len(self.generated))


class ContinuousBatcher:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}   # slot -> request
        self.preempted: deque[Request] = deque()
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    def admit(self) -> list[Request]:
        """Admit resumed-then-new requests into free slots (FIFO)."""
        admitted = []
        for slot in self.free_slots():
            src = self.preempted if self.preempted else self.waiting
            if not src:
                break
            req = src.popleft()
            req.slot = slot
            req.preempted = False
            self.running[slot] = req
            admitted.append(req)
        return admitted

    def preempt_lowest(self) -> Request | None:
        """Preempt the most recently admitted running sequence (LIFO keeps
        older sequences' latency bounded — max-slowdown QoS metric)."""
        if not self.running:
            return None
        slot = max(self.running, key=lambda s: self.running[s].start_step or 0)
        req = self.running.pop(slot)
        req.slot = None
        req.preempted = True
        self.preempted.append(req)
        return req

    def finish(self, req: Request, step: int) -> None:
        if req.slot is not None:
            self.running.pop(req.slot, None)
        req.slot = None
        req.done = True
        req.finish_step = step
        self.finished.append(req)

    def fail(self, req: Request, step: int, error: Exception) -> None:
        """Terminally fail a request wherever it sits (running slot,
        waiting queue, preempted queue): it retires with ``error`` set
        instead of silently completing or wedging the batch."""
        if req.slot is not None:
            self.running.pop(req.slot, None)
            req.slot = None
        for q in (self.waiting, self.preempted):
            try:
                q.remove(req)
            except ValueError:
                pass
        req.error = error
        req.done = True
        req.finish_step = step
        self.finished.append(req)

    @property
    def active(self) -> list[Request]:
        return list(self.running.values())

    def all_done(self) -> bool:
        return not (self.waiting or self.running or self.preempted)

    def depths(self) -> dict[str, int]:
        """Queue depths for the obs metrics registry."""
        return {"waiting": len(self.waiting), "running": len(self.running),
                "preempted": len(self.preempted),
                "finished": len(self.finished)}
