"""Continuous-batching scheduler with memos-aware, priority-aware preemption.

Requests stream in; the scheduler packs up to ``max_batch`` sequences into
decode slots.  When the HBM page pool can't host a new sequence's pages,
the lowest-priority *running* sequence is preempted: its pages stop being
touched, SysMon sees them go cold/RD, and the memos loop migrates them to
the host tier (lazy path) — freeing HBM without an explicit eviction
policy.  On resume the engine requests an *eager* promotion of the
sequence's pages (paper Sec. 6.3's eager mode is exactly this user-driven
path).

Multi-tenant QoS (``repro.qos``) adds ``tenant`` / ``priority`` /
``deadline`` to :class:`Request` and makes both scheduler decisions
priority-aware:

  * **admission** (``priority_aware=True``): highest priority first;
    within a priority, resumed (preempted) requests before new ones,
    then FIFO.  The legacy order — drain ``preempted`` before
    ``waiting`` unconditionally — let a resumed batch request starve a
    newly-arrived latency-critical one; it remains the default policy
    and is pinned bit-identical by tests/test_scheduler.py.
  * **preemption**: lowest priority first, then LIFO within the
    priority (most recently admitted — keeps older sequences' latency
    bounded, the max-slowdown QoS metric).  With uniform priorities this
    reduces exactly to the legacy pure-LIFO victim.

Requests also carry real wall-clock timestamps (submit / first token /
finish) so TTFT and end-to-end latency are measurable per tenant; the
engine stamps ``submit_ts`` / ``first_token_ts``, the scheduler stamps
``finish_ts`` on finish/fail.  Timestamps never feed a decision, so they
cannot perturb the served tokens.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    arrival: int = 0
    # multi-tenant QoS identity (repro.qos): priority orders admission /
    # preemption, weight multiplies per-page utility in memos placement,
    # deadline is an absolute wall-clock completion target (monotonic
    # seconds) or None
    tenant: str = "default"
    priority: int = 0
    weight: float = 1.0
    deadline: float | None = None
    # runtime state
    tokens: list[int] = field(default_factory=list)   # processed tokens
    generated: list[int] = field(default_factory=list)
    pages: list[int] = field(default_factory=list)   # logical page ids
    slot: int | None = None
    done: bool = False
    preempted: bool = False
    start_step: int | None = None
    finish_step: int | None = None
    first_token_step: int | None = None   # step-clock TTFT (deterministic)
    # wall-clock lifecycle timestamps (time.monotonic seconds)
    submit_ts: float | None = None
    first_token_ts: float | None = None
    finish_ts: float | None = None
    # terminal failure (CapacityError, PageCorruptionError, ...): the
    # request retired without completing; ``generated`` holds whatever
    # was produced before the fault
    error: Exception | None = None

    @property
    def pos(self) -> int:
        return len(self.tokens)

    @property
    def remaining_steps(self) -> int:
        """Decode steps left until this request finishes: the unconsumed
        prompt prefix (steps that don't emit) plus the generation budget.
        The engine sizes its fused dispatch K so no sequence overruns."""
        return max(len(self.prompt) - 1 - self.pos, 0) + \
            (self.max_new - len(self.generated))

    @property
    def ttft_s(self) -> float | None:
        """Wall-clock time to first token, when both stamps exist."""
        if self.submit_ts is None or self.first_token_ts is None:
            return None
        return self.first_token_ts - self.submit_ts

    @property
    def e2e_s(self) -> float | None:
        """Wall-clock submit-to-retire latency."""
        if self.submit_ts is None or self.finish_ts is None:
            return None
        return self.finish_ts - self.submit_ts


class ContinuousBatcher:
    def __init__(self, max_batch: int, *, priority_aware: bool = False):
        self.max_batch = max_batch
        self.priority_aware = priority_aware
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}   # slot -> request
        self.preempted: deque[Request] = deque()
        self.finished: list[Request] = []
        # decision counters for the QoS harness (pure ints — the
        # scheduler stays obs-free; the engine publishes them)
        self.n_admitted = 0
        self.n_preempted = 0

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    def _pop_next(self) -> Request | None:
        """The next request to admit under the active policy.

        Legacy (default): drain ``preempted`` before ``waiting``, FIFO
        each — exactly the pre-QoS order.  Priority-aware: highest
        priority across *both* queues wins; within a priority resumed
        requests go first (their pages are warm and their latency clock
        has been running longest), then FIFO by arrival."""
        if not self.priority_aware:
            src = self.preempted if self.preempted else self.waiting
            return src.popleft() if src else None
        best = None
        best_key = None
        for qrank, q in enumerate((self.preempted, self.waiting)):
            for i, req in enumerate(q):
                key = (-req.priority, qrank, i)
                if best_key is None or key < best_key:
                    best, best_key = (q, i), key
        if best is None:
            return None
        q, i = best
        req = q[i]
        del q[i]
        return req

    def admit(self, limit: int | None = None) -> list[Request]:
        """Admit requests into free slots under the active policy.
        ``limit`` caps the number of *running* sequences (the power
        governor shrinks it below ``max_batch`` while over budget)."""
        admitted = []
        for slot in self.free_slots():
            if limit is not None and len(self.running) >= limit:
                break
            req = self._pop_next()
            if req is None:
                break
            req.slot = slot
            req.preempted = False
            self.running[slot] = req
            admitted.append(req)
        self.n_admitted += len(admitted)
        return admitted

    def preempt_lowest(self, max_priority: int | None = None
                       ) -> Request | None:
        """Preempt the lowest-priority running sequence; LIFO (most
        recently admitted) within the priority, which keeps older
        sequences' latency bounded — the max-slowdown QoS metric.  With
        uniform priorities this is exactly the legacy pure-LIFO victim.

        ``max_priority`` bounds the victim: None preempts regardless
        (capacity must be freed); otherwise only a victim with priority
        <= ``max_priority`` is taken, so admitting a low-priority
        request can never evict a higher-priority running one."""
        if not self.running:
            return None
        lowest = min(r.priority for r in self.running.values())
        if max_priority is not None and lowest > max_priority:
            return None
        slot = max((s for s in self.running
                    if self.running[s].priority == lowest),
                   key=lambda s: self.running[s].start_step or 0)
        req = self.running.pop(slot)
        req.slot = None
        req.preempted = True
        self.preempted.append(req)
        self.n_preempted += 1
        return req

    def finish(self, req: Request, step: int) -> None:
        if req.slot is not None:
            self.running.pop(req.slot, None)
        req.slot = None
        req.done = True
        req.finish_step = step
        req.finish_ts = time.monotonic()
        self.finished.append(req)

    def fail(self, req: Request, step: int, error: Exception) -> None:
        """Terminally fail a request wherever it sits (running slot,
        waiting queue, preempted queue): it retires with ``error`` set
        instead of silently completing or wedging the batch."""
        if req.slot is not None:
            self.running.pop(req.slot, None)
            req.slot = None
        for q in (self.waiting, self.preempted):
            try:
                q.remove(req)
            except ValueError:
                pass
        req.error = error
        req.done = True
        req.finish_step = step
        req.finish_ts = time.monotonic()
        self.finished.append(req)

    @property
    def active(self) -> list[Request]:
        return list(self.running.values())

    def all_done(self) -> bool:
        return not (self.waiting or self.running or self.preempted)

    def depths(self) -> dict[str, int]:
        """Queue depths for the obs metrics registry."""
        return {"waiting": len(self.waiting), "running": len(self.running),
                "preempted": len(self.preempted),
                "finished": len(self.finished)}
