"""Paged serving engine: continuous batching + memos-managed KV tiering.

The steady state is a **fused multi-token decode dispatch**: K inner
decode steps run inside one jitted ``jax.lax.scan`` whose carry is
``(tokens, positions, SysmonState, fast_pool, page-write counters)`` —
greedy sampling (argmax) happens on device so the sampled token feeds the
next inner step, SysMon's read/write scatter-adds
(``kernels/hotness_update.touch_update``) and the fast-tier version/write
counters ride in the same dispatch, and the host sees **one dispatch and
one device->host transfer per K tokens** instead of ~4 round-trips per
token (decode + argmax pull + two SysMon records).

Host-side ``step()`` is the slow path that runs only at dispatch
boundaries: admit/resume requests, pre-reserve tail pages for the next K
positions, detect finished sequences from the returned token block, and
run the memos pass (plan + migrate + wear/energy snapshot) **between**
dispatches — monitoring stays at pass granularity exactly as in the
paper's Fig. 10, off the decode critical path.

The dispatch size adapts: K = min(decode_block, min remaining steps over
the batch), snapped to a power of two so recompilation stays bounded.
Every sequence therefore stays live for the whole dispatch (no dead-lane
masking), finished sequences are retired exactly at a boundary, and the
generated tokens are bit-identical to the retained K=1 reference path
(``ServeConfig(reference=True)`` — host argmax + standalone per-step
SysMon records), pinned by tests/test_serving.py.

Tiering dynamics are unchanged from the unfused engine:

  * running sequences touch all their pages every step  -> hot  -> stay;
  * the tail page is written every step                  -> WD   -> stay;
  * preempted / finished-prefix pages go quiet           -> cold -> host;
  * resumed sequences eagerly promote their pages (paper's eager mode).

Supports every ``layout == "attn"`` arch (dense + MoE); MoE expert
hotness is accumulated inside the scan and drained per dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sysmon as sysmon_mod
from repro.core.hierarchy import MemoryHierarchy
from repro.core.memos import MemosConfig, MemosManager
from repro.kernels.paged_attention import paged_attention
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.kv_cache import SERVE_TIER, PagedKVCache, PagedKVConfig
from repro.serving.scheduler import ContinuousBatcher, Request


@dataclass
class ServeConfig:
    page_size: int = 16
    max_batch: int = 4
    fast_slots: int = 48
    slow_slots: int = 512
    # full tier stack (e.g. MemoryHierarchy.three_tier for the
    # HBM -> DRAM-sim -> NVM-sim scenario); None -> two_tier(fast, slow)
    hierarchy: MemoryHierarchy | None = None
    memos_interval: int = 8
    max_pages_per_seq: int = 64
    memos_enabled: bool = True
    # NVM wear feedback horizon (years); None = telemetry only, no feedback
    lifetime_horizon_years: float | None = None
    # K: inner decode steps per fused dispatch (latency vs. dispatch
    # amortization; the effective K shrinks near sequence ends)
    decode_block: int = 8
    # retained unfused K=1 path — host-side sampling + standalone SysMon
    # records; the parity oracle and the pre-fusion throughput baseline
    reference: bool = False


class PagedServingEngine:
    def __init__(self, cfg: ArchConfig, params: dict, scfg: ServeConfig):
        assert cfg.layout == "attn", "paged engine serves attention archs"
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.kv = PagedKVCache(PagedKVConfig(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, page_size=scfg.page_size,
            fast_slots=scfg.fast_slots, slow_slots=scfg.slow_slots,
            hierarchy=scfg.hierarchy))
        store = self.kv.store
        self.sysmon = sysmon_mod.init(
            self.kv.n_pages, n_banks=store.cfg.n_banks,
            n_slabs=store.cfg.n_slabs)
        self.memos = MemosManager(store, MemosConfig(
            interval=scfg.memos_interval, adaptive_interval=False,
            lifetime_horizon_years=scfg.lifetime_horizon_years))
        self.batcher = ContinuousBatcher(scfg.max_batch)
        self.step_count = 0
        self.expert_counts = (np.zeros(cfg.n_experts, np.int64)
                              if cfg.is_moe else None)
        self.tokens_out = 0
        self.rid = 0
        self.last_logits = None     # final inner step's logits, on device
        self._decode_fn = jax.jit(self._decode_batch, donate_argnums=(5,))
        self._fused_fns: dict[int, object] = {}

    # -- request API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int) -> Request:
        cap = self.scfg.max_pages_per_seq * self.scfg.page_size
        assert len(prompt) + max_new <= cap, \
            f"sequence needs {len(prompt) + max_new} positions but " \
            f"max_pages_per_seq*page_size = {cap}"
        req = Request(self.rid, list(prompt), max_new, arrival=self.step_count)
        req.tokens = []          # processed tokens (prompt-consumed + generated)
        req.generated = []       # type: ignore[attr-defined]
        self.rid += 1
        self.batcher.submit(req)
        return req

    # -- page management ---------------------------------------------------------
    def _ensure_pages(self, req: Request, k: int = 1) -> bool:
        """Provision ``req`` for the next ``k`` decode positions: allocate
        the tail pages covering pos .. pos+k-1 and promote every
        non-resident page — the whole span must be HBM-resident for the
        dispatch's block table."""
        need = (req.pos + k - 1) // self.scfg.page_size + 1
        while len(req.pages) < need:
            pid = self.kv.new_page(SERVE_TIER)
            if pid is None:
                return False
            req.pages.append(pid)
        return self._promote_all([req])

    def _promote_all(self, reqs: list[Request]) -> bool:
        """Promote every non-resident page of ``reqs`` in one batched
        migration (single plan->execute bulk move instead of per-request
        per-page copies)."""
        pids = [p for req in reqs for p in req.pages]
        if not pids:
            return True
        mask = self.kv.resident_mask(pids)
        if not mask.all():
            cold = [p for p, m in zip(pids, mask) if not m]
            self.memos.engine.migrate_locked(cold, SERVE_TIER)
            mask = self.kv.resident_mask(pids)
        return bool(mask.all())

    def _make_room(self) -> bool:
        victim = self.batcher.preempt_lowest()
        if victim is None:
            return False
        # eagerly demote the victim's serving-tier pages: preemption must
        # actually free tier-0 slots, because the lazy memos drain only
        # runs between dispatches and admission can be blocked *now*
        # (livelock otherwise when the pool is smaller than two
        # sequences' demand).  Walk the backing tiers deepest-first so a
        # full deepest tier cascades into any intermediate tier with room.
        store = self.kv.store
        for dst in range(store.n_tiers - 1, 0, -1):
            still = [p for p in victim.pages
                     if int(store.tier[p]) == SERVE_TIER]
            if not still:
                break
            self.memos.engine.migrate_optimistic(still, dst)
        return True

    # -- jitted model compute ------------------------------------------------------
    def _decode_core(self, params, tokens, positions, block_tables,
                     lengths, fast_pool):
        """One decode step for the batch: write the new token's K/V into
        the pool *before* attention (exact self-attention), run the layer
        stack through paged_attention.  tokens [B] i32; positions [B];
        block_tables [B,P] fast-slot ids; lengths [B] (incl. current
        token).  Returns (logits [B,Vp], expert_counts|0, new fast_pool)."""
        cfg = self.cfg
        page = self.scfg.page_size
        B = tokens.shape[0]
        h = T.embed_in(params, cfg, {"tokens": tokens[:, None]}, None)
        cos, sin = L.rope_angles(positions[:, None], cfg.head_dim,
                                 cfg.rope_theta)
        b_idx = jnp.arange(B)
        slot = block_tables[b_idx, positions // page]
        off = positions % page
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            dtype = fast_pool.dtype
            fast_pool = fast_pool.at[slot, l, 0, off].set(
                k[:, 0].astype(dtype))
            fast_pool = fast_pool.at[slot, l, 1, off].set(
                v[:, 0].astype(dtype))
            out = paged_attention(q[:, 0], fast_pool[:, l, 0],
                                  fast_pool[:, l, 1], block_tables, lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                B, cfg.n_heads, cfg.head_dim), p.wo)[:, None, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[:, 0]
        return logits, counts_acc, fast_pool

    def _decode_batch(self, params, tokens, positions, block_tables,
                      lengths, fast_pool):
        """Retained K=1 reference entry point (tokens [B,1]); sampling and
        SysMon charging stay on the host."""
        return self._decode_core(params, tokens[:, 0], positions,
                                 block_tables, lengths, fast_pool)

    def _fused_decode(self, params, tokens, positions, prompt_buf,
                      prompt_len, page_tables, block_tables, sm_state,
                      fast_pool, *, k_steps: int):
        """K inner decode steps in one dispatch: a ``lax.scan`` carrying
        (tokens, positions, SysmonState, fast_pool, page-write counters).
        Greedy sampling, the SysMon read/write scatter-adds, and the
        fast-tier write counters all stay on device; the host gets back
        one [K, B] token block per dispatch.

        tokens/positions [B]; prompt_buf [B, Lp] padded prompt tokens;
        prompt_len [B]; page_tables [B, P] logical page ids (SysMon's
        id space); block_tables [B, P] fast-pool slots; sm_state and
        fast_pool are donated loop state.
        """
        cfg = self.cfg
        page = self.scfg.page_size
        B, P = block_tables.shape
        b_idx = jnp.arange(B)
        col = jnp.arange(P, dtype=jnp.int32)[None, :]
        vp = (params["embed"].shape[0] if cfg.tie_embeddings
              else params["lm_head"].shape[1])
        counts0 = (jnp.zeros((cfg.n_experts,), jnp.int32)
                   if cfg.is_moe else jnp.int32(0))

        def body(carry, _):
            tokens, positions, sm, pool, page_writes, counts_acc, _ = carry
            logits, counts, pool = self._decode_core(
                params, tokens, positions, block_tables, positions + 1, pool)
            # device-side greedy sampling feeds the next inner step
            sampled = jnp.argmax(logits[:, :cfg.vocab],
                                 axis=-1).astype(jnp.int32)
            nxt_pos = positions + 1
            prompt_next = prompt_buf[
                b_idx, jnp.clip(nxt_pos, 0, prompt_buf.shape[1] - 1)]
            nxt_tok = jnp.where(nxt_pos < prompt_len, prompt_next, sampled)
            # SysMon: the exact access stream — one read sampling over the
            # block-table prefix covering the current position, one write
            # sampling on the tail page (same two-sampling cadence as the
            # reference path, so pass counters are bit-comparable)
            tailcol = positions // page
            sm = sysmon_mod.record(
                sm, page_tables.reshape(-1), is_write=False,
                valid=(col <= tailcol[:, None]).reshape(-1))
            tails = page_tables[b_idx, tailcol]
            sm = sysmon_mod.record(sm, tails, is_write=True)
            # fast-tier version/write counters (the dirty bits optimistic
            # migration checks) accumulate on device, applied in bulk at
            # the dispatch boundary
            page_writes = page_writes.at[tails].add(1)
            if cfg.is_moe:
                counts_acc = counts_acc + counts
            return (nxt_tok, nxt_pos, sm, pool, page_writes, counts_acc,
                    logits), sampled

        carry0 = (tokens, positions, sm_state, fast_pool,
                  jnp.zeros((sm_state.n_pages,), jnp.int32), counts0,
                  jnp.zeros((B, vp), jnp.float32))
        (_, _, sm, pool, page_writes, counts, logits), sampled = \
            jax.lax.scan(body, carry0, None, length=k_steps)
        return sampled, logits, sm, pool, page_writes, counts

    def _get_fused(self, k: int):
        fn = self._fused_fns.get(k)
        if fn is None:
            # only the pool is donated: SysmonState fields routinely alias
            # one shared zeros buffer (init/end_pass), which XLA rejects
            # as a double donation — and the state is tiny anyway
            fn = jax.jit(partial(self._fused_decode, k_steps=k),
                         donate_argnums=(8,))       # fast_pool
            self._fused_fns[k] = fn
        return fn

    # -- main loop (dispatch-boundary slow path) -----------------------------------
    def step(self) -> dict:
        # 1) admit / resume; make room by preempting if promotion fails.
        # A request that fails provisioning twice in one step is making no
        # progress (its blocker holds the pool) — stop admitting and let
        # the dispatch/memos machinery below free capacity first.
        failed: set[int] = set()
        while True:
            admitted = self.batcher.admit()
            if not admitted:
                break
            ok = True
            stuck = False
            for req in admitted:
                if req.start_step is None:
                    req.start_step = self.step_count
                if not self._ensure_pages(req):
                    ok = False
                    stuck = stuck or req.rid in failed
                    failed.add(req.rid)
            if stuck or (not ok and not self._make_room()):
                break

        active = list(self.batcher.active)
        stats = {"step": self.step_count, "active": len(active)}
        if not active:
            self.step_count += 1
            return stats

        # 2) size the dispatch: K bounded by every sequence's remaining
        # budget (rows stay live for the whole dispatch — finished
        # sequences retire exactly at the boundary), snapped to a power of
        # two so the set of compiled scan lengths stays small
        if self.scfg.reference:
            k = 1
        else:
            k = max(min(self.scfg.decode_block,
                        min(r.remaining_steps for r in active)), 1)
            k = 1 << (k.bit_length() - 1)

        # 3) provision: pre-reserve tail pages for all K positions; under
        # HBM pressure first shrink the dispatch, then preempt (the K=1
        # reference semantics) — preempting to feed a large dispatch
        # would thrash
        while True:
            ok = True
            for req in active:
                if not req.preempted and not self._ensure_pages(req, k):
                    ok = False
                    break
            if ok:
                break
            if k > 1:
                k //= 2
            elif not self._make_room():
                raise RuntimeError("HBM+host pools exhausted")
        active = [r for r in active if not r.preempted]
        if not active:
            self.step_count += 1
            return stats

        B = len(active)
        P = self.scfg.max_pages_per_seq
        page = self.scfg.page_size
        store = self.kv.store
        positions = np.array([r.pos for r in active], np.int32)
        prompt_lens = np.array([len(r.prompt) for r in active], np.int32)
        tokens = np.array([(r.prompt + r.generated)[r.pos] for r in active],
                          np.int32)
        page_tables, block_tables = self.kv.fill_tables(
            [r.pages for r in active], P)

        if self.scfg.reference:
            # -- retained K=1 reference path (parity oracle / baseline) ----
            logits, ecounts, store.fast_pool = self._decode_fn(
                self.params, jnp.asarray(tokens[:, None]),
                jnp.asarray(positions), jnp.asarray(block_tables),
                jnp.asarray(positions + 1), store.fast_pool)
            # host-side argmax sampling: one transfer per token
            sampled = np.asarray(
                jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                np.int32)[None, :]
            # standalone per-step SysMon records — the host round-trips the
            # fused path folds into its scan
            read_valid = np.arange(P)[None, :] <= (positions // page)[:, None]
            self.sysmon = sysmon_mod.record(
                self.sysmon, jnp.asarray(page_tables.reshape(-1)),
                is_write=False, valid=jnp.asarray(read_valid.reshape(-1)))
            tails = page_tables[np.arange(B), positions // page]
            self.sysmon = sysmon_mod.record(
                self.sysmon, jnp.asarray(tails), is_write=True)
            page_writes = np.zeros(store.cfg.n_pages, np.int64)
            np.add.at(page_writes, tails, 1)
            self.last_logits = logits
        else:
            # -- fused K-step dispatch -------------------------------------
            prompt_buf = np.zeros((B, P * page), np.int32)
            for i, r in enumerate(active):
                prompt_buf[i, :len(r.prompt)] = r.prompt
            fn = self._get_fused(k)
            (sampled_d, logits, self.sysmon, store.fast_pool,
             page_writes_d, ecounts) = fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(prompt_buf), jnp.asarray(prompt_lens),
                jnp.asarray(page_tables), jnp.asarray(block_tables),
                self.sysmon, store.fast_pool)
            sampled = np.asarray(sampled_d)   # one transfer per K tokens
            page_writes = np.asarray(page_writes_d)
            self.last_logits = logits

        if self.expert_counts is not None:
            self.expert_counts += np.asarray(ecounts, np.int64)

        # 4) fast-tier accounting, vectorized: device-counted page writes
        # bump versions in one add; the read count is closed-form
        n_reads = int(((positions[:, None] + np.arange(k)[None, :])
                       // page + 1).sum())
        store.charge_fast_accesses(page_writes, n_reads)

        # 5) advance sequences from the returned token block: tokens
        # sampled at inner step s >= emit_from[i] are new generations
        emit_from = np.maximum(prompt_lens - 1 - positions, 0)
        for i, req in enumerate(active):
            new_gen = [int(t) for t in sampled[emit_from[i]:k, i]]
            req.generated.extend(new_gen)
            self.tokens_out += len(new_gen)
            seq = req.prompt + req.generated
            p0 = int(positions[i])
            req.tokens.extend(seq[p0:p0 + k])
            if len(req.generated) >= req.max_new:
                self.batcher.finish(req, self.step_count + k - 1)
                for pid in req.pages:
                    self.kv.free_page(pid)
                req.pages = []

        # 6) memos loop between dispatches (hot pages stay; cold/preempted
        # pages drain to host) — pass granularity, off the decode hot path
        if self.scfg.memos_enabled:
            self.sysmon, report = self.memos.maybe_step(self.sysmon, steps=k)
            if report is not None:
                stats["memos"] = {
                    "migrated": report.migrations.migrated,
                    "to_fast": report.migrations.to_fast,
                    "to_slow": report.migrations.to_slow,
                    "wear_pressure": report.wear_pressure,
                }
                if report.nvm is not None:
                    stats["nvm"] = {
                        "wear_max": report.nvm.wear_max,
                        "slow_writes": report.nvm.slow_writes,
                        "dynamic_power_mw": report.nvm.dynamic_power_mw,
                        "lifetime_years": report.nvm.lifetime_years_actual,
                    }
                # single bulk promotion for every page the memos pass
                # demoted out from under a still-running sequence
                self._promote_all(list(self.batcher.active))

        self.step_count += k
        stats["decode_block"] = k
        stats["tokens_out"] = self.tokens_out
        stats.update(self.kv.occupancy())
        return stats

    def run(self, max_steps: int = 10_000) -> list[dict]:
        hist = []
        while not self.batcher.all_done() and self.step_count < max_steps:
            hist.append(self.step())
        return hist
