"""Paged serving engine: continuous batching + memos-managed KV tiering.

The steady state is a **fused multi-token decode dispatch**: K inner
decode steps run inside one jitted ``jax.lax.scan`` whose carry is
``(tokens, positions, SysmonState, fast_pool, page-write counters)`` —
greedy sampling (argmax) happens on device so the sampled token feeds the
next inner step, SysMon's read/write scatter-adds
(``kernels/hotness_update.touch_update``) and the fast-tier version/write
counters ride in the same dispatch, and the host sees **one dispatch and
one device->host transfer per K tokens** instead of ~4 round-trips per
token (decode + argmax pull + two SysMon records).

Host-side ``step()`` is the slow path that runs only at dispatch
boundaries: admit/resume requests, pre-reserve tail pages for the next K
positions, detect finished sequences from the returned token block, and
run the memos pass (plan + migrate + wear/energy snapshot) **between**
dispatches — monitoring stays at pass granularity exactly as in the
paper's Fig. 10, off the decode critical path.

The dispatch size adapts: K = min(decode_block, min remaining steps over
the batch), snapped to a power of two so recompilation stays bounded.
Every sequence therefore stays live for the whole dispatch (no dead-lane
masking), finished sequences are retired exactly at a boundary, and the
generated tokens are bit-identical to the retained K=1 reference path
(``ServeConfig(reference=True)`` — host argmax + standalone per-step
SysMon records), pinned by tests/test_serving.py.

Tiering dynamics are unchanged from the unfused engine:

  * running sequences touch all their pages every step  -> hot  -> stay;
  * the tail page is written every step                  -> WD   -> stay;
  * preempted / finished-prefix pages go quiet           -> cold -> host;
  * resumed sequences eagerly promote their pages (paper's eager mode).

Supports every ``layout == "attn"`` arch (dense + MoE); MoE expert
hotness is accumulated inside the scan and drained per dispatch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig
from repro.core import sysmon as sysmon_mod
from repro.core.hierarchy import MemoryHierarchy
from repro.core.memos import MemosConfig, MemosManager
from repro.core.tiers import NO_SLOT
from repro.faults.errors import CapacityError, PageCorruptionError
from repro.faults.injector import get_injector, note_recovered
from repro.kernels.paged_attention import paged_attention, paged_attention_pages
from repro.kernels.wear_update import wear_update
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import transformer as T
from repro.qos import QoSConfig
from repro.serving.kv_cache import SERVE_TIER, PagedKVCache, PagedKVConfig
from repro.serving.prefill import (PackedGroup, PrefillRunner, pack_prompts,
                                   replay_page_counts)
from repro.serving.scheduler import ContinuousBatcher, Request


@dataclass
class ServeConfig:
    page_size: int = 16
    max_batch: int = 4
    fast_slots: int = 48
    slow_slots: int = 512
    # full tier stack (e.g. MemoryHierarchy.three_tier for the
    # HBM -> DRAM-sim -> NVM-sim scenario); None -> two_tier(fast, slow)
    hierarchy: MemoryHierarchy | None = None
    memos_interval: int = 8
    max_pages_per_seq: int = 64
    memos_enabled: bool = True
    # NVM wear feedback horizon (years); None = telemetry only, no feedback
    lifetime_horizon_years: float | None = None
    # K: inner decode steps per fused dispatch (latency vs. dispatch
    # amortization; the effective K shrinks near sequence ends)
    decode_block: int = 8
    # overlap the memos *plan* phase with the next dispatch on a worker
    # thread (snapshot -> plan -> commit; the pass's migrations commit at
    # the following dispatch boundary, degrading to the synchronous pass
    # when pages were dirtied mid-plan)
    overlap_plan: bool = False
    # retained unfused K=1 path — host-side sampling + standalone SysMon
    # records; the parity oracle and the pre-fusion throughput baseline
    reference: bool = False
    # multi-tenant QoS (repro.qos): tenant classes + priorities, page
    # utility weights into memos placement, and the dynamic-power cap.
    # None — or a bare QoSConfig() with no tenants and no budget — keeps
    # every scheduler and placement decision bit-identical to pre-QoS
    # behavior (pinned by tests/test_qos.py).
    qos: QoSConfig | None = None
    # bucketed packed prefill (serving/prefill.py): newly admitted
    # requests ingest their whole prompt in one pow2-bucket dispatch
    # instead of replaying it through the decode scan.  Off by default
    # — the replay path is the bit-parity oracle — and ignored under
    # reference=True (the oracle IS prompt replay).
    prefill: bool = False
    prefill_min_bucket: int = 16
    # largest bucket (pow2-rounded); None -> covers max_pages_per_seq
    prefill_max_bucket: int | None = None
    # pack multiple short prompts into one bucket row (segment-isolated)
    prefill_pack: bool = True
    prefill_max_segments: int = 4


class PagedServingEngine:
    def __init__(self, cfg: ArchConfig, params: dict, scfg: ServeConfig):
        assert cfg.layout == "attn", "paged engine serves attention archs"
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.kv = PagedKVCache(PagedKVConfig(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, page_size=scfg.page_size,
            fast_slots=scfg.fast_slots, slow_slots=scfg.slow_slots,
            hierarchy=scfg.hierarchy))
        store = self.kv.store
        # dual-pool serving: when the deepest tier is a (lossless)
        # pinned-host pool, its pages are served and appended in place by
        # the fused dispatch — no promote-before-attend, and the tier's
        # wear counters ride the scan
        pt = self.kv.pinned_tier
        if pt is not None and store.pools[pt].quantized:
            pt = None     # int8 pools can't absorb token-granular appends
        self.pinned_tier = pt
        # in-dispatch Start-Gap: the fused dual-pool scan advances the
        # pinned tier's gap itself whenever this many pinned writes have
        # accumulated (0 = no leveler / untracked tier -> compiled out)
        lv = (store.leveler_by_tier.get(pt) if pt is not None else None)
        self._gap_interval = (lv.interval if lv is not None
                              and store.wear_by_tier.get(pt) is not None
                              and store.pools[pt].data.shape[0] >= 2
                              else 0)
        self.sysmon = sysmon_mod.init(
            self.kv.n_pages, n_banks=store.cfg.n_banks,
            n_slabs=store.cfg.n_slabs)
        qos = scfg.qos
        self.memos = MemosManager(store, MemosConfig(
            interval=scfg.memos_interval, adaptive_interval=False,
            lifetime_horizon_years=scfg.lifetime_horizon_years,
            async_plan=scfg.overlap_plan,
            power_cap_mw=qos.power_budget_mw if qos is not None else None,
            power_recover_passes=(qos.power_recover_passes
                                  if qos is not None else 2)))
        # priority-aware scheduling engages only when tenants are actually
        # configured: a bare QoSConfig() keeps the literal legacy admission
        # code path, making the bit-identity pin structural
        self.batcher = ContinuousBatcher(
            scfg.max_batch,
            priority_aware=bool(qos is not None and qos.priority_aware
                                and qos.tenants))
        self.step_count = 0
        self.expert_counts = (np.zeros(cfg.n_experts, np.int64)
                              if cfg.is_moe else None)
        self.tokens_out = 0
        self.rid = 0
        self.last_logits = None     # final inner step's logits, on device
        self._decode_fn = jax.jit(self._decode_batch, donate_argnums=(5,))
        self._decode_pinned_fn = jax.jit(self._decode_batch_pinned,
                                         donate_argnums=(6, 7))
        self._fused_fns: dict[int, object] = {}
        self._fused_pinned_fns: dict[int, object] = {}
        self.prefill_runner = (PrefillRunner(self)
                               if scfg.prefill and not scfg.reference
                               else None)
        # prompt tokens ingested by prefill since the last memos tick —
        # the pass's sampling clock advances by them (replay would have
        # spent that many inner decode steps), drained at step 6
        self._prefill_tokens_pending = 0

    # -- request API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int, *,
               tenant: str | None = None) -> Request:
        cap = self.scfg.max_pages_per_seq * self.scfg.page_size
        if len(prompt) + max_new > cap:
            # structured rejection (a bare assert vanishes under -O): the
            # sequence can never fit, so refuse at the door instead of
            # failing mid-serve with a CapacityError nobody can act on
            raise CapacityError(
                f"sequence needs {len(prompt) + max_new} positions but "
                f"max_pages_per_seq*page_size = {cap}")
        if (self.prefill_runner is not None
                and len(prompt) > self.prefill_runner.max_bucket):
            raise CapacityError(
                f"prompt of {len(prompt)} tokens exceeds the largest "
                f"prefill bucket ({self.prefill_runner.max_bucket}); raise "
                f"prefill_max_bucket (or max_pages_per_seq) or split the "
                f"prompt")
        req = Request(self.rid, list(prompt), max_new, arrival=self.step_count)
        req.submit_ts = time.monotonic()
        if tenant is not None:
            req.tenant = tenant
        qos = self.scfg.qos
        if qos is not None:
            spec = qos.spec(tenant)
            req.priority = spec.priority
            if qos.placement_weights:
                req.weight = spec.page_weight
            if spec.deadline_s is not None:
                req.deadline = req.submit_ts + spec.deadline_s
        req.tokens = []          # processed tokens (prompt-consumed + generated)
        req.generated = []       # type: ignore[attr-defined]
        self.rid += 1
        self.batcher.submit(req)
        return req

    # -- page management ---------------------------------------------------------
    def _servable_mask(self, pids):
        """Pages the dispatch can attend to: tier-0 residents, plus the
        pinned deepest tier's residents on the dual-pool path."""
        if self.pinned_tier is not None:
            return self.kv.servable_mask(pids)
        return self.kv.resident_mask(pids)

    def _ensure_pages(self, req: Request, k: int = 1) -> bool:
        """Provision ``req`` for the next ``k`` decode positions: allocate
        the tail pages covering pos .. pos+k-1 and promote every
        non-servable page — the whole span must be addressable by the
        dispatch's block table (HBM, or the pinned-host tier on the
        dual-pool path, where pages are served in place)."""
        need = (req.pos + k - 1) // self.scfg.page_size + 1
        while len(req.pages) < need:
            pid = self.kv.new_page(SERVE_TIER)
            if pid is None:
                return False
            req.pages.append(pid)
            if req.weight != 1.0:
                # tenant utility weight rides onto the page for the memos
                # planner (demotion resistance + ranking multiplier)
                self.memos.set_page_weight([pid], req.weight)
        return self._promote_all([req])

    def _release_pages(self, req: Request) -> None:
        """Free a retired request's pages, first resetting any tenant
        utility weight back to neutral — recycled pages must not inherit
        the previous owner's demotion resistance."""
        if req.weight != 1.0 and req.pages:
            self.memos.set_page_weight(req.pages, 1.0)
        for pid in req.pages:
            self.kv.free_page(pid)
        req.pages = []

    def _promote_all(self, reqs: list[Request]) -> bool:
        """Promote every non-servable page of ``reqs`` in one batched
        migration (single plan->execute bulk move instead of per-request
        per-page copies)."""
        pids = [p for req in reqs for p in req.pages]
        if not pids:
            return True
        mask = self._servable_mask(pids)
        if not mask.all():
            cold = [p for p, m in zip(pids, mask) if not m]
            self.memos.engine.migrate_locked(cold, SERVE_TIER)
            mask = self._servable_mask(pids)
        return bool(mask.all())

    def _make_room(self, max_priority: int | None = None) -> bool:
        victim = self.batcher.preempt_lowest(max_priority)
        if victim is None:
            return False
        obs.get_registry().counter(
            "serving.preemptions",
            "running sequences preempted for capacity").inc()
        # eagerly demote the victim's serving-tier pages: preemption must
        # actually free tier-0 slots, because the lazy memos drain only
        # runs between dispatches and admission can be blocked *now*
        # (livelock otherwise when the pool is smaller than two
        # sequences' demand).  Walk the backing tiers deepest-first so a
        # full deepest tier cascades into any intermediate tier with room.
        store = self.kv.store
        for dst in range(store.n_tiers - 1, 0, -1):
            still = [p for p in victim.pages
                     if int(store.tier[p]) == SERVE_TIER]
            if not still:
                break
            self.memos.engine.migrate_optimistic(still, dst)
        return True

    # -- fault handling (repro.faults) -----------------------------------------
    def _fail_request(self, req: Request, err: Exception) -> None:
        """Terminally fail one request with a structured error: release
        its pages (quarantined pages have no slot left — ``release`` is a
        no-op for them and only the logical id returns) and retire it
        through the scheduler so the batch keeps serving."""
        self._release_pages(req)
        self.batcher.fail(req, self.step_count, err)
        obs.get_registry().counter(
            "serving.failed_requests",
            "requests retired with a structured error").inc()

    def _drain_faults(self) -> None:
        """Fail every sequence owning a page the store quarantined since
        the last drain (scrub, promotion pre-flight, pre-dispatch verify)
        — the page's bits are unrecoverable, so the owner errors cleanly
        instead of ever serving from a corrupt page."""
        store = self.kv.store
        if not store.quarantine_log:
            return
        bad = set(store.quarantine_log)
        store.quarantine_log.clear()
        everyone = (self.batcher.active + list(self.batcher.preempted)
                    + list(self.batcher.waiting))
        for req in everyone:
            hit = sorted(bad.intersection(req.pages))
            if hit:
                self._fail_request(req, PageCorruptionError(
                    f"request {req.rid}: page(s) {hit} lost to media "
                    f"corruption", rid=req.rid, pages=hit))

    def _predispatch_verify(self, active: list[Request]) -> None:
        """Last line of the zero-corrupted-tokens invariant: before the
        block tables are built, re-verify the checksum of every page this
        dispatch would serve out of the pinned-host pool (tier 0 is
        trusted media; host-tier pages verify on promotion pre-flight
        instead).  A mismatch quarantines the slot, and the following
        drain fails the owner before it can attend to the bits."""
        pt = self.pinned_tier
        store = self.kv.store
        if pt is None or not store.integrity.enabled:
            return
        slots = {int(store.slot[p]) for r in active for p in r.pages
                 if int(store.tier[p]) == pt
                 and int(store.slot[p]) != NO_SLOT}
        for s in store.integrity.verify(store, pt, sorted(slots)):
            store.quarantine_slot(pt, s, reason="pre-dispatch")

    # -- jitted model compute ------------------------------------------------------
    def _decode_core(self, params, tokens, positions, block_tables,
                     lengths, fast_pool):
        """One decode step for the batch: write the new token's K/V into
        the pool *before* attention (exact self-attention), run the layer
        stack through paged_attention.  tokens [B] i32; positions [B];
        block_tables [B,P] fast-slot ids; lengths [B] (incl. current
        token).  Returns (logits [B,Vp], expert_counts|0, new fast_pool)."""
        cfg = self.cfg
        page = self.scfg.page_size
        B = tokens.shape[0]
        h = T.embed_in(params, cfg, {"tokens": tokens[:, None]}, None)
        cos, sin = L.rope_angles(positions[:, None], cfg.head_dim,
                                 cfg.rope_theta)
        b_idx = jnp.arange(B)
        slot = block_tables[b_idx, positions // page]
        off = positions % page
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            dtype = fast_pool.dtype
            fast_pool = fast_pool.at[slot, l, 0, off].set(
                k[:, 0].astype(dtype))
            fast_pool = fast_pool.at[slot, l, 1, off].set(
                v[:, 0].astype(dtype))
            out = paged_attention(q[:, 0], fast_pool[:, l, 0],
                                  fast_pool[:, l, 1], block_tables, lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                B, cfg.n_heads, cfg.head_dim), p.wo)[:, None, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[:, 0]
        return logits, counts_acc, fast_pool

    def _decode_batch(self, params, tokens, positions, block_tables,
                      lengths, fast_pool):
        """Retained K=1 reference entry point (tokens [B,1]); sampling and
        SysMon charging stay on the host."""
        return self._decode_core(params, tokens[:, 0], positions,
                                 block_tables, lengths, fast_pool)

    # -- dual-pool (pinned-host deepest tier) decode -----------------------------
    def _decode_core_pinned(self, params, tokens, positions, block_tables,
                            pool_sel, lengths, fast_pool, pinned_pool,
                            remap):
        """One decode step with the KV split across the tier-0 pool and
        the pinned-host pool: pages are attended wherever they live
        (per-page select after a dual gather) and the new token's K/V
        lands in whichever pool holds the tail page — the slow-tier KV
        append joins the dispatch instead of forcing a promotion.

        block_tables [B,P] hold each page's slot *in its own pool* —
        tier-0 pool slot, or the pinned pool's **logical** slot, which is
        translated through ``remap`` (the wear-leveling logical->physical
        permutation, [n_pin] i32) here inside the dispatch: the fused
        path carries the remap in its scan and rotates it as Start-Gap
        advances swap rows mid-dispatch, so translation can't happen on
        the host anymore.  pool_sel [B,P] is 1 for pinned pages.  Rows
        whose tail lives in the other pool write through an out-of-range
        index dropped by the scatter (``mode="drop"``), so a numeric slot
        collision between the two pools can never clobber a real
        write."""
        cfg = self.cfg
        page = self.scfg.page_size
        B = tokens.shape[0]
        h = T.embed_in(params, cfg, {"tokens": tokens[:, None]}, None)
        cos, sin = L.rope_angles(positions[:, None], cfg.head_dim,
                                 cfg.rope_theta)
        b_idx = jnp.arange(B)
        n_fast = fast_pool.shape[0]
        n_pin = pinned_pool.shape[0]
        # pinned entries -> physical rows under the *current* remap (fast
        # entries pass through; the clip keeps the dead gather in range)
        block_tables = jnp.where(
            pool_sel > 0,
            remap[jnp.clip(block_tables, 0, n_pin - 1)], block_tables)
        tailcol = positions // page
        slot = block_tables[b_idx, tailcol]
        sel_tail = pool_sel[b_idx, tailcol] > 0
        off = positions % page
        f_idx = jnp.where(sel_tail, n_fast, slot)   # OOB for pinned tails
        p_idx = jnp.where(sel_tail, slot, n_pin)    # OOB for fast tails
        sel_pages = (pool_sel > 0)[:, :, None, None, None]
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            fd, pd = fast_pool.dtype, pinned_pool.dtype
            fast_pool = fast_pool.at[f_idx, l, 0, off].set(
                k[:, 0].astype(fd), mode="drop")
            fast_pool = fast_pool.at[f_idx, l, 1, off].set(
                v[:, 0].astype(fd), mode="drop")
            pinned_pool = pinned_pool.at[p_idx, l, 0, off].set(
                k[:, 0].astype(pd), mode="drop")
            pinned_pool = pinned_pool.at[p_idx, l, 1, off].set(
                v[:, 0].astype(pd), mode="drop")
            # dual gather + per-page select (out-of-range slots clamp and
            # are discarded by the select)
            k_pages = jnp.where(sel_pages,
                                pinned_pool[block_tables, l, 0].astype(fd),
                                fast_pool[block_tables, l, 0])
            v_pages = jnp.where(sel_pages,
                                pinned_pool[block_tables, l, 1].astype(fd),
                                fast_pool[block_tables, l, 1])
            out = paged_attention_pages(q[:, 0], k_pages, v_pages, lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                B, cfg.n_heads, cfg.head_dim), p.wo)[:, None, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[:, 0]
        return logits, counts_acc, fast_pool, pinned_pool

    def _decode_batch_pinned(self, params, tokens, positions, block_tables,
                             pool_sel, lengths, fast_pool, pinned_pool,
                             remap):
        """Retained K=1 reference entry point for the dual-pool path."""
        return self._decode_core_pinned(params, tokens[:, 0], positions,
                                        block_tables, pool_sel, lengths,
                                        fast_pool, pinned_pool, remap)

    @staticmethod
    def _advance_prompt(positions, prompt_buf, prompt_len, sampled, b_idx):
        """Advance one inner decode step: the next position, and the next
        input token — the buffered prompt token while replay is still
        inside the prompt, the freshly sampled token once past it.
        Shared by every fused scan body (single- and dual-pool)."""
        nxt_pos = positions + 1
        prompt_next = prompt_buf[
            b_idx, jnp.clip(nxt_pos, 0, prompt_buf.shape[1] - 1)]
        nxt_tok = jnp.where(nxt_pos < prompt_len, prompt_next, sampled)
        return nxt_tok, nxt_pos

    def _fused_decode(self, params, tokens, positions, prompt_buf,
                      prompt_len, page_tables, block_tables, sm_state,
                      fast_pool, *, k_steps: int):
        """K inner decode steps in one dispatch: a ``lax.scan`` carrying
        (tokens, positions, SysmonState, fast_pool, page-write counters).
        Greedy sampling, the SysMon read/write scatter-adds, and the
        fast-tier write counters all stay on device; the host gets back
        one [K, B] token block per dispatch.

        tokens/positions [B]; prompt_buf [B, Lp] padded prompt tokens;
        prompt_len [B]; page_tables [B, P] logical page ids (SysMon's
        id space); block_tables [B, P] fast-pool slots; sm_state and
        fast_pool are donated loop state.
        """
        cfg = self.cfg
        page = self.scfg.page_size
        B, P = block_tables.shape
        b_idx = jnp.arange(B)
        col = jnp.arange(P, dtype=jnp.int32)[None, :]
        vp = (params["embed"].shape[0] if cfg.tie_embeddings
              else params["lm_head"].shape[1])
        counts0 = (jnp.zeros((cfg.n_experts,), jnp.int32)
                   if cfg.is_moe else jnp.int32(0))

        def body(carry, _):
            tokens, positions, sm, pool, page_writes, counts_acc, _ = carry
            logits, counts, pool = self._decode_core(
                params, tokens, positions, block_tables, positions + 1, pool)
            # device-side greedy sampling feeds the next inner step
            sampled = jnp.argmax(logits[:, :cfg.vocab],
                                 axis=-1).astype(jnp.int32)
            nxt_tok, nxt_pos = self._advance_prompt(
                positions, prompt_buf, prompt_len, sampled, b_idx)
            # SysMon: the exact access stream — one read sampling over the
            # block-table prefix covering the current position, one write
            # sampling on the tail page (same two-sampling cadence as the
            # reference path, so pass counters are bit-comparable)
            tailcol = positions // page
            sm = sysmon_mod.record(
                sm, page_tables.reshape(-1), is_write=False,
                valid=(col <= tailcol[:, None]).reshape(-1))
            tails = page_tables[b_idx, tailcol]
            sm = sysmon_mod.record(sm, tails, is_write=True)
            # fast-tier version/write counters (the dirty bits optimistic
            # migration checks) accumulate on device, applied in bulk at
            # the dispatch boundary
            page_writes = page_writes.at[tails].add(1)
            if cfg.is_moe:
                counts_acc = counts_acc + counts
            return (nxt_tok, nxt_pos, sm, pool, page_writes, counts_acc,
                    logits), sampled

        carry0 = (tokens, positions, sm_state, fast_pool,
                  jnp.zeros((sm_state.n_pages,), jnp.int32), counts0,
                  jnp.zeros((B, vp), jnp.float32))
        (_, _, sm, pool, page_writes, counts, logits), sampled = \
            jax.lax.scan(body, carry0, None, length=k_steps)
        return sampled, logits, sm, pool, page_writes, counts

    def _get_fused(self, k: int):
        fn = self._fused_fns.get(k)
        if fn is None:
            # only the pool is donated: SysmonState fields routinely alias
            # one shared zeros buffer (init/end_pass), which XLA rejects
            # as a double donation — and the state is tiny anyway
            fn = jax.jit(partial(self._fused_decode, k_steps=k),
                         donate_argnums=(8,))       # fast_pool
            self._fused_fns[k] = fn
        return fn

    def _fused_decode_pinned(self, params, tokens, positions, prompt_buf,
                             prompt_len, page_tables, block_tables, pool_sel,
                             sm_state, fast_pool, pinned_pool, wear, remap,
                             gap, pending, *, k_steps: int,
                             gap_interval: int):
        """The dual-pool fused dispatch: K inner steps with KV appends
        landing in either pool and the pinned tier's wear counters riding
        the scan carry — each inner step's slow-tier tail write
        scatter-adds its physical row through the ``wear_update`` kernel.
        Start-Gap leveling runs *inside the dispatch* but **after the
        scan**: the scan accumulates the pinned write count, then a
        single ``while_loop`` performs every advance the dispatch earned
        — swap physical rows (gap, gap+1) of the pinned pool, swap the
        two entries of the remap, charge both rows' wear — the same
        adjacent-row-swap the host leveler performs.  Keeping the loop
        out of the scan body keeps the hot inner step fully fused (an
        in-step ``while_loop`` cost ~35% on CPU even when it never
        fired), while leveling still never serializes the boundary with
        un-jitted whole-pool row swaps; advance *totals* are unchanged
        by the deferred cadence (each advance drains exactly one
        interval), so gap/rotation/remap/pool state stays bit-identical
        to per-token leveling — only the attribution of in-flight app
        writes to pre- vs post-swap physical rows can differ within one
        dispatch.  The boundary adopts (wear, remap, gap, pending,
        #advances) back into the host trackers.  ``gap_interval`` 0
        disables in-dispatch leveling (untracked or unleveled pinned
        tiers); SysMon, sampling, and the page-write counters are
        unchanged from the single-pool path."""
        cfg = self.cfg
        page = self.scfg.page_size
        B, P = block_tables.shape
        b_idx = jnp.arange(B)
        col = jnp.arange(P, dtype=jnp.int32)[None, :]
        n_pin = pinned_pool.shape[0]
        vp = (params["embed"].shape[0] if cfg.tie_embeddings
              else params["lm_head"].shape[1])
        counts0 = (jnp.zeros((cfg.n_experts,), jnp.int32)
                   if cfg.is_moe else jnp.int32(0))

        def advance_gap(state):
            """One Start-Gap move, mirroring StartGapLeveler.advance."""
            ppool, wear, remap, gap, pending, n_adv = state
            nxt = gap + 1
            pair = jnp.stack([gap, nxt])
            ppool = ppool.at[pair].set(ppool[jnp.stack([nxt, gap])])
            remap = jnp.where(remap == gap, nxt,
                              jnp.where(remap == nxt, gap, remap))
            # the swap physically rewrites both rows (leveling overhead)
            wear = wear.at[gap].add(1).at[nxt].add(1)
            gap = jnp.where(nxt >= n_pin - 1, 0, nxt)
            return ppool, wear, remap, gap, pending - gap_interval, n_adv + 1

        def body(carry, _):
            (tokens, positions, sm, fpool, ppool, wear, pin_w,
             page_writes, counts_acc, _) = carry
            logits, counts, fpool, ppool = self._decode_core_pinned(
                params, tokens, positions, block_tables, pool_sel,
                positions + 1, fpool, ppool, remap)
            sampled = jnp.argmax(logits[:, :cfg.vocab],
                                 axis=-1).astype(jnp.int32)
            nxt_tok, nxt_pos = self._advance_prompt(
                positions, prompt_buf, prompt_len, sampled, b_idx)
            tailcol = positions // page
            sm = sysmon_mod.record(
                sm, page_tables.reshape(-1), is_write=False,
                valid=(col <= tailcol[:, None]).reshape(-1))
            tails = page_tables[b_idx, tailcol]
            sm = sysmon_mod.record(sm, tails, is_write=True)
            page_writes = page_writes.at[tails].add(1)
            # pinned-tier wear: tails living in the pinned pool charge
            # their physical row — under the carried remap — on device
            # (amount 0 for fast tails)
            tail_slot = block_tables[b_idx, tailcol]
            tail_pin = pool_sel[b_idx, tailcol]
            tail_phys = remap[jnp.clip(tail_slot, 0, n_pin - 1)]
            wear = wear_update(wear, tail_phys, amount=tail_pin)
            pin_w = pin_w + tail_pin.sum()
            if cfg.is_moe:
                counts_acc = counts_acc + counts
            return (nxt_tok, nxt_pos, sm, fpool, ppool, wear, pin_w,
                    page_writes, counts_acc, logits), sampled

        carry0 = (tokens, positions, sm_state, fast_pool, pinned_pool, wear,
                  jnp.int32(0), jnp.zeros((sm_state.n_pages,), jnp.int32),
                  counts0, jnp.zeros((B, vp), jnp.float32))
        (_, _, sm, fpool, ppool, wear, pin_w, page_writes, counts,
         logits), sampled = \
            jax.lax.scan(body, carry0, None, length=k_steps)
        n_adv = jnp.int32(0)
        if gap_interval:    # static: compiled out when leveling is off
            pending = pending + pin_w
            ppool, wear, remap, gap, pending, n_adv = jax.lax.while_loop(
                lambda s: s[4] >= gap_interval, advance_gap,
                (ppool, wear, remap, gap, pending, n_adv))
        return (sampled, logits, sm, fpool, ppool, wear, remap, gap,
                pending, n_adv, page_writes, counts)

    def _get_fused_pinned(self, k: int):
        fn = self._fused_pinned_fns.get(k)
        if fn is None:
            fn = jax.jit(partial(self._fused_decode_pinned, k_steps=k,
                                 gap_interval=self._gap_interval),
                         donate_argnums=(9, 10))   # fast_pool, pinned_pool
            self._fused_pinned_fns[k] = fn
        return fn

    def _page_read_counts(self, positions: np.ndarray,
                          page_tables: np.ndarray, k: int) -> np.ndarray:
        """Per-logical-page read counts for one K-step dispatch: page j of
        a row is read by every inner step whose block-table prefix covers
        it (closed form, no device work)."""
        page = self.scfg.page_size
        P = page_tables.shape[1]
        n_prefix = (positions[:, None] + np.arange(k)[None, :]) // page + 1
        cnt = (n_prefix[:, None, :] > np.arange(P)[None, :, None]).sum(2)
        reads = np.zeros(self.kv.n_pages, np.int64)
        np.add.at(reads, page_tables.reshape(-1), cnt.reshape(-1))
        return reads

    def warmup(self, batch: int | None = None) -> None:
        """Pre-compile every fused dispatch variant this engine can emit
        — each power-of-two K up to ``decode_block``, on the single-pool
        path and (when a pinned tier exists) the dual-pool path — against
        dummy inputs of the given batch width.  A production server does
        this at boot: the dispatch variant actually used at a boundary
        depends on runtime state (tail shrinkage, pinned residency), and
        a mid-stream compile would stall serving for seconds."""
        B = batch or self.scfg.max_batch
        P = self.scfg.max_pages_per_seq
        page = self.scfg.page_size
        store = self.kv.store
        sm = sysmon_mod.init(self.kv.n_pages, n_banks=store.cfg.n_banks,
                             n_slabs=store.cfg.n_slabs)
        zi = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
        ks = []
        k = 1
        while k <= self.scfg.decode_block:
            ks.append(k)
            k *= 2
        for k in ks:
            args = (self.params, zi(B), zi(B), zi(B, P * page), zi(B),
                    zi(B, P), zi(B, P))
            # pools are donated by the dispatch: hand each call its own
            # dummy copy, never the live buffers
            jax.block_until_ready(
                self._get_fused(k)(*args, sm,
                                   jnp.zeros_like(store.fast_pool))[0])
            if self.pinned_tier is not None:
                ppool = store.pools[self.pinned_tier]
                # match the live dispatch's wear-array shape exactly: the
                # real tracker's counters, or the shape-(1,) dummy used
                # when the pinned tier is untracked
                wtr = store.wear_by_tier.get(self.pinned_tier)
                wear = zi(ppool.data.shape[0] if wtr is not None else 1)
                remap = jnp.arange(ppool.data.shape[0], dtype=jnp.int32)
                jax.block_until_ready(
                    self._get_fused_pinned(k)(
                        *args, zi(B, P), sm,
                        jnp.zeros_like(store.fast_pool),
                        jnp.zeros_like(ppool.data), wear, remap,
                        jnp.int32(0), jnp.int32(0))[0])
        # prefill: AOT-compile every advertised (bucket, pool-variant)
        # dispatch — .lower().compile() against abstract shapes, so no
        # dummy pool copies are needed and serving never recompiles
        if self.prefill_runner is not None:
            self.prefill_runner.warmup()

    # -- main loop (dispatch-boundary slow path) -----------------------------------
    def _publish_dispatch_metrics(self, dt: float, k: int, batch: int) -> None:
        """Per-dispatch latency + throughput metrics (looked up by name
        each dispatch so registry resets between sweep configs take
        effect)."""
        reg = obs.get_registry()
        reg.histogram("serving.dispatch_latency_s",
                      "wall time of one fused decode dispatch").observe(dt)
        # one dispatch advances every live row by k tokens: per-token
        # latency is dt/k, weighted k so quantiles are over tokens
        reg.histogram("serving.token_latency_s",
                      "per-token decode latency (dispatch wall / K)"
                      ).observe(dt / k, n=k)
        reg.counter("serving.dispatches", "decode dispatches issued").inc()
        reg.counter("serving.tokens_sampled",
                    "tokens sampled across all rows").inc(k * batch)
        for qn, qv in self.batcher.depths().items():
            reg.gauge(f"serving.queue_{qn}",
                      f"scheduler {qn} queue depth").set(qv)

    def _publish_first_token(self, req: Request) -> None:
        """Wall-clock TTFT, aggregate + per-tenant (metric-name label)."""
        if req.ttft_s is None:
            return
        reg = obs.get_registry()
        reg.histogram("serving.ttft_s",
                      "wall-clock time to first token").observe(req.ttft_s)
        reg.histogram(f"qos.ttft_s.{req.tenant}",
                      "per-tenant wall-clock TTFT").observe(req.ttft_s)

    def _publish_finish(self, req: Request) -> None:
        """Wall-clock end-to-end latency + mean inter-token latency for a
        completed request, aggregate + per-tenant."""
        if req.e2e_s is None:
            return
        reg = obs.get_registry()
        reg.histogram("serving.e2e_latency_s",
                      "wall-clock submit-to-finish latency").observe(
                          req.e2e_s)
        reg.histogram(f"qos.e2e_s.{req.tenant}",
                      "per-tenant wall-clock e2e latency").observe(req.e2e_s)
        if req.first_token_ts is not None and len(req.generated) > 1:
            itl = ((req.finish_ts - req.first_token_ts)
                   / (len(req.generated) - 1))
            reg.histogram(f"qos.itl_s.{req.tenant}",
                          "per-tenant mean inter-token latency").observe(
                              itl, n=len(req.generated) - 1)

    # -- bucketed packed prefill (serving/prefill.py) --------------------------
    def _prefill_admitted(self) -> None:
        new = [r for r in self.batcher.active if r.pos == 0]
        if not new:
            return
        pr = self.prefill_runner
        groups = pack_prompts(
            new, min_bucket=pr.min_bucket, max_bucket=pr.max_bucket,
            pack=self.scfg.prefill_pack, max_segments=pr.max_segments)
        for g in groups:
            self._prefill_group(g)

    def _prefill_group(self, group: PackedGroup) -> None:
        """One packed prefill dispatch: provision every segment's prompt
        pages, run the (bucket, pool-variant) executable, then settle the
        boundary accounting — store charges, SysMon streaming record,
        pinned wear/integrity, first-token stamping — with totals exactly
        matching what replaying the prompts through the decode scan would
        have charged (the parity invariant), while SysMon's sampling
        cadence sees ONE sequential burst instead of K decode touches."""
        # provision under pressure: preempt, dropping group members that
        # got evicted themselves (they re-enter at a later boundary with
        # pos still 0), and fail the blocked request when nothing is left
        segs = []
        while True:
            self._drain_faults()
            segs = [r for r in group.requests
                    if not r.preempted and not r.done]
            blocked = None
            for r in segs:
                if not self._ensure_pages(r, k=len(r.prompt)):
                    blocked = r
                    break
            if blocked is None:
                break
            if not self._make_room():
                self._fail_request(blocked, CapacityError(
                    f"request {blocked.rid}: HBM+host pools exhausted "
                    f"during prefill and no preemption victim remains",
                    rid=blocked.rid, occupancy=self.kv.occupancy()))
                note_recovered("backpressure")
        group.requests = segs
        if not segs:
            return

        pr = self.prefill_runner
        store = self.kv.store
        page = self.scfg.page_size
        pt = self.pinned_tier
        Pp = pr.n_table_pages(group.bucket)
        pages_rows = [r.pages for r in segs]
        if pt is None:
            page_tables, block_tables = self.kv.fill_tables(pages_rows, Pp)
            pool_sel = None
            wear_tr = None
        else:
            page_tables, block_tables, pool_sel = self.kv.fill_tables_mixed(
                pages_rows, Pp)
            wear_tr = store.wear_by_tier.get(pt)
            if not pool_sel.any():
                # all prompt pages landed tier-0 resident: single-pool
                # dispatch (same downgrade the decode boundary applies)
                pt = None
                pool_sel = None
                wear_tr = None
        a = pr.build_args(group, block_tables, pool_sel)
        n_tok = group.total_tokens
        t0 = time.perf_counter()
        with obs.span("serve.prefill", step=self.step_count,
                      bucket=group.bucket, segments=len(segs),
                      tokens=n_tok):
            if pt is None:
                fn = pr.get_plain(group.bucket)
                first_d, seg_logits, ecounts, store.fast_pool = fn(
                    self.params, jnp.asarray(a["tokens"]),
                    jnp.asarray(a["local_pos"]),
                    jnp.asarray(a["row_tables"]), jnp.asarray(a["lengths"]),
                    jnp.asarray(a["write_slot"]),
                    jnp.asarray(a["write_off"]),
                    jnp.asarray(a["seg_last"]), store.fast_pool)
            else:
                ppool = store.pools[pt]
                n_pin = ppool.data.shape[0]
                remap_arr = (wear_tr.state.remap if wear_tr is not None
                             else jnp.arange(n_pin, dtype=jnp.int32))
                fn = pr.get_pinned(group.bucket)
                (first_d, seg_logits, ecounts, store.fast_pool,
                 ppool.data) = fn(
                    self.params, jnp.asarray(a["tokens"]),
                    jnp.asarray(a["local_pos"]),
                    jnp.asarray(a["row_tables"]), jnp.asarray(a["row_sel"]),
                    jnp.asarray(a["lengths"]), jnp.asarray(a["write_slot"]),
                    jnp.asarray(a["write_sel"]),
                    jnp.asarray(a["write_off"]),
                    jnp.asarray(a["seg_last"]), store.fast_pool, ppool.data,
                    remap_arr)
            first = np.asarray(first_d)
        dt = time.perf_counter() - t0
        self.last_logits = seg_logits
        reg = obs.get_registry()
        reg.histogram("serving.prefill_latency_s",
                      "wall time of one packed prefill dispatch").observe(dt)
        reg.counter("serving.prefill_dispatches",
                    "packed prefill dispatches issued").inc()
        reg.counter("serving.prefill_tokens",
                    "prompt tokens ingested via prefill").inc(n_tok)

        if self.expert_counts is not None:
            self.expert_counts += np.asarray(ecounts, np.int64)

        # boundary accounting: closed-form dense totals, bit-identical to
        # the replay stream (reads: page j of an Lp-token segment is
        # covered by Lp - j*page inner-step prefixes; writes: the tail
        # lands on it min(page, Lp - j*page) times)
        prompt_lens = [len(r.prompt) for r in segs]
        d_reads, d_writes = replay_page_counts(
            prompt_lens, page_tables, page, self.kv.n_pages)
        self.sysmon = sysmon_mod.record_dense(
            self.sysmon, jnp.asarray(d_reads, dtype=jnp.int32),
            jnp.asarray(d_writes, dtype=jnp.int32))
        if pt is None:
            store.charge_fast_accesses(d_writes, int(d_reads.sum()))
        else:
            store.charge_accesses(d_writes, d_reads)
            # pinned-pool writes charge wear per token write (the decode
            # scan's wear_update totals, host-side) and refresh the
            # written rows' checksums — the in-dispatch scatters bypass
            # the host write paths that normally record both
            wr_slots: list[int] = []
            for si, lp in enumerate(prompt_lens):
                for j in range((lp - 1) // page + 1):
                    if pool_sel[si, j]:
                        wr_slots.extend([int(block_tables[si, j])]
                                        * min(page, lp - j * page))
            if wear_tr is not None and wr_slots:
                store._account_host_writes(
                    pt, wear_tr.phys(np.asarray(wr_slots, np.int64)))
            if store.integrity.enabled and wr_slots:
                store.integrity.record(store, pt, sorted(set(wr_slots)))

        # lifecycle: the prompt is consumed and the first token sampled —
        # the request joins the decode batch at pos == len(prompt), or
        # retires right here when one token was all it asked for
        for req, first_tok in zip(segs, first[:len(segs)]):
            req.tokens = list(req.prompt)
            req.generated = [int(first_tok)]
            self.tokens_out += 1
            req.first_token_step = self.step_count
            req.first_token_ts = time.monotonic()
            self._publish_first_token(req)
            if req.max_new <= 1:
                self.batcher.finish(req, self.step_count)
                self._publish_finish(req)
                self._release_pages(req)
        self._prefill_tokens_pending += n_tok

    def step(self) -> dict:
        # 0) fail owners of pages quarantined since the last boundary
        # (memos-pass scrub, late promotion pre-flights) before admitting
        # against the shrunken pool
        self._drain_faults()
        # 1) admit / resume; make room by preempting if promotion fails.
        # A request that fails provisioning twice in one step is making no
        # progress (its blocker holds the pool) — stop admitting and let
        # the dispatch/memos machinery below free capacity first.
        failed: set[int] = set()
        # power governor (repro.qos): while over the dynamic-power budget
        # the admission width shrinks one slot per throttle level, so the
        # write stream — and with it NVM dynamic power — backs off
        gov = self.memos.governor
        limit = (gov.batch_limit(self.scfg.max_batch)
                 if gov is not None else None)
        with obs.span("serve.admit", step=self.step_count):
            while True:
                admitted = self.batcher.admit(limit)
                if not admitted:
                    break
                obs.get_registry().counter(
                    "serving.admissions",
                    "requests admitted into decode slots").inc(len(admitted))
                ok = True
                stuck = False
                need_room = 0
                for req in admitted:
                    if req.start_step is None:
                        req.start_step = self.step_count
                    if not self._ensure_pages(req):
                        ok = False
                        need_room = max(need_room, req.priority)
                        stuck = stuck or req.rid in failed
                        failed.add(req.rid)
                if stuck:
                    break
                # admission-time preemption is priority-bounded: freeing
                # room for a request may only evict strictly lower
                # priority (unbounded preemption stays reserved for the
                # provision loop, where the dispatch must proceed)
                if not ok and not self._make_room(
                        need_room - 1 if self.batcher.priority_aware
                        else None):
                    break

        # 1b) prefill: every newly admitted request (pos == 0 — nothing
        # processed yet) ingests its whole prompt in one packed bucketed
        # dispatch and joins the running decode batch with its first
        # token already sampled.  Resumed mid-prompt requests (preempted
        # replay) keep the replay path — their pool state is positional.
        if self.prefill_runner is not None:
            self._prefill_admitted()

        active = list(self.batcher.active)
        stats = {"step": self.step_count, "active": len(active)}
        if not active:
            self.step_count += 1
            return stats

        # 2) size the dispatch: K bounded by every sequence's remaining
        # budget (rows stay live for the whole dispatch — finished
        # sequences retire exactly at the boundary), snapped to a power of
        # two so the set of compiled scan lengths stays small
        if self.scfg.reference:
            k = 1
        else:
            k = max(min(self.scfg.decode_block,
                        min(r.remaining_steps for r in active)), 1)
            k = 1 << (k.bit_length() - 1)

        # 3) provision: pre-reserve tail pages for all K positions; under
        # HBM pressure first shrink the dispatch, then preempt (the K=1
        # reference semantics) — preempting to feed a large dispatch
        # would thrash
        with obs.span("serve.provision", step=self.step_count) as prov_sp:
            while True:
                # promotion pre-flights inside _ensure_pages can
                # quarantine a corrupt source page: fail its owner now so
                # the retry below provisions against the survivors
                self._drain_faults()
                active = [r for r in active if not r.done]
                blocked = None
                for req in active:
                    if not req.preempted and not self._ensure_pages(req, k):
                        blocked = req
                        break
                if blocked is None:
                    break
                if k > 1:
                    k //= 2
                elif not self._make_room():
                    # backpressure floor: even at K=1 with nothing left
                    # to preempt the pools cannot host this sequence's
                    # next page — retire it with a structured capacity
                    # error instead of crashing the whole server
                    self._fail_request(blocked, CapacityError(
                        f"request {blocked.rid}: HBM+host pools exhausted "
                        f"and no preemption victim remains",
                        rid=blocked.rid, occupancy=self.kv.occupancy()))
                    note_recovered("backpressure")
            prov_sp.set(k=k)
        active = [r for r in active if not r.preempted and not r.done]
        # pre-dispatch integrity sweep: quarantine any pinned-pool page
        # whose stored bits drifted since its last checksum, and fail its
        # owner, *before* the block tables are built — the dispatch never
        # attends to corrupt bits
        if get_injector().enabled:
            self._predispatch_verify(active)
            self._drain_faults()
            active = [r for r in active if not r.done]
        if not active:
            self.step_count += 1
            return stats

        B = len(active)
        P = self.scfg.max_pages_per_seq
        page = self.scfg.page_size
        store = self.kv.store
        pt = self.pinned_tier
        positions = np.array([r.pos for r in active], np.int32)
        prompt_lens = np.array([len(r.prompt) for r in active], np.int32)
        tokens = np.array([(r.prompt + r.generated)[r.pos] for r in active],
                          np.int32)
        if pt is None:
            page_tables, block_tables = self.kv.fill_tables(
                [r.pages for r in active], P)
            pool_sel = None
            wear_tr = None
        else:
            page_tables, block_tables, pool_sel = self.kv.fill_tables_mixed(
                [r.pages for r in active], P)
            wear_tr = store.wear_by_tier.get(pt)
            if not pool_sel.any():
                # every page of this dispatch is tier-0 resident: the
                # block tables are plain fast-pool slots, so take the
                # single-pool fast path — the dual-pool dispatch (second
                # gather + select per layer) only pays for itself when a
                # page actually lives in the pinned tier
                pt = None
                pool_sel = None
                wear_tr = None

        dispatch_path = (("reference" if self.scfg.reference else "fused")
                         + ("+pinned" if pt is not None else ""))
        # wall clock measured independently of tracing — the latency
        # histograms must populate with the tracer disabled
        t_disp0 = time.perf_counter()
        with obs.span("serve.dispatch", step=self.step_count, k=k, batch=B,
                      path=dispatch_path):
            if self.scfg.reference and pt is None:
                # -- retained K=1 reference path (parity oracle / baseline)
                logits, ecounts, store.fast_pool = self._decode_fn(
                    self.params, jnp.asarray(tokens[:, None]),
                    jnp.asarray(positions), jnp.asarray(block_tables),
                    jnp.asarray(positions + 1), store.fast_pool)
                # host-side argmax sampling: one transfer per token
                sampled = np.asarray(
                    jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                    np.int32)[None, :]
                # standalone per-step SysMon records — the host round-trips
                # the fused path folds into its scan
                read_valid = (np.arange(P)[None, :]
                              <= (positions // page)[:, None])
                self.sysmon = sysmon_mod.record(
                    self.sysmon, jnp.asarray(page_tables.reshape(-1)),
                    is_write=False, valid=jnp.asarray(read_valid.reshape(-1)))
                tails = page_tables[np.arange(B), positions // page]
                self.sysmon = sysmon_mod.record(
                    self.sysmon, jnp.asarray(tails), is_write=True)
                page_writes = np.zeros(store.cfg.n_pages, np.int64)
                np.add.at(page_writes, tails, 1)
                self.last_logits = logits
            elif self.scfg.reference:
                # -- K=1 reference path over the dual pools (parity oracle)
                ppool = store.pools[pt]
                n_pin = ppool.data.shape[0]
                remap_arr = (wear_tr.state.remap if wear_tr is not None
                             else jnp.arange(n_pin, dtype=jnp.int32))
                logits, ecounts, store.fast_pool, ppool.data = \
                    self._decode_pinned_fn(
                        self.params, jnp.asarray(tokens[:, None]),
                        jnp.asarray(positions), jnp.asarray(block_tables),
                        jnp.asarray(pool_sel), jnp.asarray(positions + 1),
                        store.fast_pool, ppool.data, remap_arr)
                sampled = np.asarray(
                    jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                    np.int32)[None, :]
                read_valid = (np.arange(P)[None, :]
                              <= (positions // page)[:, None])
                self.sysmon = sysmon_mod.record(
                    self.sysmon, jnp.asarray(page_tables.reshape(-1)),
                    is_write=False, valid=jnp.asarray(read_valid.reshape(-1)))
                tails = page_tables[np.arange(B), positions // page]
                self.sysmon = sysmon_mod.record(
                    self.sysmon, jnp.asarray(tails), is_write=True)
                page_writes = np.zeros(store.cfg.n_pages, np.int64)
                np.add.at(page_writes, tails, 1)
                # host-side wear charge for pinned tail writes (the fused
                # path folds this into the scan; totals are bit-identical).
                # The block tables carry *logical* pinned slots now, so
                # translate through the remap before charging the physical
                # rows — this also drives the host leveler, whose advances
                # the next dispatch picks up through ``wear_tr.state.remap``.
                tcol = positions // page
                tslot = block_tables[np.arange(B), tcol]
                tpin = pool_sel[np.arange(B), tcol] > 0
                if wear_tr is not None and tpin.any():
                    store._account_host_writes(pt, wear_tr.phys(tslot[tpin]))
                self.last_logits = logits
            elif pt is None:
                # -- fused K-step dispatch ---------------------------------
                prompt_buf = np.zeros((B, P * page), np.int32)
                for i, r in enumerate(active):
                    prompt_buf[i, :len(r.prompt)] = r.prompt
                fn = self._get_fused(k)
                (sampled_d, logits, self.sysmon, store.fast_pool,
                 page_writes_d, ecounts) = fn(
                    self.params, jnp.asarray(tokens), jnp.asarray(positions),
                    jnp.asarray(prompt_buf), jnp.asarray(prompt_lens),
                    jnp.asarray(page_tables), jnp.asarray(block_tables),
                    self.sysmon, store.fast_pool)
                sampled = np.asarray(sampled_d)  # one transfer per K tokens
                page_writes = np.asarray(page_writes_d)
                self.last_logits = logits
            else:
                # -- fused K-step dual-pool dispatch: slow-tier KV appends
                # and the wear_update scatter-add ride the same scan -------
                ppool = store.pools[pt]
                n_pin_rows = ppool.data.shape[0]
                prompt_buf = np.zeros((B, P * page), np.int32)
                for i, r in enumerate(active):
                    prompt_buf[i, :len(r.prompt)] = r.prompt
                wear_arr = (wear_tr.state.wear if wear_tr is not None
                            else jnp.zeros((1,), jnp.int32))
                remap_arr = (wear_tr.state.remap if wear_tr is not None
                             else jnp.arange(n_pin_rows, dtype=jnp.int32))
                lv = (store.leveler_by_tier.get(pt)
                      if self._gap_interval else None)
                gap0 = jnp.int32(lv.stats.gap if lv is not None else 0)
                pending0 = jnp.int32(lv._pending if lv is not None else 0)
                fn = self._get_fused_pinned(k)
                (sampled_d, logits, self.sysmon, store.fast_pool, ppool.data,
                 wear_out, remap_out, gap_out, pending_out, n_adv_out,
                 page_writes_d, ecounts) = fn(
                    self.params, jnp.asarray(tokens), jnp.asarray(positions),
                    jnp.asarray(prompt_buf), jnp.asarray(prompt_lens),
                    jnp.asarray(page_tables), jnp.asarray(block_tables),
                    jnp.asarray(pool_sel), self.sysmon, store.fast_pool,
                    ppool.data, wear_arr, remap_arr, gap0, pending0)
                sampled = np.asarray(sampled_d)
                page_writes = np.asarray(page_writes_d)
                if wear_tr is not None:
                    n_pin_w = int(page_writes[store.tier == pt].sum())
                    n_adv = int(n_adv_out)
                    # adopt the dispatch's wear counters (app writes + the
                    # two row rewrites each in-dispatch gap advance
                    # charged), its rotated remap, and the leveler's
                    # (gap, pending) bookkeeping — the boundary replays
                    # counter arithmetic only, never pool row swaps
                    with obs.span("serve.startgap_adopt", advances=n_adv):
                        wear_tr.adopt_scan_writes(wear_out, n_pin_w,
                                                  leveling_writes=2 * n_adv)
                        if n_adv:
                            wear_tr.adopt_scan_remap(remap_out)
                        if lv is not None:
                            lv.adopt_scan_advances(n_adv, int(pending_out))
                self.last_logits = logits
        dispatch_dt = time.perf_counter() - t_disp0
        self._publish_dispatch_metrics(dispatch_dt, k, B)

        if self.expert_counts is not None:
            self.expert_counts += np.asarray(ecounts, np.int64)

        # 4) access accounting, vectorized: device-counted page writes
        # bump versions in one add; reads are closed-form.  The dual-pool
        # path splits the charge by each page's tier (the dispatch touched
        # both the fast pool and the pinned tier).
        if pt is None:
            n_reads = int(((positions[:, None] + np.arange(k)[None, :])
                           // page + 1).sum())
            store.charge_fast_accesses(page_writes, n_reads)
        else:
            page_reads = self._page_read_counts(positions, page_tables, k)
            store.charge_accesses(page_writes, page_reads)
        # refresh checksums for pinned-pool rows the dispatch appended to
        # (the in-scan tail writes bypass the host write paths that
        # normally record them)
        if pt is not None and store.integrity.enabled:
            written = np.nonzero(page_writes > 0)[0]
            wmask = (store.tier[written] == pt) & \
                (store.slot[written] != NO_SLOT)
            if wmask.any():
                store.integrity.record(
                    store, pt, np.unique(store.slot[written[wmask]]))

        # 5) advance sequences from the returned token block: tokens
        # sampled at inner step s >= emit_from[i] are new generations
        with obs.span("serve.retire", step=self.step_count):
            emit_from = np.maximum(prompt_lens - 1 - positions, 0)
            for i, req in enumerate(active):
                had_gen = bool(req.generated)
                new_gen = [int(t) for t in sampled[emit_from[i]:k, i]]
                req.generated.extend(new_gen)
                self.tokens_out += len(new_gen)
                if new_gen and not had_gen:
                    # first token of this request surfaced in this block:
                    # stamp both clocks (wall for reporting, step for the
                    # deterministic QoS gates — the inner step that
                    # sampled it)
                    req.first_token_step = self.step_count + int(emit_from[i])
                    req.first_token_ts = time.monotonic()
                    self._publish_first_token(req)
                seq = req.prompt + req.generated
                p0 = int(positions[i])
                req.tokens.extend(seq[p0:p0 + k])
                if len(req.generated) >= req.max_new:
                    self.batcher.finish(req, self.step_count + k - 1)
                    self._publish_finish(req)
                    self._release_pages(req)

        # 6) memos loop between dispatches (hot pages stay; cold/preempted
        # pages drain to host) — pass granularity, off the decode hot
        # path.  With overlap_plan the pass's plan phase runs on a worker
        # thread across the *next* dispatch and commits at the following
        # boundary (maybe_step returns that commit's report).
        if self.scfg.memos_enabled:
            # on_commit: re-promote pages an async commit demoted out from
            # under running sequences *before* the next plan snapshots, so
            # the reaction is part of the snapshot instead of a guaranteed
            # mid-plan conflict at the next commit
            # the memos sampling clock also advances by every prompt
            # token prefill ingested since the last tick (replay would
            # have spent that many inner decode steps)
            pending = self._prefill_tokens_pending
            self._prefill_tokens_pending = 0
            self.sysmon, report = self.memos.maybe_step(
                self.sysmon, steps=k + pending,
                on_commit=lambda rep: self._promote_all(
                    list(self.batcher.active)))
            if report is not None:
                stats["memos"] = {
                    "migrated": report.migrations.migrated,
                    "to_fast": report.migrations.to_fast,
                    "to_slow": report.migrations.to_slow,
                    "wear_pressure": report.wear_pressure,
                    "power_pressure": report.power_pressure,
                    "power_throttle": report.power_throttle,
                    "power_mw": report.power_mw,
                    "committed_async": report.committed_async,
                    "plan_conflict": report.plan_conflict,
                    "pages_committed": report.pages_committed,
                    "pages_degraded": report.pages_degraded,
                    "pages_dropped": report.pages_dropped,
                }
                if report.nvm is not None:
                    stats["nvm"] = {
                        "wear_max": report.nvm.wear_max,
                        "slow_writes": report.nvm.slow_writes,
                        "dynamic_power_mw": report.nvm.dynamic_power_mw,
                        "lifetime_years": report.nvm.lifetime_years_actual,
                    }
                # single bulk promotion for every page the memos pass
                # demoted out from under a still-running sequence (async
                # commits already promoted via on_commit above)
                if not self.scfg.overlap_plan:
                    self._promote_all(list(self.batcher.active))

        if not self.scfg.memos_enabled:
            # no memos pass ever rolls the bandwidth-headroom window, so
            # roll it at dispatch boundaries — otherwise cascade targeting
            # would rank tiers by lifetime-cumulative inflow
            store.roll_traffic_window()

        # 7) fault-injection tick, strictly *after* every write path of
        # this boundary has recorded its checksums and *before* the next
        # boundary's pre-dispatch verify — injected corruption always has
        # a detection point ahead of the next serve
        inj = get_injector()
        if inj.enabled:
            inj.tick(store)

        self.step_count += k
        stats["decode_block"] = k
        stats["tokens_out"] = self.tokens_out
        stats.update(self.kv.occupancy())
        return stats

    def run(self, max_steps: int = 10_000) -> list[dict]:
        hist = []
        while not self.batcher.all_done() and self.step_count < max_steps:
            hist.append(self.step())
        # commit any plan still overlapping when the workload drains, so
        # stores/telemetry are consistent for inspection across runs
        if self.scfg.memos_enabled:
            report = self.memos.flush()
            if report is not None and self.batcher.active:
                self._promote_all(list(self.batcher.active))
        return hist

    def close(self) -> None:
        """Release the engine's background resources (the async memos
        plan worker); safe to call multiple times."""
        self.memos.close()
