"""Paged serving engine: continuous batching + memos-managed KV tiering.

The decode path reads KV through block tables over the memos HBM pool
(paged_attention kernel), charges SysMon with the exact page-access
stream, and lets the periodic memos loop (Fig. 10) migrate pages between
HBM and host:

  * running sequences touch all their pages every step  -> hot  -> stay;
  * the tail page is written every step                  -> WD   -> stay;
  * preempted / finished-prefix pages go quiet           -> cold -> host;
  * resumed sequences eagerly promote their pages (paper's eager mode).

The jitted step writes the new token's K/V into the pool *before*
attention (exact self-attention; the pool buffer is donated), so engine
outputs are bit-comparable to the model-level dense decode path — tested
in tests/test_serving.py.

Supports every ``layout == "attn"`` arch (dense + MoE); MoE expert
hotness is accumulated per step for the expert-tiering benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import sysmon as sysmon_mod
from repro.core.memos import MemosConfig, MemosManager
from repro.core.placement import FAST
from repro.kernels.paged_attention import paged_attention
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.kv_cache import PagedKVCache, PagedKVConfig
from repro.serving.scheduler import ContinuousBatcher, Request


@dataclass
class ServeConfig:
    page_size: int = 16
    max_batch: int = 4
    fast_slots: int = 48
    slow_slots: int = 512
    memos_interval: int = 8
    max_pages_per_seq: int = 64
    memos_enabled: bool = True
    # NVM wear feedback horizon (years); None = telemetry only, no feedback
    lifetime_horizon_years: float | None = None


class PagedServingEngine:
    def __init__(self, cfg: ArchConfig, params: dict, scfg: ServeConfig):
        assert cfg.layout == "attn", "paged engine serves attention archs"
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.kv = PagedKVCache(PagedKVConfig(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, page_size=scfg.page_size,
            fast_slots=scfg.fast_slots, slow_slots=scfg.slow_slots))
        store = self.kv.store
        self.sysmon = sysmon_mod.init(
            scfg.slow_slots, n_banks=store.cfg.n_banks,
            n_slabs=store.cfg.n_slabs)
        self.memos = MemosManager(store, MemosConfig(
            interval=scfg.memos_interval, adaptive_interval=False,
            lifetime_horizon_years=scfg.lifetime_horizon_years))
        self.batcher = ContinuousBatcher(scfg.max_batch)
        self.step_count = 0
        self.expert_counts = (np.zeros(cfg.n_experts, np.int64)
                              if cfg.is_moe else None)
        self.tokens_out = 0
        self.rid = 0
        self._decode_fn = jax.jit(self._decode_batch, donate_argnums=(5,))

    # -- request API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int) -> Request:
        req = Request(self.rid, list(prompt), max_new, arrival=self.step_count)
        req.tokens = []          # processed tokens (prompt-consumed + generated)
        req.generated = []       # type: ignore[attr-defined]
        self.rid += 1
        self.batcher.submit(req)
        return req

    # -- page management ---------------------------------------------------------
    def _ensure_page(self, req: Request) -> bool:
        need = req.pos // self.scfg.page_size + 1
        while len(req.pages) < need:
            pid = self.kv.new_page(FAST)
            if pid is None:
                return False
            req.pages.append(pid)
        tail = req.pages[need - 1]
        if not self.kv.is_resident(tail):
            self.memos.engine.migrate_locked([tail], FAST)
        return self.kv.is_resident(tail)

    def _promote(self, req: Request) -> bool:
        return self._promote_all([req])

    def _promote_all(self, reqs: list[Request]) -> bool:
        """Promote every non-resident page of ``reqs`` in one batched
        migration (single plan->execute bulk move instead of per-request
        per-page copies)."""
        pids = [p for req in reqs for p in req.pages]
        if not pids:
            return True
        mask = self.kv.resident_mask(pids)
        if not mask.all():
            cold = [p for p, m in zip(pids, mask) if not m]
            self.memos.engine.migrate_locked(cold, FAST)
            mask = self.kv.resident_mask(pids)
        return bool(mask.all())

    def _make_room(self) -> bool:
        return self.batcher.preempt_lowest() is not None

    # -- jitted model compute ------------------------------------------------------
    def _decode_batch(self, params, tokens, positions, block_tables,
                      lengths, fast_pool):
        """tokens [B,1] i32; positions [B]; block_tables [B,P] fast-slot
        ids; lengths [B] (incl. current token); fast_pool donated.
        Returns (logits [B, Vp], expert_counts|0, new fast_pool)."""
        cfg = self.cfg
        page = self.scfg.page_size
        B = tokens.shape[0]
        h = T.embed_in(params, cfg, {"tokens": tokens}, None)
        cos, sin = L.rope_angles(positions[:, None], cfg.head_dim,
                                 cfg.rope_theta)
        b_idx = jnp.arange(B)
        slot = block_tables[b_idx, positions // page]
        off = positions % page
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            dtype = fast_pool.dtype
            fast_pool = fast_pool.at[slot, l, 0, off].set(
                k[:, 0].astype(dtype))
            fast_pool = fast_pool.at[slot, l, 1, off].set(
                v[:, 0].astype(dtype))
            out = paged_attention(q[:, 0], fast_pool[:, l, 0],
                                  fast_pool[:, l, 1], block_tables, lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                B, cfg.n_heads, cfg.head_dim), p.wo)[:, None, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[:, 0]
        return logits, counts_acc, fast_pool

    # -- main loop -----------------------------------------------------------------
    def step(self) -> dict:
        # 1) admit / resume; make room by preempting if promotion fails
        while True:
            admitted = self.batcher.admit()
            if not admitted:
                break
            ok = True
            for req in admitted:
                if req.start_step is None:
                    req.start_step = self.step_count
                if not (self._promote(req) and self._ensure_page(req)):
                    ok = False
            if not ok and not self._make_room():
                break

        active = list(self.batcher.active)
        stats = {"step": self.step_count, "active": len(active)}
        if not active:
            self.step_count += 1
            return stats

        for req in list(active):
            while not self._ensure_page(req):
                if not self._make_room():
                    raise RuntimeError("HBM+host pools exhausted")
            if req.preempted:       # got preempted while making room
                active.remove(req)
        if not active:
            self.step_count += 1
            return stats

        B = len(active)
        P = self.scfg.max_pages_per_seq
        page = self.scfg.page_size
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        block_tables = np.zeros((B, P), np.int32)
        lengths = np.zeros((B,), np.int32)
        for i, req in enumerate(active):
            seq = req.prompt + req.generated
            tokens[i, 0] = seq[req.pos]
            positions[i] = req.pos
            lengths[i] = req.pos + 1
            pg = req.pages[:P]
            # one vectorized page-table lookup per row (no per-page loop)
            block_tables[i, :len(pg)] = self.kv.fast_slots_of(pg)

        # 2) jitted decode: KV write into the pool + paged attention
        store = self.kv.store
        logits, ecounts, store.fast_pool = self._decode_fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(block_tables), jnp.asarray(lengths),
            store.fast_pool)
        if self.expert_counts is not None:
            self.expert_counts += np.asarray(ecounts, np.int64)

        # 3) advance sequences / sample
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab], axis=-1))
        for i, req in enumerate(active):
            pos_i = int(positions[i])             # pre-advance position
            tail = req.pages[pos_i // page]
            store.version[tail] += 1              # dirty bit for migration
            store.writes_to[FAST] += 1
            req.tokens.append(int(tokens[i, 0]))
            if pos_i + 1 >= len(req.prompt):      # logits predict a new token
                req.generated.append(int(nxt[i]))
                self.tokens_out += 1
            done = len(req.generated) >= req.max_new
            if done:
                self.batcher.finish(req, self.step_count)
                for pid in req.pages:
                    self.kv.free_page(pid)
                req.pages = []

        # 4) SysMon charging: exact page-access stream
        touched = [pid for req in active for pid in req.pages]
        tails = [req.pages[min(req.pos // page, len(req.pages) - 1)]
                 for req in active if req.pages]
        if touched:
            self.sysmon = sysmon_mod.record(
                self.sysmon, jnp.asarray(touched, jnp.int32), is_write=False)
            store.reads_from[FAST] += len(touched)
        if tails:
            self.sysmon = sysmon_mod.record(
                self.sysmon, jnp.asarray(tails, jnp.int32), is_write=True)

        # 5) memos loop (hot pages stay; cold/preempted pages drain to host)
        if self.scfg.memos_enabled:
            self.sysmon, report = self.memos.maybe_step(self.sysmon)
            if report is not None:
                stats["memos"] = {
                    "migrated": report.migrations.migrated,
                    "to_fast": report.migrations.to_fast,
                    "to_slow": report.migrations.to_slow,
                    "wear_pressure": report.wear_pressure,
                }
                if report.nvm is not None:
                    stats["nvm"] = {
                        "wear_max": report.nvm.wear_max,
                        "slow_writes": report.nvm.slow_writes,
                        "dynamic_power_mw": report.nvm.dynamic_power_mw,
                        "lifetime_years": report.nvm.lifetime_years_actual,
                    }
                # single bulk promotion for every page the memos pass demoted
                # out from under a still-running sequence
                self._promote_all(list(self.batcher.active))

        self.step_count += 1
        stats["tokens_out"] = self.tokens_out
        stats.update(self.kv.occupancy())
        return stats

    def run(self, max_steps: int = 10_000) -> list[dict]:
        hist = []
        while not self.batcher.all_done() and self.step_count < max_steps:
            hist.append(self.step())
        return hist
