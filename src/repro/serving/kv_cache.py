"""Paged KV cache on top of the memos TierStore.

Logical page = one ``page_size``-token span of one sequence, payload
[L, 2(K/V), page, Hkv, Dh] across all layers (pages migrate between HBM
and host as a unit, like the OS paper's 4 KB pages).  The TierStore's
sub-buddy allocator places pages by color (bank = pool-slot stripe =
HBM-controller analogue); block tables map (sequence, span) -> logical
page -> physical fast-pool slot for the paged_attention kernel.

SysMon charging: every decode step reads all pages of active sequences
and writes the tail page — the exact access stream (no sampling error),
DESIGN.md Sec. 2.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.placement import FAST, SLOW
from repro.core.tiers import NO_SLOT, TierConfig, TierStore


@dataclass
class PagedKVConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    page_size: int = 16
    fast_slots: int = 64          # HBM pool capacity (pages)
    slow_slots: int = 512         # host pool capacity
    dtype: object = jnp.float32


class PagedKVCache:
    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        shape = (cfg.n_layers, 2, cfg.page_size, cfg.n_kv_heads, cfg.head_dim)
        self.store = TierStore(TierConfig(
            n_pages=cfg.slow_slots, fast_slots=cfg.fast_slots,
            slow_slots=cfg.slow_slots, page_shape=shape, dtype=cfg.dtype))
        self._free_ids = list(range(cfg.slow_slots - 1, -1, -1))

    # -- logical page lifecycle ------------------------------------------------
    def new_page(self, tier: int = FAST) -> int | None:
        if not self._free_ids:
            return None
        pid = self._free_ids.pop()
        if not self.store.allocate(pid, tier):
            if tier == FAST and self.store.allocate(pid, SLOW):
                return pid            # HBM full: land on host, promote later
            self._free_ids.append(pid)
            return None
        return pid

    def free_page(self, pid: int) -> None:
        self.store.release(pid)
        self._free_ids.append(pid)

    def is_resident(self, pid: int) -> bool:
        return int(self.store.tier[pid]) == FAST and \
            int(self.store.slot[pid]) != NO_SLOT

    def fast_slot(self, pid: int) -> int:
        assert self.is_resident(pid), f"page {pid} not HBM-resident"
        return int(self.store.slot[pid])

    def resident_mask(self, pids) -> np.ndarray:
        """bool [k]: which of ``pids`` are live in the fast pool."""
        pids = np.asarray(pids, np.int64)
        return (self.store.tier[pids] == FAST) & \
            (self.store.slot[pids] != NO_SLOT)

    def fast_slots_of(self, pids) -> np.ndarray:
        """int32 [k] fast-pool slots for a batch of logical pages — the
        vectorized block-table fill (all pages must be HBM-resident)."""
        pids = np.asarray(pids, np.int64)
        assert self.resident_mask(pids).all(), \
            f"non-resident pages in {pids.tolist()}"
        return self.store.slot[pids].astype(np.int32)

    def fill_tables(self, pages_rows: list[list[int]],
                    n_cols: int) -> tuple[np.ndarray, np.ndarray]:
        """(page_tables, block_tables) int32 [B, n_cols] for a batch of
        sequences' logical page lists: logical ids feed SysMon charging,
        fast-pool slots feed the paged_attention kernel.  One vectorized
        page-table lookup per row (no per-page loops); unused columns are
        zero and must be masked by position/length downstream."""
        B = len(pages_rows)
        page_tables = np.zeros((B, n_cols), np.int32)
        block_tables = np.zeros((B, n_cols), np.int32)
        for i, pg in enumerate(pages_rows):
            pg = pg[:n_cols]
            page_tables[i, :len(pg)] = pg
            block_tables[i, :len(pg)] = self.fast_slots_of(pg)
        return page_tables, block_tables

    # -- data access -------------------------------------------------------------
    def write_token_kv(self, pid: int, layer_kv: jnp.ndarray,
                       offset: int) -> None:
        """layer_kv: [L, 2, Hkv, Dh] for one token at in-page ``offset``.
        Fast path writes straight into the pool slot; bumps the version
        (the dirty bit for optimistic migration)."""
        slot = int(self.store.slot[pid])
        assert slot != NO_SLOT
        if int(self.store.tier[pid]) == FAST:
            self.store.fast_pool = self.store.fast_pool.at[
                slot, :, :, offset].set(layer_kv.astype(self.store.cfg.dtype))
            self.store.writes_to[FAST] += 1
        else:
            page = self.store._slow_read(slot)
            page[:, :, offset] = np.asarray(layer_kv, np.float32)
            self.store._slow_write(slot, page)
            self.store.writes_to[SLOW] += 1
        self.store.version[pid] += 1

    def layer_pools(self, layer: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(k_pool, v_pool) views [n_fast_slots, page, Hkv, Dh] for the
        paged_attention kernel."""
        return (self.store.fast_pool[:, layer, 0],
                self.store.fast_pool[:, layer, 1])

    def occupancy(self) -> dict:
        return self.store.occupancy()
