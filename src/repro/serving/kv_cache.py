"""Paged KV cache on top of the memos TierStore.

Logical page = one ``page_size``-token span of one sequence, payload
[L, 2(K/V), page, Hkv, Dh] across all layers (pages migrate between the
hierarchy's tiers as a unit, like the OS paper's 4 KB pages).  The tier
layout comes from a :class:`~repro.core.hierarchy.MemoryHierarchy` —
two-tier HBM/host by default, or any deeper stack (e.g. the
HBM -> DRAM-sim -> NVM-sim demo) via ``PagedKVConfig.hierarchy``.  Tier 0
is the serving tier: block tables map (sequence, span) -> logical page ->
tier-0 pool slot for the paged_attention kernel, so a page must be
promoted to tier 0 before it can be attended to.

Each tier's slots are placed by its own color-aware sub-buddy allocator
(bank = pool-slot stripe = HBM-controller analogue).

SysMon charging: every decode step reads all pages of active sequences
and writes the tail page — the exact access stream (no sampling error),
DESIGN.md Sec. 2.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.hierarchy import MemoryHierarchy
from repro.core.tiers import NO_SLOT, StoreConfig, TierStore

SERVE_TIER = 0   # compute only ever reads tier 0 (the fastest device pool)


@dataclass
class PagedKVConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    page_size: int = 16
    fast_slots: int = 64          # HBM pool capacity (two-tier default)
    slow_slots: int = 512         # host pool capacity (two-tier default)
    dtype: object = jnp.float32
    # full tier stack; None -> MemoryHierarchy.two_tier(fast, slow)
    hierarchy: MemoryHierarchy | None = None
    # logical page count; None -> total backing capacity (tiers 1..deepest)
    n_pages: int | None = None


class PagedKVCache:
    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        hier = cfg.hierarchy or MemoryHierarchy.two_tier(cfg.fast_slots,
                                                         cfg.slow_slots)
        n_pages = (cfg.n_pages if cfg.n_pages is not None
                   else sum(t.slots for t in hier.tiers[1:]))
        shape = (cfg.n_layers, 2, cfg.page_size, cfg.n_kv_heads, cfg.head_dim)
        self.store = TierStore(StoreConfig(
            n_pages=n_pages, page_shape=shape, hierarchy=hier,
            dtype=cfg.dtype))
        self.n_pages = n_pages
        self._free_ids = list(range(n_pages - 1, -1, -1))

    @property
    def pinned_tier(self) -> int | None:
        """The deepest tier when it is a pinned-host pool (addressable
        from device code, so the fused dispatch can serve KV out of it
        and append to it); None otherwise."""
        deepest = self.store.hierarchy.deepest
        return deepest if self.store.hierarchy[deepest].is_pinned else None

    # -- logical page lifecycle ------------------------------------------------
    def new_page(self, tier: int = SERVE_TIER) -> int | None:
        """Bind a fresh logical page, preferring ``tier`` and cascading
        down the hierarchy when a pool is full (HBM full -> next tier,
        promote later).  The backing tiers are tried in bandwidth-headroom
        order — per-``MediumSpec`` peak bandwidth against the (src, dst)
        traffic counters' current window — so a saturated middle channel
        is skipped; with unmodeled bandwidths this reduces to plain tier
        order."""
        if not self._free_ids:
            return None
        pid = self._free_ids.pop()
        order = [tier] + self.store.backing_tier_order(start=tier + 1)
        for t in order:
            if self.store.allocate(pid, t):
                return pid
        self._free_ids.append(pid)
        return None

    def free_page(self, pid: int) -> None:
        self.store.release(pid)
        self._free_ids.append(pid)

    def is_resident(self, pid: int) -> bool:
        return int(self.store.tier[pid]) == SERVE_TIER and \
            int(self.store.slot[pid]) != NO_SLOT

    def fast_slot(self, pid: int) -> int:
        assert self.is_resident(pid), f"page {pid} not HBM-resident"
        return int(self.store.slot[pid])

    def resident_mask(self, pids) -> np.ndarray:
        """bool [k]: which of ``pids`` are live in the serving (tier-0)
        pool."""
        pids = np.asarray(pids, np.int64)
        return (self.store.tier[pids] == SERVE_TIER) & \
            (self.store.slot[pids] != NO_SLOT)

    def servable_mask(self, pids) -> np.ndarray:
        """bool [k]: which of ``pids`` the fused dispatch can attend to —
        tier-0 residents plus, when the deepest tier is pinned-host,
        residents of that pool (served in place, no promotion needed)."""
        pids = np.asarray(pids, np.int64)
        live = self.store.slot[pids] != NO_SLOT
        ok = self.store.tier[pids] == SERVE_TIER
        pt = self.pinned_tier
        if pt is not None:
            ok = ok | (self.store.tier[pids] == pt)
        return ok & live

    def fast_slots_of(self, pids) -> np.ndarray:
        """int32 [k] tier-0 pool slots for a batch of logical pages — the
        vectorized block-table fill (all pages must be HBM-resident)."""
        pids = np.asarray(pids, np.int64)
        assert self.resident_mask(pids).all(), \
            f"non-resident pages in {pids.tolist()}"
        return self.store.slot[pids].astype(np.int32)

    def fill_tables(self, pages_rows: list[list[int]],
                    n_cols: int) -> tuple[np.ndarray, np.ndarray]:
        """(page_tables, block_tables) int32 [B, n_cols] for a batch of
        sequences' logical page lists: logical ids feed SysMon charging,
        tier-0 pool slots feed the paged_attention kernel.  One vectorized
        page-table lookup per row (no per-page loops); unused columns are
        zero and must be masked by position/length downstream."""
        B = len(pages_rows)
        page_tables = np.zeros((B, n_cols), np.int32)
        block_tables = np.zeros((B, n_cols), np.int32)
        for i, pg in enumerate(pages_rows):
            pg = pg[:n_cols]
            page_tables[i, :len(pg)] = pg
            block_tables[i, :len(pg)] = self.fast_slots_of(pg)
        return page_tables, block_tables

    def fill_tables_mixed(self, pages_rows: list[list[int]], n_cols: int
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(page_tables, block_tables, pool_sel) for the dual-pool fused
        dispatch: every page must be *servable* (tier 0 or the pinned
        deepest tier).  ``block_tables`` holds the slot in the page's own
        pool — tier-0 pool slot, or the pinned pool's **logical** slot:
        the dispatch translates pinned slots through the wear-leveling
        remap it carries in its scan, so in-dispatch Start-Gap advances
        keep addressing the right rows mid-scan (host pre-translation
        would go stale after the first in-scan swap); ``pool_sel`` is 1
        where the page is pinned-resident."""
        pt = self.pinned_tier
        assert pt is not None, "fill_tables_mixed needs a pinned deepest tier"
        store = self.store
        B = len(pages_rows)
        page_tables = np.zeros((B, n_cols), np.int32)
        block_tables = np.zeros((B, n_cols), np.int32)
        pool_sel = np.zeros((B, n_cols), np.int32)
        for i, pg in enumerate(pages_rows):
            pg = np.asarray(pg[:n_cols], np.int64)
            assert self.servable_mask(pg).all(), \
                f"non-servable pages in {pg.tolist()}"
            sel = (store.tier[pg] == pt).astype(np.int32)
            page_tables[i, :len(pg)] = pg
            block_tables[i, :len(pg)] = store.slot[pg].astype(np.int32)
            pool_sel[i, :len(pg)] = sel
        return page_tables, block_tables, pool_sel

    # -- data access -------------------------------------------------------------
    def write_token_kv(self, pid: int, layer_kv: jnp.ndarray,
                       offset: int) -> None:
        """layer_kv: [L, 2, Hkv, Dh] for one token at in-page ``offset``.
        Device-tier path writes straight into the pool slot; host tiers
        read-modify-write the page.  Bumps the version (the dirty bit for
        optimistic migration)."""
        t, slot = int(self.store.tier[pid]), int(self.store.slot[pid])
        assert slot != NO_SLOT
        if self.store.is_device_tier(t):
            pool = self.store.pools[t]
            pool.data = pool.data.at[slot, :, :, offset].set(
                layer_kv.astype(pool.dtype))
        elif self.store.is_pinned_tier(t):
            # pinned pool: one jitted in-place token write (no host
            # read-modify-write round trip), charged to the wear remap
            wear = self.store.wear_by_tier.get(t)
            phys = slot if wear is None else wear.phys_one(slot)
            pool = self.store.pools[t]
            assert not pool.quantized, \
                "token-granular appends need a lossless pinned pool"
            pool.data = pool.data.at[phys, :, :, offset].set(
                layer_kv.astype(pool.data.dtype))
            self.store._account_host_writes(t, np.asarray([phys]))
            self.store.integrity.record(self.store, t, [slot])
        else:
            page = self.store._host_read(t, slot)
            page[:, :, offset] = np.asarray(layer_kv, np.float32)
            self.store._host_write(t, slot, page)
        self.store.writes_to[t] += 1
        self.store.bump_version(pid)

    def layer_pools(self, layer: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(k_pool, v_pool) views [n_fast_slots, page, Hkv, Dh] for the
        paged_attention kernel."""
        return (self.store.fast_pool[:, layer, 0],
                self.store.fast_pool[:, layer, 1])

    def occupancy(self) -> dict:
        return self.store.occupancy()
