"""Bucketed packed prefill: the serving engine's prompt front door.

Before this module, prompts were *replayed* through the fused decode
scan one token per inner step (``prompt_buf`` in ``engine._fused_decode``)
— prompt ingestion cost a full decode dispatch per ``decode_block``
prompt tokens and TTFT was really queueing delay.  Prefill turns prompt
ingestion into **one dispatch per power-of-two length bucket**, modeled
on the JetStream/MaxText offline engine:

  * **pow2 buckets** — a prompt is padded to the smallest covering
    power-of-two bucket (``bucket_for``), so the set of compiled shapes
    is O(log max_len), not O(distinct prompt lengths);
  * **packing** — short prompts are concatenated into one bucket row
    under per-position segment bookkeeping (``pack_prompts``), so a
    bucket never runs mostly-padding.  Segment isolation is structural:
    each packed position's attention runs through a *per-row block
    table* listing only its own segment's KV pages, so a segment can
    never attend across a packing boundary (there is no foreign page to
    address), and the causal mask is the same ``lengths`` mask the
    decode kernel uses;
  * **one dispatch** — every packed position's K/V is written into the
    tier pools positionally (out-of-bucket padding rows scatter to an
    out-of-range slot and are dropped), full-sequence attention reuses
    ``paged_attention`` verbatim (`kernels.paged_attention_prefill`),
    and the first sampled token of every segment comes back with the
    dispatch — TTFT becomes prompt-length-proportional measurement, not
    approximation;
  * **AOT** — every (bucket, pool-variant) dispatch is precompiled by
    ``PagedServingEngine.warmup()`` via ``jit(...).lower().compile()``,
    so first-request latency is serving time, not compile time.
    ``PrefillRunner.n_compiles`` counts compilations; after warmup it
    must not move (pinned by tests/test_prefill.py).

Bit-parity with the prompt-replay oracle is a hard invariant (tokens,
KV pool contents, SysMon read/write/bank/slab counters): the per-layer
op sequence below mirrors ``engine._decode_core`` /
``_decode_core_pinned`` exactly — same pool scatter, same
``paged_attention`` mask math (masked scores are -1e30 regardless of
what garbage sits beyond a row's causal prefix), same row-independent
norm/projection/FFN einsums — so position ``p`` of a packed segment
produces bitwise the decode-step-at-``p`` output.  What *changes* is
the monitoring cadence: the engine reports the burst to SysMon as one
``record_dense`` streaming sampling instead of K fake decode touches,
so the next memos pass sees a sequential write burst (cold, rarely
touched), exactly the access-pattern asymmetry the paper exploits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import (paged_attention_prefill,
                                           paged_attention_prefill_pages)
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import transformer as T


# =============================================================================
# buckets + packing (pure host-side policy, no jax)
# =============================================================================

def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def bucket_for(n: int, min_bucket: int, max_bucket: int) -> int:
    """Smallest covering pow2 bucket for a prompt of ``n`` tokens,
    floored at ``min_bucket``.  Raises ValueError past ``max_bucket`` —
    the caller (``submit``) surfaces that as a structured rejection."""
    if n > max_bucket:
        raise ValueError(
            f"prompt of {n} tokens exceeds the largest prefill bucket "
            f"({max_bucket}); raise prefill_max_bucket / max_pages_per_seq "
            f"or shorten the prompt")
    return max(next_pow2(n), min_bucket)


def bucket_list(min_bucket: int, max_bucket: int) -> list[int]:
    """Every bucket warmup advertises: pow2s in [min_bucket, max_bucket]."""
    out = []
    b = next_pow2(min_bucket)
    while b <= max_bucket:
        out.append(b)
        b *= 2
    return out


@dataclass
class PackedGroup:
    """One prefill dispatch: segments packed into a single bucket row."""
    bucket: int
    requests: list = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return sum(len(r.prompt) for r in self.requests)


def pack_prompts(reqs: list, *, min_bucket: int, max_bucket: int,
                 pack: bool = True, max_segments: int = 4
                 ) -> list[PackedGroup]:
    """Greedy packing in admission order (order preservation keeps the
    priority-aware batcher's decisions intact): prompts coalesce into
    one group while the packed total still fits ``max_bucket`` and the
    segment budget holds — the group's bucket *escalates* to the
    smallest pow2 covering the packed total, so a burst of short
    prompts becomes one wide dispatch instead of one dispatch each
    (one host round-trip per group is what makes prefill cheaper than
    absorbing prompts into the batched decode scan)."""
    groups: list[PackedGroup] = []
    i = 0
    while i < len(reqs):
        total = len(reqs[i].prompt)
        bucket_for(total, min_bucket, max_bucket)   # raises past the cap
        members = [reqs[i]]
        i += 1
        if pack:
            while (i < len(reqs) and len(members) < max_segments
                   and total + len(reqs[i].prompt) <= max_bucket):
                members.append(reqs[i])
                total += len(reqs[i].prompt)
                i += 1
        groups.append(PackedGroup(
            bucket=max(next_pow2(total), min_bucket), requests=members))
    return groups


def replay_page_counts(prompt_lens: list[int], page_tables: np.ndarray,
                       page: int, n_pages: int
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Closed-form per-logical-page (reads, writes) event totals for a
    packed prefill, *identical in total to the prompt-replay stream*:
    replaying an ``Lp``-token prompt reads segment page ``j`` once per
    inner step whose prefix covers it (``Lp - j*page`` steps) and writes
    it once per step whose tail lands on it (``min(page, Lp - j*page)``).
    These dense totals feed both the store's version/traffic charge and
    SysMon's ``record_dense`` — raw counters stay bit-identical to the
    oracle while the sampling cadence collapses to one streaming touch."""
    reads = np.zeros(n_pages, np.int64)
    writes = np.zeros(n_pages, np.int64)
    for si, lp in enumerate(prompt_lens):
        n_pg = (lp - 1) // page + 1
        for j in range(n_pg):
            pid = int(page_tables[si, j])
            reads[pid] += lp - j * page
            writes[pid] += min(page, lp - j * page)
    return reads, writes


# =============================================================================
# the jitted prefill dispatches
# =============================================================================

class PrefillRunner:
    """Owns the compiled (bucket, pool-variant) prefill executables.

    ``get_plain``/``get_pinned`` return AOT-compiled executables
    (``jit(...).lower(shapes).compile()``), compiling on first use and
    counting every compile in ``n_compiles`` — ``warmup()`` walks the
    advertised bucket list so serving never compiles."""

    def __init__(self, engine):
        self.eng = engine
        scfg = engine.scfg
        cap = scfg.max_pages_per_seq * scfg.page_size
        self.min_bucket = next_pow2(scfg.prefill_min_bucket)
        self.max_bucket = (next_pow2(scfg.prefill_max_bucket)
                           if scfg.prefill_max_bucket is not None
                           else next_pow2(cap))
        self.max_bucket = min(self.max_bucket, next_pow2(cap))
        self.max_segments = scfg.prefill_max_segments
        self._plain: dict[int, object] = {}
        self._pinned: dict[int, object] = {}
        self.n_compiles = 0

    @property
    def buckets(self) -> list[int]:
        return bucket_list(self.min_bucket, self.max_bucket)

    def n_table_pages(self, bucket: int) -> int:
        """Per-row block-table width: just the pages covering the bucket
        (not ``max_pages_per_seq`` — the attention gather materializes
        [L, P, page] keys, so the table stays as narrow as possible)."""
        page = self.eng.scfg.page_size
        return (bucket + page - 1) // page

    # -- core compute (mirrors engine._decode_core op-for-op) -----------------
    def _core_plain(self, params, tokens, local_pos, row_tables, lengths,
                    write_slot, write_off, seg_last, fast_pool):
        """One packed prefill over the tier-0 pool.  tokens/local_pos
        [L] i32 (padding rows: pos 0, length 0); row_tables [L, Pp]
        fast-pool slots of the row's own segment; lengths [L] causal
        prefix length (= local_pos+1, 0 for padding); write_slot [L]
        pool slot for this position's K/V (out-of-range for padding —
        dropped); seg_last [S] row index of each segment's last token.
        Returns (first_tokens [S], seg_logits [S, Vp], expert_counts,
        fast_pool)."""
        cfg = self.eng.cfg
        Lb = tokens.shape[0]
        h = T.embed_in(params, cfg, {"tokens": tokens[None, :]}, None)
        cos, sin = L.rope_angles(local_pos[None, :], cfg.head_dim,
                                 cfg.rope_theta)
        valid = (lengths > 0)[None, :]
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            dtype = fast_pool.dtype
            fast_pool = fast_pool.at[write_slot, l, 0, write_off].set(
                k[0].astype(dtype), mode="drop")
            fast_pool = fast_pool.at[write_slot, l, 1, write_off].set(
                v[0].astype(dtype), mode="drop")
            out = paged_attention_prefill(q[0], fast_pool[:, l, 0],
                                          fast_pool[:, l, 1], row_tables,
                                          lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                Lb, cfg.n_heads, cfg.head_dim), p.wo)[None, :, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None, valid=valid)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[0]          # [L, Vp]
        seg_logits = logits[seg_last]                     # [S, Vp]
        first = jnp.argmax(seg_logits[:, :cfg.vocab],
                           axis=-1).astype(jnp.int32)
        return first, seg_logits, counts_acc, fast_pool

    def _core_pinned(self, params, tokens, local_pos, row_tables, pool_sel,
                     lengths, write_slot, write_sel, write_off, seg_last,
                     fast_pool, pinned_pool, remap):
        """Dual-pool packed prefill (mirrors ``_decode_core_pinned``):
        block tables hold each page's slot in its own pool — pinned
        logical slots translate through ``remap`` in-dispatch — and each
        position's K/V scatters into whichever pool owns its page, with
        the other pool's index driven out of range and dropped.  Wear
        and integrity for the pinned writes are charged at the boundary
        by the engine (host-side, same totals as per-token charging)."""
        cfg = self.eng.cfg
        Lb = tokens.shape[0]
        n_fast = fast_pool.shape[0]
        n_pin = pinned_pool.shape[0]
        row_tables = jnp.where(
            pool_sel > 0,
            remap[jnp.clip(row_tables, 0, n_pin - 1)], row_tables)
        wslot = jnp.where(write_sel > 0,
                          remap[jnp.clip(write_slot, 0, n_pin - 1)],
                          write_slot)
        f_idx = jnp.where(write_sel > 0, n_fast, wslot)
        p_idx = jnp.where(write_sel > 0, wslot, n_pin)
        sel_pages = (pool_sel > 0)[:, :, None, None, None]
        h = T.embed_in(params, cfg, {"tokens": tokens[None, :]}, None)
        cos, sin = L.rope_angles(local_pos[None, :], cfg.head_dim,
                                 cfg.rope_theta)
        valid = (lengths > 0)[None, :]
        counts_acc = (jnp.zeros((cfg.n_experts,), jnp.int32)
                      if cfg.is_moe else jnp.int32(0))
        for l in range(cfg.n_layers):
            lp = T._tree_slice(params["layers"], l)
            x = L.rms_norm(h, lp["ln1"], eps=cfg.norm_eps,
                           gemma_style=cfg.gemma_norm)
            p = T._attn_from_dict(lp["attn"])
            q, k, v = attn_mod.project_qkv(p, x, cos, sin)
            fd, pd = fast_pool.dtype, pinned_pool.dtype
            fast_pool = fast_pool.at[f_idx, l, 0, write_off].set(
                k[0].astype(fd), mode="drop")
            fast_pool = fast_pool.at[f_idx, l, 1, write_off].set(
                v[0].astype(fd), mode="drop")
            pinned_pool = pinned_pool.at[p_idx, l, 0, write_off].set(
                k[0].astype(pd), mode="drop")
            pinned_pool = pinned_pool.at[p_idx, l, 1, write_off].set(
                v[0].astype(pd), mode="drop")
            k_pages = jnp.where(sel_pages,
                                pinned_pool[row_tables, l, 0].astype(fd),
                                fast_pool[row_tables, l, 0])
            v_pages = jnp.where(sel_pages,
                                pinned_pool[row_tables, l, 1].astype(fd),
                                fast_pool[row_tables, l, 1])
            out = paged_attention_prefill_pages(q[0], k_pages, v_pages,
                                                lengths)
            out = jnp.einsum("bhk,hkd->bd", out.reshape(
                Lb, cfg.n_heads, cfg.head_dim), p.wo)[None, :, :]
            h = h + out
            h, counts, _ = T._ffn_block(lp, cfg, h, None, valid=valid)
            if cfg.is_moe and counts is not None:
                counts_acc = counts_acc + counts
        h = L.rms_norm(h, params["final_norm"], eps=cfg.norm_eps,
                       gemma_style=cfg.gemma_norm)
        logits = T.logits_out(params, cfg, h)[0]
        seg_logits = logits[seg_last]
        first = jnp.argmax(seg_logits[:, :cfg.vocab],
                           axis=-1).astype(jnp.int32)
        return first, seg_logits, counts_acc, fast_pool, pinned_pool

    # -- AOT compilation ------------------------------------------------------
    def _abstract_params(self):
        return jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            self.eng.params)

    def _compile_plain(self, bucket: int):
        store = self.eng.kv.store
        Pp = self.n_table_pages(bucket)
        S = self.max_segments
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        fn = jax.jit(self._core_plain, donate_argnums=(8,))
        compiled = fn.lower(
            self._abstract_params(), i32(bucket), i32(bucket),
            i32(bucket, Pp), i32(bucket), i32(bucket), i32(bucket), i32(S),
            jax.ShapeDtypeStruct(store.fast_pool.shape,
                                 store.fast_pool.dtype)).compile()
        self.n_compiles += 1
        self._plain[bucket] = compiled
        return compiled

    def _compile_pinned(self, bucket: int):
        eng = self.eng
        store = eng.kv.store
        ppool = store.pools[eng.pinned_tier]
        n_pin = ppool.data.shape[0]
        Pp = self.n_table_pages(bucket)
        S = self.max_segments
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        fn = jax.jit(self._core_pinned, donate_argnums=(10, 11))
        compiled = fn.lower(
            self._abstract_params(), i32(bucket), i32(bucket),
            i32(bucket, Pp), i32(bucket, Pp), i32(bucket), i32(bucket),
            i32(bucket), i32(bucket), i32(S),
            jax.ShapeDtypeStruct(store.fast_pool.shape,
                                 store.fast_pool.dtype),
            jax.ShapeDtypeStruct(ppool.data.shape, ppool.data.dtype),
            i32(n_pin)).compile()
        self.n_compiles += 1
        self._pinned[bucket] = compiled
        return compiled

    def get_plain(self, bucket: int):
        return self._plain.get(bucket) or self._compile_plain(bucket)

    def get_pinned(self, bucket: int):
        return self._pinned.get(bucket) or self._compile_pinned(bucket)

    def warmup(self) -> None:
        """AOT-compile every advertised (bucket, pool-variant) dispatch."""
        for b in self.buckets:
            self.get_plain(b)
            if self.eng.pinned_tier is not None:
                self.get_pinned(b)

    # -- host-side arg assembly ----------------------------------------------
    def build_args(self, group: PackedGroup, block_tables: np.ndarray,
                   pool_sel: np.ndarray | None) -> dict[str, np.ndarray]:
        """Expand a packed group's per-*segment* tables into the
        per-*position* arrays the dispatch consumes.  ``block_tables``
        (and ``pool_sel`` on the dual-pool path) are [S, Pp] from
        ``fill_tables``/``fill_tables_mixed`` over the group's requests."""
        eng = self.eng
        page = eng.scfg.page_size
        Lb = group.bucket
        Pp = self.n_table_pages(Lb)
        S = self.max_segments
        n_fast = eng.kv.store.fast_pool.shape[0]
        tokens = np.zeros(Lb, np.int32)
        local_pos = np.zeros(Lb, np.int32)
        lengths = np.zeros(Lb, np.int32)
        # padding rows scatter out of range in *both* pools: slot n_fast
        # with sel 0 is dropped by the fast pool, and maps to p_idx n_pin
        # on the pinned path
        write_slot = np.full(Lb, n_fast, np.int32)
        write_sel = np.zeros(Lb, np.int32)
        write_off = np.zeros(Lb, np.int32)
        row_tables = np.zeros((Lb, Pp), np.int32)
        row_sel = np.zeros((Lb, Pp), np.int32)
        seg_last = np.zeros(S, np.int32)
        off = 0
        for si, r in enumerate(group.requests):
            lp = len(r.prompt)
            sl = slice(off, off + lp)
            tokens[sl] = r.prompt
            pos = np.arange(lp, dtype=np.int32)
            local_pos[sl] = pos
            lengths[sl] = pos + 1
            row_tables[sl] = block_tables[si]
            if pool_sel is not None:
                row_sel[sl] = pool_sel[si]
                write_sel[sl] = pool_sel[si, pos // page]
            write_slot[sl] = block_tables[si, pos // page]
            write_off[sl] = pos % page
            seg_last[si] = off + lp - 1
            off += lp
        return dict(tokens=tokens, local_pos=local_pos, lengths=lengths,
                    write_slot=write_slot, write_sel=write_sel,
                    write_off=write_off, row_tables=row_tables,
                    row_sel=row_sel, seg_last=seg_last)
