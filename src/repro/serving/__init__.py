from .engine import PagedServingEngine, ServeConfig
from .kv_cache import PagedKVCache, PagedKVConfig
from .scheduler import ContinuousBatcher, Request

__all__ = ["PagedServingEngine", "ServeConfig", "PagedKVCache",
           "PagedKVConfig", "ContinuousBatcher", "Request"]
