from .engine import PagedServingEngine, ServeConfig
from .kv_cache import PagedKVCache, PagedKVConfig
from .prefill import PackedGroup, PrefillRunner, bucket_for, pack_prompts
from .scheduler import ContinuousBatcher, Request

__all__ = ["PagedServingEngine", "ServeConfig", "PagedKVCache",
           "PagedKVConfig", "ContinuousBatcher", "Request",
           "PackedGroup", "PrefillRunner", "bucket_for", "pack_prompts"]
