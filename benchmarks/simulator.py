"""Shared emulation machinery for the paper-figure benchmarks.

Mirrors the paper's evaluation methodology (Sec. 6.1): trace-driven
emulation — synthetic per-pass (reads, writes) page traces for workloads
with the memory personalities the paper studies (SPEC-like + Memcached-
like), pushed through a placement policy, scored with the Table-1
DRAM/NVM cost model (core/costmodel.py).

Policies reproduced (Sec. 7.3):
  * ``baseline``  — unmodified kernel: channel-interleaved placement,
                    no migration, hash-mapped cache (no slab isolation);
  * ``vertical``  — cache-bank vertical partitioning [36,37]: slab
                    isolation + bank rebalancing, channel-blind;
  * ``utility``   — utility-based cache partitioning [31]: slab quotas by
                    marginal utility, no bank/channel awareness;
  * ``memos``     — the full loop: WD prediction -> channel allocation ->
                    Algorithm-2 bank/slab targeting -> migration +
                    bandwidth balancing.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import costmodel as cm
from repro.core import patterns, predictor

# tier indices for the trace emulation's two-channel machine model —
# imported from the core two-tier compatibility shim
from repro.core.hierarchy import FAST, SLOW  # noqa: E402


# =============================================================================
# workload personalities (Fig. 1 / Sec. 3 characters)
# =============================================================================

@dataclass
class AppSpec:
    name: str
    n_pages: int = 256
    hot_frac: float = 0.1          # fraction of pages in the hot set
    hot_rate: float = 8.0          # accesses/page/pass in the hot set
    cold_rate: float = 0.05
    wd_frac: float = 0.5           # fraction of hot accesses that are writes
    wd_burst_len: int = 12         # passes a WD burst persists
    wd_gap_len: int = 40           # passes between bursts (astar: long)
    shift_every: int = 0           # hot-set rotation period (memcached-like)
    streaming: bool = False        # thrashing sequential scans (libquantum)
    bank_skew: float = 0.0         # hot pages concentrated on few banks
    intensity: float = 1.0         # memory accesses per unit compute


PERSONALITIES = {
    # transient WD bursts over a mostly cold space (Fig. 1 astar)
    "astar": AppSpec("astar", hot_frac=0.15, wd_frac=0.7, wd_burst_len=6,
                     wd_gap_len=48, intensity=0.4),
    # large active set, mixed WD/RD all the time (Fig. 1 cactusADM)
    "cactus": AppSpec("cactus", hot_frac=0.5, wd_frac=0.45, wd_burst_len=20,
                      wd_gap_len=8, intensity=0.8),
    # spatially segregated WD and RD regions (Fig. 1 hmmer)
    "hmmer": AppSpec("hmmer", hot_frac=0.3, wd_frac=0.9, wd_burst_len=30,
                     wd_gap_len=6, intensity=0.5),
    # streaming RD scans that thrash the cache (libquantum)
    "libquantum": AppSpec("libquantum", hot_frac=0.8, wd_frac=0.02,
                          streaming=True, bank_skew=0.6, intensity=1.0),
    # memory-intensive write-heavy with bank skew (mcf / GemsFDTD)
    "mcf": AppSpec("mcf", hot_frac=0.4, wd_frac=0.6, wd_burst_len=24,
                   wd_gap_len=10, bank_skew=0.8, intensity=1.0),
    "gems": AppSpec("gems", hot_frac=0.3, wd_frac=0.4, bank_skew=0.9,
                    wd_burst_len=16, wd_gap_len=16, intensity=0.9),
    # small, frequently shifting hot set (Memcached, Sec. 7.1)
    "memcached": AppSpec("memcached", hot_frac=0.08, hot_rate=16.0,
                         wd_frac=0.5, shift_every=12, wd_burst_len=8,
                         wd_gap_len=4, intensity=0.7),
    # xalan-like: moderate intensity, mixed
    "xalan": AppSpec("xalan", hot_frac=0.25, wd_frac=0.5, wd_burst_len=14,
                     wd_gap_len=20, intensity=0.7),
}


def make_trace(spec: AppSpec, n_passes: int, seed: int = 0
               ) -> tuple[np.ndarray, np.ndarray]:
    """Generate (reads, writes) uint16 [n_passes, n_pages]."""
    rng = np.random.RandomState(seed)
    P = spec.n_pages
    reads = np.zeros((n_passes, P), np.float64)
    writes = np.zeros((n_passes, P), np.float64)
    n_hot = max(1, int(spec.hot_frac * P))
    hot0 = rng.permutation(P)[:n_hot]
    period = spec.wd_burst_len + spec.wd_gap_len
    phase0 = rng.randint(0, period, size=P)
    for t in range(n_passes):
        if spec.shift_every and t % spec.shift_every == 0:
            hot0 = rng.permutation(P)[:n_hot]
        hot = hot0
        base = np.full(P, spec.cold_rate)
        base[hot] = spec.hot_rate
        if spec.streaming:
            # sequential scan: every page touched ~once per pass, read-only
            reads[t] = rng.poisson(1.0, P) + base * 0.1
            writes[t] = rng.poisson(spec.wd_frac, P) * (base > 1)
            continue
        in_burst = ((t + phase0) % period) < spec.wd_burst_len
        w_rate = base * spec.wd_frac * in_burst
        r_rate = base * (1 - spec.wd_frac * in_burst)
        reads[t] = rng.poisson(r_rate)
        writes[t] = rng.poisson(w_rate)
    return reads.astype(np.int32), writes.astype(np.int32)


# =============================================================================
# machine model
# =============================================================================

@dataclass
class Machine:
    n_banks: int = 16              # per channel
    n_slabs: int = 16
    fast_capacity: int = 256       # pages the DRAM channel can hold
    slow_capacity: int = 4096
    fast: cm.MediumParams = cm.DRAM
    slow: cm.MediumParams = cm.NVM
    llc_base_missrate: float = 0.35
    cpu_ns_per_access: float = 22.0  # non-memory work per access (Amdahl)


@dataclass
class PolicyState:
    tier: np.ndarray               # [P] per-page tier
    bank: np.ndarray               # [P] bank within its channel
    slab: np.ndarray               # [P] cache slab color
    hist: np.ndarray               # [P] WD history bytes
    migrations: int = 0
    slow_writes: int = 0
    slow_reads: int = 0
    fast_writes: int = 0
    fast_reads: int = 0


def init_state(n_pages: int, m: Machine, policy: str, seed: int = 0
               ) -> PolicyState:
    rng = np.random.RandomState(seed + 99)
    if policy == "memos":
        tier = np.full(n_pages, SLOW)     # start on NVM (Sec. 7.1)
    else:
        tier = (np.arange(n_pages) % 2).astype(np.int64)  # channel interleave
    fast_used = int((tier == FAST).sum())
    if fast_used > m.fast_capacity:       # overflow lands on NVM
        over = np.nonzero(tier == FAST)[0][m.fast_capacity:]
        tier[over] = SLOW
    return PolicyState(
        tier=tier,
        bank=rng.randint(0, m.n_banks, n_pages),
        slab=rng.randint(0, m.n_slabs, n_pages),
        hist=np.zeros(n_pages, np.uint8),
    )


def _popcount8(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = x - ((x >> 1) & 0x55)
    x = (x & 0x33) + ((x >> 2) & 0x33)
    return ((x + (x >> 4)) & 0x0F).astype(np.int32)


def predict_np(hist: np.ndarray) -> np.ndarray:
    ones = _popcount8(hist)
    out = np.where(ones >= predictor.HI_THRESH, predictor.WD_FREQ_H,
                   np.where(ones >= predictor.LO_THRESH,
                            predictor.WD_FREQ_L, predictor.UN_WD))
    suffix = hist & 0b111
    out = np.where(suffix == 0b111, predictor.WD_FREQ_H, out)
    out = np.where(suffix == 0, predictor.UN_WD, out)
    return out


@dataclass
class PassResult:
    latency_ns: float
    slow_latency_ns: float
    fast_energy_mw: float
    slow_energy_mw: float
    slow_write_bytes: float
    bank_imbalance_fast: float
    bank_imbalance_slow: float
    llc_missrate: float
    ipc_like: float                # throughput proxy: accesses / time


def step_policy(policy: str, st: PolicyState, reads: np.ndarray,
                writes: np.ndarray, m: Machine, *,
                max_migrations: int = 64) -> PassResult:
    """One sampling pass: classify -> (policy-specific) migrate -> score."""
    P = reads.shape[0]
    touched = (reads + writes) > 0
    wd = (2 * writes >= reads) & touched
    hot = (reads + writes) >= 4
    st.hist = (((st.hist.astype(np.uint16) << 1) | wd.astype(np.uint16))
               & 0xFF).astype(np.uint8)

    # ---- policy actions ------------------------------------------------------
    if policy == "memos":
        fut = predict_np(st.hist)
        want_fast = hot | (fut != predictor.UN_WD)
        # thrashing RD streams stay slow (reserved slab isolates them)
        streaming = hot & ~wd & (reads > 0) & (np.abs(reads - np.median(
            reads[touched]) if touched.any() else 0) < 1)
        # rank: WD_FREQ_H first then hotness (Fig. 10)
        cand = np.nonzero(want_fast & (st.tier == SLOW))[0]
        order = np.lexsort((-(reads + 2 * writes)[cand], -fut[cand]))
        cand = cand[order]
        fast_used = int((st.tier == FAST).sum())
        bank_load = np.bincount(st.bank[st.tier == FAST],
                                weights=hot[st.tier == FAST].astype(float),
                                minlength=m.n_banks)
        promoted = 0
        for p in cand[:max_migrations]:
            if fast_used >= m.fast_capacity:
                # evict the coldest UN_WD fast page (bandwidth balance spill)
                evictable = np.nonzero((st.tier == FAST) & ~want_fast)[0]
                if len(evictable) == 0:
                    break
                ev = evictable[np.argmin((reads + 2 * writes)[evictable])]
                st.tier[ev] = SLOW
                st.migrations += 1
                fast_used -= 1
            st.tier[p] = FAST
            # Algorithm 2: coldest bank; slab by reuse class
            b = int(np.argmin(bank_load))
            st.bank[p] = b
            bank_load[b] += 1
            st.slab[p] = 0 if streaming[p] else 1 + (p % (m.n_slabs - 2))
            st.migrations += 1
            fast_used += 1
            promoted += 1
        # drain cold/UN_WD pages off DRAM (lazy, optimistic path)
        cold_fast = np.nonzero((st.tier == FAST) & ~want_fast & ~touched)[0]
        for p in cold_fast[:max_migrations]:
            st.tier[p] = SLOW
            st.migrations += 1
        # intra-channel rebalancing on the NVM side too (Sec. 5.4: "even for
        # a specific channel, hot pages are migrated from highly utilized
        # banks to lower ones")
        traffic = (reads + writes).astype(float)
        slow_hot = np.nonzero((st.tier == SLOW) & touched)[0]
        slow_hot = slow_hot[np.argsort(-traffic[slow_hot])][:max_migrations]
        sload = np.bincount(st.bank[st.tier == SLOW],
                            weights=traffic[st.tier == SLOW],
                            minlength=m.n_banks)
        for p in slow_hot:
            b = int(np.argmin(sload))
            if sload[st.bank[p]] > sload[b] + traffic[p]:
                sload[st.bank[p]] -= traffic[p]
                st.bank[p] = b
                sload[b] += traffic[p]
                st.migrations += 1
    elif policy == "vertical":
        # bank+slab rebalance within channels; channel-blind (no migration
        # across DRAM/NVM)
        for tier in (FAST, SLOW):
            mask = st.tier == tier
            if not mask.any():
                continue
            load = np.bincount(st.bank[mask], weights=hot[mask].astype(float),
                               minlength=m.n_banks)
            hot_here = np.nonzero(mask & hot)[0]
            for p in hot_here[:max_migrations // 2]:
                b = int(np.argmin(load))
                load[st.bank[p]] -= 1
                st.bank[p] = b
                load[b] += 1
        streaming = hot & ~wd
        st.slab[streaming] = 0
    elif policy == "utility":
        # cache-only: give high-reuse pages dedicated slabs
        st.slab[hot] = 1 + (np.nonzero(hot)[0] % (m.n_slabs - 1))
    # baseline: nothing

    # ---- scoring --------------------------------------------------------------
    fast_mask = st.tier == FAST
    slow_mask = ~fast_mask

    # LLC model: thrashing streams pollute unless isolated in slab 0
    streaming_like = hot & ~wd
    isolated = streaming_like & (st.slab == 0)
    pollution = float(streaming_like.sum() - isolated.sum()) / max(P, 1)
    # slab crowding raises conflict misses
    slab_load = np.bincount(st.slab[touched], minlength=m.n_slabs)
    inner = slab_load[1:m.n_slabs - 1]  # reserved slabs are sacrificial
    crowding = float(np.std(inner)) / (max(float(np.mean(inner)), 1e-9))
    miss = np.clip(m.llc_base_missrate * (1 + 1.2 * pollution
                                          + 0.15 * crowding), 0.05, 1.0)

    # bank conflict model: row-buffer conflict rate grows with imbalance
    def imbalance(mask):
        # paper Fig. 6/15 metric: spread of *active page counts* per bank
        if not mask.any():
            return 0.0
        load = np.bincount(st.bank[mask & touched], minlength=m.n_banks)
        return float(np.std(load))

    def conflict(mask):
        if not mask.any():
            return 0.0
        load = np.bincount(st.bank[mask & touched], minlength=m.n_banks)
        mean = max(float(np.mean(load)), 1e-9)
        return min(1.0, 0.5 * float(np.std(load)) / mean)

    imb_f, imb_s = imbalance(fast_mask), imbalance(slow_mask)
    conf_f, conf_s = conflict(fast_mask), conflict(slow_mask)

    # memory accesses that reach DRAM/NVM = misses
    f_reads = float(reads[fast_mask].sum()) * miss
    f_writes = float(writes[fast_mask].sum()) * miss
    s_reads = float(reads[slow_mask].sum()) * miss
    s_writes = float(writes[slow_mask].sum()) * miss
    st.fast_reads += f_reads
    st.fast_writes += f_writes
    st.slow_reads += s_reads
    st.slow_writes += s_writes

    cf = cm.AccessCounts(f_reads, f_writes)
    cs = cm.AccessCounts(s_reads, s_writes)
    lat = cm.mean_latency_ns(cf, cs, m.fast, m.slow, conf_f, conf_s)
    slow_lat = cm.slow_tier_latency_ns(cs, m.slow, conf_s)
    window_s = 1e-3
    e_f = cm.dynamic_energy_mw(cf, m.fast, window_s)
    e_s = cm.dynamic_energy_mw(cs, m.slow, window_s)

    total_acc = float((reads + writes).sum())
    mem_acc = total_acc * miss
    time_ns = total_acc * m.cpu_ns_per_access + mem_acc * lat
    ipc = total_acc / max(time_ns, 1e-9)

    return PassResult(
        latency_ns=lat, slow_latency_ns=slow_lat,
        fast_energy_mw=e_f, slow_energy_mw=e_s,
        slow_write_bytes=s_writes * 4096,
        bank_imbalance_fast=imb_f, bank_imbalance_slow=imb_s,
        llc_missrate=float(miss), ipc_like=ipc,
    )


def run_app(app: str, policy: str, *, n_passes: int = 120,
            machine: Machine | None = None, seed: int = 0,
            n_pages: int | None = None) -> dict:
    spec = PERSONALITIES[app]
    if n_pages:
        from dataclasses import replace
        spec = replace(spec, n_pages=n_pages)
    m = machine or Machine()
    reads, writes = make_trace(spec, n_passes, seed)
    st = init_state(spec.n_pages, m, policy, seed)
    if spec.bank_skew > 0:
        # physical allocation concentrates the busy pages on few banks
        # (contiguous allocations + bank-bit aliasing, Fig. 6)
        rng = np.random.RandomState(seed + 7)
        busy = np.argsort(-(reads.sum(0) + writes.sum(0)))
        n_skew = int(spec.bank_skew * spec.n_pages)
        st.bank[busy[:n_skew]] = rng.randint(
            0, max(2, m.n_banks // 4), n_skew)
    res = [step_policy(policy, st, reads[t], writes[t], m)
           for t in range(n_passes)]
    return {
        "app": app, "policy": policy, "state": st, "passes": res,
        "mean_latency_ns": float(np.mean([r.latency_ns for r in res])),
        "slow_latency_ns": float(np.mean([r.slow_latency_ns for r in res])),
        "slow_energy_mw": float(np.mean([r.slow_energy_mw for r in res])),
        "fast_energy_mw": float(np.mean([r.fast_energy_mw for r in res])),
        "slow_writes": st.slow_writes, "slow_reads": st.slow_reads,
        "fast_writes": st.fast_writes, "fast_reads": st.fast_reads,
        "throughput": float(np.mean([r.ipc_like for r in res])),
        "llc_missrate": float(np.mean([r.llc_missrate for r in res])),
        "bank_imb_fast": float(np.mean([r.bank_imbalance_fast for r in res])),
        "bank_imb_slow": float(np.mean([r.bank_imbalance_slow for r in res])),
    }
